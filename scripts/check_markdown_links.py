#!/usr/bin/env python3
"""Check that intra-repo markdown links resolve.

Scans the given markdown files (and all ``*.md`` under given
directories) for ``[text](target)`` links and verifies every relative
target exists on disk (anchors are stripped; ``http(s)``/``mailto``
links are skipped — CI must not depend on the network).  Exits non-zero
listing every broken link.

    python scripts/check_markdown_links.py README.md ROADMAP.md docs
"""

from __future__ import annotations

import os
import re
import sys

# [text](target) — target up to the first unescaped ')'; tolerates
# "(url \"title\")" forms by splitting on whitespace
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "#")


def collect(paths):
    for p in paths:
        if os.path.isdir(p):
            for dirpath, _, names in os.walk(p):
                yield from (os.path.join(dirpath, n) for n in names
                            if n.endswith(".md"))
        else:
            yield p


def check_file(path: str):
    broken = []
    with open(path, encoding="utf-8") as f:
        text = f.read()
    # fenced code blocks routinely contain literal `](` examples
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            broken.append((target, resolved))
    return broken


def main(argv) -> int:
    files = sorted(set(collect(argv or ["README.md", "ROADMAP.md", "docs"])))
    bad = 0
    for path in files:
        for target, resolved in check_file(path):
            bad += 1
            print(f"BROKEN  {path}: ({target}) -> {resolved}")
    print(f"checked {len(files)} markdown files: "
          f"{'all links resolve' if not bad else f'{bad} broken link(s)'}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
