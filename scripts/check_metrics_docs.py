#!/usr/bin/env python3
"""Check that docs/OBSERVABILITY.md documents every metric the code can
emit.

The source of truth is ``repro.obs.catalog`` — metric names derived from
the same dataclass introspection and name families the runtime registers
(``dataclass_gauges`` bridges, per-op and per-span histogram families).
Any name in the catalog that never appears in the doc fails the lint, so
adding a metric without documenting it breaks docs CI.

    PYTHONPATH=src python scripts/check_metrics_docs.py
"""

from __future__ import annotations

import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC = os.path.join(ROOT, "docs", "OBSERVABILITY.md")


def main() -> int:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.obs.catalog import all_names

    try:
        with open(DOC) as f:
            text = f.read()
    except OSError as e:
        print(f"cannot read {DOC}: {e}")
        return 1

    missing = [name for name in all_names() if name not in text]
    if missing:
        print(f"{len(missing)} registered metric(s) missing from "
              f"docs/OBSERVABILITY.md:")
        for name in missing:
            print(f"  {name}")
        return 1
    print(f"ok — all {len(all_names())} catalog metrics documented in "
          f"docs/OBSERVABILITY.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
