"""Cluster fault tolerance end-to-end: a 3-node cache cluster serves a
real workload through the unchanged ``ServingEngine``; one node is
SIGKILLed mid-workload; serving degrades but stays *correct* (zero
committed blocks lost — every read fails over to the surviving replica);
the node rejoins on the same address and the ring rebalances back.

The engine and hierarchy never learn any of this happened: the cluster
store is just another ``StorageBackend``.

    PYTHONPATH=src python examples/failover.py
"""

import shutil
import tempfile

import numpy as np

from repro.cache.hierarchy import CacheHierarchy
from repro.cluster import ClusterKVBlockStore, spawn_local_node
from repro.configs import get_config
from repro.serving import ComputeModel, ServingEngine
from repro.workload import StagedWorkload

BLOCK = 16
PROMPT = 256
N_NODES = 3
REPLICATION = 2


def make_engine(cluster: ClusterKVBlockStore) -> ServingEngine:
    h = CacheHierarchy(BLOCK, device_budget_blocks=64, host_budget_blocks=128,
                       store=cluster)
    return ServingEngine(h, ComputeModel(get_config("glm4-9b")),
                         kv_bytes_per_token=512)


def hit(recs) -> float:
    return float(np.mean([r.reused_tokens / r.prompt_len for r in recs]))


def main():
    work = tempfile.mkdtemp(prefix="failover_")
    print(f"[cluster] spawning {N_NODES} local cache-node processes ...")
    nodes = [
        spawn_local_node(f"{work}/node_{i}", block_size=BLOCK, codec="raw",
                         io_threads=2)
        for i in range(N_NODES)
    ]
    cluster = ClusterKVBlockStore(
        [n.address for n in nodes], replication=REPLICATION, io_threads=2,
        retries=1, timeout_s=20.0,
    )
    print(f"[cluster] up: {[n.address for n in nodes]}, replication={REPLICATION}")
    engine = make_engine(cluster)

    wl = StagedWorkload(prompt_len=PROMPT, requests_per_stage=24,
                        stages=(0.7, 0.7), block_size=BLOCK, corpus_size=8, seed=0)

    # --- phase 1: warm the corpus through the engine, serve stage 0 -------
    warm_prompts = list(wl.warmup_prompts(8 * PROMPT))
    for p in warm_prompts:
        engine.submit(type("R", (), {"tokens": p, "rid": -1, "stage": -1})())
    engine.run()
    recs = []
    for r in wl.stage_requests(0):
        engine.submit(r)
    recs.extend(engine.run())
    engine.drain()  # settle write-behind: everything below counts as committed
    committed = {i: cluster.probe(p) for i, p in enumerate(warm_prompts)}
    print(f"[phase 1] served {len(recs)} requests over 3 nodes, "
          f"hit {hit(recs):.2f}; committed prefixes on cluster: "
          f"{sum(v // BLOCK for v in committed.values())} blocks")

    # --- phase 2: SIGKILL one node mid-workload ---------------------------
    victim = cluster.replicas_for(warm_prompts[0])[0]
    print(f"[phase 2] SIGKILL node {victim} ({nodes[victim].address}) ...")
    nodes[victim].kill()
    recs2 = []
    for r in wl.stage_requests(1):
        engine.submit(r)
    recs2.extend(engine.run())
    engine.drain()
    lost = sum(1 for i, p in enumerate(warm_prompts)
               if cluster.probe(p) < committed[i])
    cs = cluster.cluster_stats
    print(f"[phase 2] served {len(recs2)} requests degraded "
          f"(down={cluster.down_nodes}), hit {hit(recs2):.2f}; "
          f"failover reads: {cs.failovers}, degraded reads: {cs.degraded_reads}")
    print(f"[phase 2] lost committed blocks after kill: {lost}")
    assert lost == 0, "replication=2 must survive a single node kill"
    assert hit(recs2) >= 0.5, "degraded cluster must keep serving cached prefixes"

    # --- phase 3: rejoin on the same address; ring rebalances -------------
    host, port = nodes[victim].address
    shutil.rmtree(nodes[victim].root, ignore_errors=True)  # cold restart
    nodes[victim] = spawn_local_node(f"{work}/node_{victim}", port=port,
                                     block_size=BLOCK, codec="raw", io_threads=2)
    revived = cluster.maintenance(0)["revived"]  # maintenance pings down nodes
    print(f"[phase 3] node {victim} rejoined on {nodes[victim].address}: "
          f"revived={revived}, live={cluster.live_nodes}")
    assert revived == [victim] and not cluster.down_nodes
    recs3 = []
    for r in wl.stage_requests(0):  # replay stage 0 against the healed ring
        engine.submit(r)
    recs3.extend(engine.run())
    engine.drain()
    still_lost = sum(1 for i, p in enumerate(warm_prompts)
                     if cluster.probe(p) < committed[i])
    print(f"[phase 3] healed cluster served {len(recs3)} requests, "
          f"hit {hit(recs3):.2f}; lost committed blocks: {still_lost} "
          f"(cold rejoined replica is backstopped by best-of-replica reads)")
    assert still_lost == 0

    report = cluster.report()
    print(f"[report] cluster: {report['cluster']}, "
          f"rpcs={sum(r['rpcs'] for r in report['rpc'].values())}, "
          f"chunks={sum(r['stream_chunks'] for r in report['rpc'].values())}")
    for i, nd in sorted(report["nodes"].items()):
        print(f"[report] node {i} ({nd['name']}): "
              f"disk={nd['disk_bytes'] or 0} B in {nd['file_count']} files, "
              f"get_blocks={nd['get_blocks']}, put_blocks={nd['put_blocks']}, "
              f"streams={nd['streams']}, chunks={nd['stream_chunks']}, "
              f"sendfile={nd['sendfile_bytes'] or 0} B")
    cluster.close()
    for n in nodes:
        n.close()
    shutil.rmtree(work, ignore_errors=True)
    print("ok — zero committed blocks lost across kill and rejoin")


if __name__ == "__main__":
    main()
