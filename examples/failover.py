"""Fault tolerance end-to-end: a serving replica crashes mid-workload; a
replacement reopens the SAME disk store (WAL + manifest recovery), takes
over the unserved queue (request re-dispatch), and keeps hitting the
prefixes the dead replica populated — nothing cached on disk is lost.

    PYTHONPATH=src python examples/failover.py
"""

import tempfile

import numpy as np

from repro.cache.hierarchy import CacheHierarchy
from repro.configs import get_config
from repro.core.store import KVBlockStore
from repro.serving import ComputeModel, ServingEngine
from repro.workload import StagedWorkload

BLOCK = 16
PROMPT = 256


def make_replica(root: str) -> ServingEngine:
    store = KVBlockStore(root, block_size=BLOCK)  # reopens + recovers if exists
    h = CacheHierarchy(BLOCK, device_budget_blocks=64, host_budget_blocks=128, store=store)
    cfg = get_config("glm4-9b")
    return ServingEngine(h, ComputeModel(cfg), kv_bytes_per_token=512)


def main():
    root = tempfile.mkdtemp(prefix="failover_") + "/store"
    wl = StagedWorkload(prompt_len=PROMPT, requests_per_stage=24,
                        stages=(0.7,), block_size=BLOCK, corpus_size=6, seed=0)
    queue = wl.stage_requests(0)

    # --- replica A serves the first half, then "crashes" hard -------------
    a = make_replica(root)
    for p in wl.warmup_prompts(6 * PROMPT):
        a.submit(type("R", (), {"tokens": p, "rid": -1, "stage": -1})())
    a.run()
    half = len(queue) // 2
    for r in queue[:half]:
        a.submit(r)
    recs_a = a.run()
    hit_a = np.mean([r.reused_tokens / r.prompt_len for r in recs_a])
    print(f"[replica A] served {len(recs_a)} requests, hit {hit_a:.2f}")
    # hard crash: no close(), no flush of the memtable — WAL must cover it
    del a

    # --- replica B recovers the store and takes over the queue ------------
    b = make_replica(root)  # WAL replay + manifest recovery happens here
    for r in queue[half:]:  # re-dispatch the dead replica's queue
        b.submit(r)
    recs_b = b.run()
    hit_b = np.mean([r.reused_tokens / r.prompt_len for r in recs_b])
    print(f"[replica B] recovered store ({b.h.store.index.n_entries} index entries, "
          f"{b.h.store.file_count} files) and served {len(recs_b)} re-dispatched requests, "
          f"hit {hit_b:.2f}")
    assert hit_b >= 0.5, "disk-tier prefixes must survive the crash"
    print("ok — cached prefixes survived the replica failure")


if __name__ == "__main__":
    main()
