"""Cluster fault tolerance end-to-end: a 3-node cache cluster serves a
real workload through the unchanged ``ServingEngine``; one node is
SIGKILLed mid-workload; serving degrades but stays *correct* (zero
committed blocks lost — every read fails over to the surviving replica);
the node rejoins on the same address and the ring rebalances back.

The engine and hierarchy never learn any of this happened: the cluster
store is just another ``StorageBackend``.

The per-node numbers printed at the end come from the observability
layer: ``cluster.scrape_cluster()`` fans ``OP_METRICS`` out to every
node and returns each node's full metrics snapshot (counters, gauges,
latency histograms).  The same scrape is exercised *while the victim is
down* — a dead node must come back as ``unreachable`` immediately, not
hang the scrape.

    PYTHONPATH=src python examples/failover.py
"""

import shutil
import tempfile
import time

import numpy as np

from repro.cache.hierarchy import CacheHierarchy
from repro.cluster import ClusterKVBlockStore, spawn_local_node
from repro.configs import get_config
from repro.serving import ComputeModel, ServingEngine
from repro.workload import StagedWorkload

BLOCK = 16
PROMPT = 256
N_NODES = 3
REPLICATION = 2


def make_engine(cluster: ClusterKVBlockStore) -> ServingEngine:
    h = CacheHierarchy(BLOCK, device_budget_blocks=64, host_budget_blocks=128,
                       store=cluster)
    return ServingEngine(h, ComputeModel(get_config("glm4-9b")),
                         kv_bytes_per_token=512, tracing=True)


def hit(recs) -> float:
    return float(np.mean([r.reused_tokens / r.prompt_len for r in recs]))


def main():
    work = tempfile.mkdtemp(prefix="failover_")
    print(f"[cluster] spawning {N_NODES} local cache-node processes ...")
    nodes = [
        spawn_local_node(f"{work}/node_{i}", block_size=BLOCK, codec="raw",
                         io_threads=2)
        for i in range(N_NODES)
    ]
    cluster = ClusterKVBlockStore(
        [n.address for n in nodes], replication=REPLICATION, io_threads=2,
        retries=1, timeout_s=20.0,
    )
    print(f"[cluster] up: {[n.address for n in nodes]}, replication={REPLICATION}")
    engine = make_engine(cluster)

    wl = StagedWorkload(prompt_len=PROMPT, requests_per_stage=24,
                        stages=(0.7, 0.7), block_size=BLOCK, corpus_size=8, seed=0)

    # --- phase 1: warm the corpus through the engine, serve stage 0 -------
    warm_prompts = list(wl.warmup_prompts(8 * PROMPT))
    for p in warm_prompts:
        engine.submit(type("R", (), {"tokens": p, "rid": -1, "stage": -1})())
    engine.run()
    recs = []
    for r in wl.stage_requests(0):
        engine.submit(r)
    recs.extend(engine.run())
    engine.drain()  # settle write-behind: everything below counts as committed
    committed = {i: cluster.probe(p) for i, p in enumerate(warm_prompts)}
    print(f"[phase 1] served {len(recs)} requests over 3 nodes, "
          f"hit {hit(recs):.2f}; committed prefixes on cluster: "
          f"{sum(v // BLOCK for v in committed.values())} blocks")

    # --- phase 2: SIGKILL one node mid-workload ---------------------------
    victim = cluster.replicas_for(warm_prompts[0])[0]
    print(f"[phase 2] SIGKILL node {victim} ({nodes[victim].address}) ...")
    nodes[victim].kill()
    recs2 = []
    for r in wl.stage_requests(1):
        engine.submit(r)
    recs2.extend(engine.run())
    engine.drain()
    lost = sum(1 for i, p in enumerate(warm_prompts)
               if cluster.probe(p) < committed[i])
    cs = cluster.cluster_stats
    print(f"[phase 2] served {len(recs2)} requests degraded "
          f"(down={cluster.down_nodes}), hit {hit(recs2):.2f}; "
          f"failover reads: {cs.failovers}, degraded reads: {cs.degraded_reads}")
    print(f"[phase 2] lost committed blocks after kill: {lost}")
    assert lost == 0, "replication=2 must survive a single node kill"
    assert hit(recs2) >= 0.5, "degraded cluster must keep serving cached prefixes"

    # scraping a cluster with a dead member must return immediately with
    # the victim flagged unreachable — never hang on the corpse
    t0 = time.perf_counter()
    degraded = cluster.scrape_cluster()
    scrape_s = time.perf_counter() - t0
    assert degraded["nodes"][victim].get("unreachable"), \
        "dead node must be reported unreachable in the scrape"
    assert all(not degraded["nodes"][i].get("unreachable")
               for i in range(N_NODES) if i != victim)
    assert scrape_s < 5.0, f"scrape must not hang on a dead node ({scrape_s:.1f}s)"
    print(f"[phase 2] mid-outage scrape in {1e3 * scrape_s:.1f}ms: "
          f"node {victim} unreachable, "
          f"{len(degraded['live'])} live nodes still reporting")

    # --- phase 3: rejoin on the same address; ring rebalances -------------
    host, port = nodes[victim].address
    shutil.rmtree(nodes[victim].root, ignore_errors=True)  # cold restart
    nodes[victim] = spawn_local_node(f"{work}/node_{victim}", port=port,
                                     block_size=BLOCK, codec="raw", io_threads=2)
    revived = cluster.maintenance(0)["revived"]  # maintenance pings down nodes
    print(f"[phase 3] node {victim} rejoined on {nodes[victim].address}: "
          f"revived={revived}, live={cluster.live_nodes}")
    assert revived == [victim] and not cluster.down_nodes
    recs3 = []
    for r in wl.stage_requests(0):  # replay stage 0 against the healed ring
        engine.submit(r)
    recs3.extend(engine.run())
    engine.drain()
    still_lost = sum(1 for i, p in enumerate(warm_prompts)
                     if cluster.probe(p) < committed[i])
    print(f"[phase 3] healed cluster served {len(recs3)} requests, "
          f"hit {hit(recs3):.2f}; lost committed blocks: {still_lost} "
          f"(cold rejoined replica is backstopped by best-of-replica reads)")
    assert still_lost == 0

    # --- final STATS: one scrape of the healed cluster --------------------
    scrape = cluster.scrape_cluster()
    assert scrape["down"] == [], "healed cluster must scrape clean"
    cg = scrape["cluster"]["gauges"]
    print(f"[metrics] cluster: rpcs={cg.get('repro_rpc_rpcs', 0):.0f}, "
          f"chunks={cg.get('repro_rpc_stream_chunks', 0):.0f}, "
          f"failovers={cg.get('repro_cluster_failovers', 0):.0f}, "
          f"live={cg.get('repro_cluster_live', 0):.0f}/"
          f"{cg.get('repro_cluster_nodes', 0):.0f}")
    traced_total = 0
    for i, nd in sorted(scrape["nodes"].items()):
        m = nd["metrics"]
        g = m["gauges"]
        hreq = m["histograms"]["repro_node_request_seconds"]
        traced = m["counters"].get("repro_node_trace_requests_total", 0)
        traced_total += traced
        print(f"[metrics] node {i} ({nd['name']}): "
              f"requests={g['repro_server_requests']:.0f}, "
              f"get_blocks={g['repro_store_get_blocks'] + g.get('repro_store_raw_get_blocks', 0):.0f}, "
              f"put_blocks={g['repro_store_put_blocks']:.0f}, "
              f"disk={g.get('repro_node_disk_bytes', 0):.0f} B "
              f"in {g.get('repro_node_file_count', 0):.0f} files, "
              f"req p50/p99={1e3 * hreq['p50']:.2f}/{1e3 * hreq['p99']:.2f} ms, "
              f"traced={traced:.0f}")
        assert g["repro_server_requests"] > 0 and hreq["count"] > 0
    # the engine ran with tracing on: its trace ids crossed the wire and
    # were closed out server-side on the nodes
    assert traced_total > 0, "engine-issued traces must reach the nodes"
    cluster.close()
    for n in nodes:
        n.close()
    shutil.rmtree(work, ignore_errors=True)
    print("ok — zero committed blocks lost across kill and rejoin")


if __name__ == "__main__":
    main()
