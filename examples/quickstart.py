"""Quickstart: the SGLANG-LSM public API in 40 lines (paper Fig. 6).

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

from repro.core.store import KVBlockStore

db = KVBlockStore(tempfile.mkdtemp(prefix="quickstart_"), block_size=4)

# --- first request: "Who wrote Odyssey?" ---------------------------------
token_0 = [1, 11644, 5456, 6715, 952, 7759, 29973, 2]  # 8 tokens = 2 blocks
kvcache_0 = [np.random.randn(4, 64).astype(np.float16) for _ in range(2)]
db.put_batch(token_0, kvcache_0)
print(f"put_batch: stored {db.stats.put_blocks} blocks "
      f"({db.stats.compression_ratio:.2f}x compressed)")

# --- second request shares the 4-token prefix ----------------------------
token_1 = [1, 11644, 5456, 6715, 7904, 1026, 29973, 2]
reuse = db.probe(token_1)
print(f"probe: longest cached prefix = {reuse} tokens")
assert reuse == 4

reuse_kvcache = db.get_batch(token_1, reuse)
print(f"get_batch: loaded {len(reuse_kvcache)} block(s) of shape {reuse_kvcache[0].shape}")

# only the uncached suffix needs recomputation
recomp = token_1[reuse:]
print(f"recompute only {len(recomp)} tokens instead of {len(token_1)}")
kvcache_1 = [np.random.randn(4, 64).astype(np.float16)]
db.put_batch(token_1, kvcache_1, start_block=reuse // 4)

# --- background services (paper §3.3 / §3.4) ------------------------------
report = db.maintenance()
print(f"maintenance: {report}")
print(f"store: {db.file_count} files on disk, {db.disk_bytes} bytes, "
      f"controller mix {db.controller.mix()}")
db.close()
print("ok")
