"""Train a ~small LM for a few hundred steps with checkpoint/auto-resume
(deliverable b): kill it mid-run and re-run — it resumes from the newest
committed checkpoint and replays the exact trajectory.

    PYTHONPATH=src python examples/train_smoke.py [--steps 200]
"""

import argparse
import shutil
import tempfile

from repro.configs import get_config
from repro.training.loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--simulate-crash", action="store_true",
                    help="crash at 40% then auto-resume, asserting identical losses")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="train_smoke_")
    tcfg = TrainConfig(steps=args.steps, ckpt_every=max(10, args.steps // 10),
                       ckpt_dir=ckpt_dir, log_every=max(1, args.steps // 10))

    if args.simulate_crash:
        crash_at = int(args.steps * 0.4)
        print(f"[example] running to step {crash_at}, then crashing ...")
        r1 = train(cfg, tcfg, crash_after=crash_at)
        assert r1["crashed"]
        print(f"[example] crashed at {r1['step']}; restarting (auto-resume) ...")
        r2 = train(cfg, tcfg)
        print(f"[example] resumed from step {r2['resumed_from']}, finished at {r2['step']}")
    else:
        res = train(cfg, tcfg)
        print(f"[example] done: {res['step']} steps, "
              f"loss {res['losses'][0]:.3f} -> {res['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
