"""End-to-end serving driver (deliverable b): serve a REAL (reduced) model
with batched requests through the full stack —

    staged workload -> ServingEngine (two-stage pipeline on the runtime's
                       I/O executor; write-behind commits; off-path
                       maintenance)
                    -> CacheHierarchy (radix + tiers; plan/fetch/fulfill)
                    -> ShardedKVBlockStore (N independent LSM shards with
                       parallel fan-out, real disk; any StorageBackend
                       slots in here)
                    -> real prefill/decode on the smoke model

KV blocks written to / promoted from the disk tier are the model's actual
cache tensors; TTFT here is fully measured (real compute + real I/O), and
batch k+1's disk promotions run while batch k computes.

    PYTHONPATH=src python examples/serve_e2e.py
"""

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.hierarchy import CacheHierarchy
from repro.configs import get_config
from repro.core.sharded_store import ShardedKVBlockStore
from repro.models import api
from repro.runtime import RuntimeServices
from repro.serving import ComputeModel, ServingEngine
from repro.workload import StagedWorkload

ARCH = "qwen3-14b"
BLOCK = 16
PROMPT = 128
DECODE_TOKENS = 8
N_SHARDS = 4

cfg = get_config(ARCH, smoke=True)
params = api.init_params(cfg, jax.random.key(0))
prefill = jax.jit(api.prefill_fn(cfg), static_argnames=())
decode = jax.jit(api.decode_fn(cfg))

kv_per_tok_elems = cfg.n_layers * 2 * cfg.n_kv_heads * cfg.d_head


def real_prefill(tokens, reused):
    """Run the real model over the non-reused suffix; return (blocks, secs).
    Block i holds the bf16 KV slab for tokens [i*B, (i+1)*B)."""
    t0 = time.perf_counter()
    toks = jnp.asarray(tokens, jnp.int32)[None, :]
    cache = api.init_cache(cfg, 1, len(tokens))
    logits, cache = prefill(params, {"tokens": toks}, cache, 0)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    k, v = np.asarray(cache["k"], np.float32), np.asarray(cache["v"], np.float32)
    nb = len(tokens) // BLOCK
    start = reused // BLOCK
    blocks = []
    for i in range(start, nb):
        sl = slice(i * BLOCK, (i + 1) * BLOCK)
        blk = np.concatenate([k[:, 0, sl].reshape(BLOCK, -1, order="F"),
                              v[:, 0, sl].reshape(BLOCK, -1, order="F")], axis=1)
        blocks.append(blk.astype(np.float16))
    return blocks, dt


def main():
    runtime = RuntimeServices(io_threads=4)
    store = ShardedKVBlockStore(tempfile.mkdtemp(prefix="serve_e2e_"), n_shards=N_SHARDS,
                                block_size=BLOCK, io_executor=runtime.executor)
    h = CacheHierarchy(BLOCK, device_budget_blocks=64, host_budget_blocks=128, store=store)
    eng = ServingEngine(h, ComputeModel(cfg), kv_bytes_per_token=kv_per_tok_elems * 2,
                        max_batch_tokens=2048, real_prefill=real_prefill, runtime=runtime)

    wl = StagedWorkload(prompt_len=PROMPT, requests_per_stage=6,
                        stages=(0.0, 0.5, 0.75), block_size=BLOCK, corpus_size=8, seed=0)
    print(f"serving {ARCH} (reduced) — real prefill, real disk tier")
    # warmup: populate the corpus write-through (paper §4.1)
    for p in wl.warmup_prompts(len(wl.corpus) * PROMPT):
        eng.submit(type("R", (), {"tokens": p[:PROMPT], "rid": -1, "stage": -1})())
    eng.run()
    for si in range(len(wl.stages)):
        recs = []
        for r in wl.stage_requests(si):
            eng.submit(r)
        recs = eng.run()
        hit = np.mean([r.reused_tokens / r.prompt_len for r in recs])
        ttft = np.mean([r.ttft_s for r in recs])
        print(f"stage {si} (expect hit {wl.stages[si]:.2f}): hit {hit:.2f}, "
              f"TTFT {ttft*1e3:.1f}ms (io {np.mean([r.io_s for r in recs])*1e3:.1f}ms, "
              f"wait {np.mean([r.io_wait_s for r in recs])*1e3:.1f}ms)")
    eng.drain()  # settle write-behind + maintenance before the report

    # a short decode to show the serve path end-to-end
    toks = jnp.asarray(wl.corpus[0][:PROMPT], jnp.int32)[None, :]
    cache = api.init_cache(cfg, 1, PROMPT + DECODE_TOKENS)
    logits, cache = prefill(params, {"tokens": toks}, cache, 0)
    out = []
    last = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for i in range(DECODE_TOKENS):
        logits, cache = decode(params, last, cache, PROMPT + i)
        last = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
        out.append(int(last[0, 0]))
    print(f"decoded {DECODE_TOKENS} tokens: {out}")
    print(f"store: shards={store.n_shards} files/shard={store.shard_file_counts()} "
          f"bytes={store.disk_bytes} compression={store.stats.compression_ratio:.2f}x "
          f"hit-tiers d/h/d={h.stats.tokens_hit_device}/"
          f"{h.stats.tokens_hit_host}/{h.stats.tokens_hit_disk}")
    rep = eng.runtime_report()
    print(f"runtime: prefetched={rep['prefetched_requests']} "
          f"(ready on arrival {rep['prefetch_ready']}) overlap={rep['overlap_io_s']*1e3:.1f}ms "
          f"writeback_blocks={rep['writeback_blocks']} "
          f"maintenance_runs={rep['maintenance_runs']}")
    eng.close()
    store.close()
    print("ok")


if __name__ == "__main__":
    main()
