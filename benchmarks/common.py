"""Shared benchmark harness: backend construction, staged-workload runs,
result tables.  Scales the paper's setup to this container (single CPU
core, small disk) while keeping every *mechanism* real: real files, real
LSM compaction, real compression, measured I/O.  Compute time is modeled
(A30 target) per DESIGN.md §7 and reported separately from I/O.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.cache.hierarchy import CacheHierarchy
from repro.configs import get_config
from repro.core.baselines import FilePerObjectStore, MemoryOnlyStore
from repro.core.codec import CODEC_INT8, CODEC_RAW, BatchCodec
from repro.core.sharded_store import ShardedKVBlockStore
from repro.core.store import KVBlockStore
from repro.serving import ComputeModel, ServingEngine
from repro.workload import PAPER_STAGES, StagedWorkload

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


@dataclass
class BenchScale:
    """Container-scale defaults; --paper-scale multiplies everything up."""

    prompt_len: int = 1024
    requests_per_stage: int = 30
    stages: tuple = PAPER_STAGES
    corpus_size: int = 96
    kv_bytes_per_token: int = 1024
    block_size: int = 16
    warmup_tokens: int = 0  # 0 -> one pass over the corpus
    disk_budget_frac: float = 0.5  # of the raw corpus footprint
    mem_budget_frac: float = 0.06
    device_frac: float = 0.33  # of the memory budget


def _budgets(s: BenchScale):
    corpus_bytes = s.corpus_size * s.prompt_len * s.kv_bytes_per_token
    disk = int(corpus_bytes * s.disk_budget_frac)
    mem_blocks = max(
        8, int(corpus_bytes * s.mem_budget_frac) // (s.block_size * s.kv_bytes_per_token)
    )
    dev_blocks = max(4, int(mem_blocks * s.device_frac))
    host_blocks = mem_blocks - dev_blocks
    return disk, dev_blocks, host_blocks


def make_backend(root: str, kind: str, s: BenchScale, adaptive: bool = True):
    disk, _, _ = _budgets(s)
    if kind == "lsm":
        # controller window ~ one workload stage of ops so phase shifts are
        # visible to the drift detector (paper §3.3 sliding window)
        window = max(256, s.requests_per_stage * (s.prompt_len // s.block_size) // 2)
        store = KVBlockStore(
            os.path.join(root, "lsm"),
            block_size=s.block_size,
            codec=BatchCodec(CODEC_INT8, use_zlib=True),
            budget_bytes=disk,
            adaptive=adaptive,
            controller_window=window,
        )
        store.controller.min_ops_between_tunings = window // 4
        return store
    if kind == "lsm-sharded":
        window = max(256, s.requests_per_stage * (s.prompt_len // s.block_size) // 2)
        store = ShardedKVBlockStore(
            os.path.join(root, "lsm_sharded"),
            n_shards=4,
            block_size=s.block_size,
            codec=BatchCodec(CODEC_INT8, use_zlib=True),
            budget_bytes=disk,
            adaptive=adaptive,
            controller_window=window,
        )
        for shard in store.shards:
            # per-shard window was scaled down by 1/n_shards in the store
            shard.controller.min_ops_between_tunings = max(64, shard.controller.window // 4)
        return store
    if kind == "file":
        # file-per-object stores raw tensors (per-object compression defeats
        # batching — paper §3.4); same *physical* disk budget incl. fs slack
        return FilePerObjectStore(
            os.path.join(root, "file"),
            block_size=s.block_size,
            codec=BatchCodec(CODEC_RAW, use_zlib=False),
            budget_bytes=disk,
        )
    if kind == "memory":
        return None
    raise ValueError(kind)


def make_engine(root: str, kind: str, s: BenchScale, arch: str = "glm4-9b", adaptive=True):
    cfg = get_config(arch)
    store = make_backend(root, kind, s, adaptive)
    disk, dev_blocks, host_blocks = _budgets(s)
    h = CacheHierarchy(s.block_size, dev_blocks, host_blocks, store=store)
    return ServingEngine(
        h,
        ComputeModel(cfg),
        kv_bytes_per_token=s.kv_bytes_per_token,
        max_batch_tokens=8 * s.prompt_len,
    )


@dataclass
class StageResult:
    stage: int
    expected_hit: float
    hit_rate: float
    mean_ttft_s: float
    mean_io_s: float
    mean_compute_s: float


def run_staged(engine: ServingEngine, s: BenchScale, seed: int = 0) -> List[StageResult]:
    wl = StagedWorkload(
        prompt_len=s.prompt_len,
        requests_per_stage=s.requests_per_stage,
        stages=s.stages,
        block_size=s.block_size,
        corpus_size=s.corpus_size,
        seed=seed,
    )
    # ---- warmup: write-through population over the corpus (paper §4.1)
    warm = s.warmup_tokens or s.corpus_size * s.prompt_len
    for p in wl.warmup_prompts(warm):
        engine.submit(type("R", (), {"tokens": p, "rid": -1, "stage": -1})())
    engine.run()
    engine.stats.ttfts.clear()
    engine.stats.hits.clear()

    out: List[StageResult] = []
    for si in range(len(s.stages)):
        recs = []
        for r in wl.stage_requests(si):
            engine.submit(r)
        recs = engine.run()
        out.append(
            StageResult(
                stage=si,
                expected_hit=s.stages[si],
                hit_rate=float(np.mean([r.reused_tokens / r.prompt_len for r in recs])),
                mean_ttft_s=float(np.mean([r.ttft_s for r in recs])),
                mean_io_s=float(np.mean([r.io_s for r in recs])),
                mean_compute_s=float(np.mean([r.compute_s for r in recs])),
            )
        )
    return out


def summarize(results: Dict[str, List[StageResult]]) -> Dict:
    rows = {}
    for kind, stages in results.items():
        rows[kind] = {
            "hit_rate": float(np.mean([st.hit_rate for st in stages])),
            "ttft_s": float(np.mean([st.mean_ttft_s for st in stages])),
            "io_s": float(np.mean([st.mean_io_s for st in stages])),
            "per_stage": [st.__dict__ for st in stages],
        }
    return rows


def percentiles(values, qs=(50, 95, 99)) -> Dict[str, float]:
    """Shared latency-quantile convention for every benchmark: p50/p95/p99
    by numpy's linear interpolation.  One helper so runtime_bench and
    cluster_bench (and anything after them) report comparable tails
    instead of each hand-rolling its own ``np.percentile`` call."""
    vals = list(values)
    if not vals:
        return {f"p{q}": 0.0 for q in qs}
    arr = np.asarray(vals, dtype=float)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


def save_artifact(name: str, payload: Dict) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def fresh_dir(path: str) -> str:
    if os.path.exists(path):
        shutil.rmtree(path)
    os.makedirs(path)
    return path
