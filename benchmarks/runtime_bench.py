"""Runtime-layer benchmark: what the concurrent runtime buys end to end.

Two measurements:

  1. FAN-OUT: serial-loop vs parallel shard fan-out read throughput
     (delegates to ``store_scalability.io_thread_sweep``) — the §3.4 batch
     operations claim at the storage layer.

  2. ENGINE: serial vs pipelined ``ServingEngine`` on a *disk-hit-heavy*
     workload — tiny device/host budgets over a disk-resident corpus, so
     most reuse must be promoted from the LSM tier.  The serial engine
     pays promotion I/O inside TTFT; the pipelined engine prefetches batch
     k+1's promotions on the I/O executor while batch k is being served
     and routes commits through the write-behind queue, so TTFT pays only
     the non-overlapped remainder (``io_wait``).  Both engines serve the
     byte-identical request stream from an identically warmed store.

     Compute occupies real wall time (``simulate_compute_wall``: the
     modeled prefill duration is slept with the GIL released — the window
     a GPU deployment exposes while the accelerator is busy).  Disk I/O
     is fully real.  Without the wall window every resource is the same
     two container CPUs and overlap is arithmetically impossible — the
     measurement would say nothing about the runtime layer.

``run()`` writes the ``runtime`` artifact and returns the dict
``benchmarks/run.py`` serializes into ``BENCH_runtime.json`` (the repo's
perf trajectory record).
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile

import numpy as np

from repro.cache.hierarchy import CacheHierarchy
from repro.configs import get_config
from repro.core.codec import CODEC_INT8, BatchCodec
from repro.core.sharded_store import ShardedKVBlockStore
from repro.runtime import RuntimeServices
from repro.serving import ComputeModel, ServingEngine
from repro.workload import StagedWorkload

from . import common, store_scalability


def _disk_heavy_engine(root: str, io_threads: int, kv_bytes: int, block: int = 16,
                       tracing: bool = False):
    """Engine whose memory tiers are far smaller than the corpus: nearly
    every stage-hit must be promoted from disk."""
    cfg = get_config("glm4-9b")
    runtime = RuntimeServices(io_threads=io_threads) if io_threads > 0 else None
    store = ShardedKVBlockStore(
        os.path.join(root, "store"),
        n_shards=4,
        block_size=block,
        codec=BatchCodec(CODEC_INT8, use_zlib=True),
        io_executor=runtime.executor if runtime else None,
    )
    h = CacheHierarchy(block, device_budget_blocks=8, host_budget_blocks=8, store=store)
    eng = ServingEngine(
        h,
        ComputeModel(cfg),
        kv_bytes_per_token=kv_bytes,
        max_batch_tokens=4 * 1024,
        runtime=runtime,
        simulate_compute_wall=True,
        tracing=tracing,
    )
    return eng, store


def engine_compare(
    prompt_len: int = 512,
    requests_per_stage: int = 24,
    corpus_size: int = 8,
    kv_bytes: int = 4096,
    stages=(0.9, 0.9),
    trials: int = 3,
    verbose: bool = True,
):
    """Serial vs pipelined engine, best-of-``trials`` mean TTFT per mode
    (shared-container noise policy; the two modes replay identical
    streams)."""
    out = {}
    for mode, io_threads in (("serial", 0), ("pipelined", 4)):
        best = None
        for trial in range(trials):
            root = tempfile.mkdtemp(prefix=f"rtbench_{mode}_{trial}_")
            eng, store = _disk_heavy_engine(root, io_threads, kv_bytes)
            wl = StagedWorkload(
                prompt_len=prompt_len,
                requests_per_stage=requests_per_stage,
                stages=stages,
                block_size=16,
                corpus_size=corpus_size,
                seed=11,
            )
            # warm the corpus onto disk, then settle write-behind so both
            # modes measure against the same disk-resident state
            for p in wl.warmup_prompts(corpus_size * prompt_len):
                eng.submit(type("R", (), {"tokens": p, "rid": -1, "stage": -1})())
            eng.run()
            eng.drain()
            eng.stats.ttfts.clear()
            eng.stats.hits.clear()
            recs = []
            for si in range(len(stages)):
                for r in wl.stage_requests(si):
                    eng.submit(r)
                recs.extend(eng.run())
            eng.drain()
            ttfts = [r.ttft_s for r in recs]
            pct = common.percentiles(ttfts)
            rec = {
                "mode": mode,
                "io_threads": io_threads,
                "requests": len(recs),
                "hit_rate": float(np.mean([r.reused_tokens / r.prompt_len for r in recs])),
                "mean_ttft_s": float(np.mean(ttfts)),
                "ttft_percentiles": pct,
                "p99_ttft_s": pct["p99"],
                "mean_io_s": float(np.mean([r.io_s for r in recs])),
                "mean_io_wait_s": float(np.mean([r.io_wait_s for r in recs])),
                "report": eng.runtime_report(),
            }
            eng.close()
            store.close()
            if best is None or rec["mean_ttft_s"] < best["mean_ttft_s"]:
                best = rec
        out[mode] = best
        if verbose:
            r = out[mode]
            print(f"{mode:9s} hit={r['hit_rate']:.2f} TTFT {r['mean_ttft_s']*1e3:7.2f}ms "
                  f"(io {r['mean_io_s']*1e3:6.2f}ms, wait {r['mean_io_wait_s']*1e3:6.2f}ms)")
    s, p = out["serial"], out["pipelined"]
    out["ttft_improvement"] = 1.0 - p["mean_ttft_s"] / max(1e-12, s["mean_ttft_s"])
    out["overlap_io_s"] = p["report"]["overlap_io_s"]
    if verbose:
        print(f"pipelined TTFT vs serial: {-100 * out['ttft_improvement']:+.1f}%  "
              f"(overlapped I/O {out['overlap_io_s']:.2f}s)")
    return out


def tracing_overhead(
    trials: int = 3,
    prompt_len: int = 512,
    requests_per_stage: int = 12,
    corpus_size: int = 8,
    kv_bytes: int = 4096,
    stages=(0.9,),
    threshold_pct: float = 5.0,
    verbose: bool = True,
):
    """What request tracing costs on the serving hot path: the same
    pipelined engine + byte-identical workload run back-to-back with
    ``tracing=False`` and ``tracing=True``, paired per trial.  The
    reported overhead is the *minimum* paired TTFT ratio across trials —
    the shared-container noise policy: the least-perturbed pair is the
    tightest upper bound on the true cost.  The methodology is written
    up in docs/OBSERVABILITY.md; the >``threshold_pct`` failure keeps the
    "tracing is cheap enough to leave on" claim honest in CI."""
    pairs = []
    for trial in range(trials):
        times = {}
        for label, tracing in (("off", False), ("on", True)):
            root = tempfile.mkdtemp(prefix=f"rtobs_{label}_{trial}_")
            eng, store = _disk_heavy_engine(root, 4, kv_bytes, tracing=tracing)
            wl = StagedWorkload(
                prompt_len=prompt_len,
                requests_per_stage=requests_per_stage,
                stages=stages,
                block_size=16,
                corpus_size=corpus_size,
                seed=11,
            )
            for p in wl.warmup_prompts(corpus_size * prompt_len):
                eng.submit(type("R", (), {"tokens": p, "rid": -1, "stage": -1})())
            eng.run()
            eng.drain()
            eng.stats.ttfts.clear()
            eng.stats.hits.clear()
            recs = []
            for si in range(len(stages)):
                for r in wl.stage_requests(si):
                    eng.submit(r)
                recs.extend(eng.run())
            eng.drain()
            times[label] = float(np.mean([r.ttft_s for r in recs]))
            eng.close()
            store.close()
        pairs.append(times)
    ratios = [t["on"] / max(1e-12, t["off"]) for t in pairs]
    min_ratio = min(ratios)
    overhead_pct = 100.0 * (min_ratio - 1.0)
    ok = overhead_pct <= threshold_pct
    out = {
        "pairs": pairs,
        "ratios": ratios,
        "min_ratio": min_ratio,
        "overhead_pct": overhead_pct,
        "threshold_pct": threshold_pct,
        "pass": ok,
    }
    if verbose:
        print(f"tracing overhead: {overhead_pct:+.2f}% TTFT "
              f"(min paired ratio over {trials} trials; "
              f"threshold {threshold_pct:.0f}%) -> {'PASS' if ok else 'FAIL'}")
    return out


def run(quick: bool = False, verbose: bool = True):
    fanout = store_scalability.io_thread_sweep(
        io_threads=(1, 4) if quick else (1, 2, 4, 8),
        n_seqs=16 if quick else 32,
        repeats=3 if quick else 5,
        verbose=verbose,
    )
    engine = engine_compare(
        requests_per_stage=12 if quick else 24,
        trials=2 if quick else 3,
        verbose=verbose,
    )
    tracing = tracing_overhead(
        trials=2 if quick else 3,
        requests_per_stage=8 if quick else 12,
        verbose=verbose,
    )
    out = {"fanout": fanout, "engine": engine, "tracing": tracing}
    common.save_artifact("runtime", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    out = run(quick=args.quick)
    if not out["tracing"]["pass"]:
        print("FAIL: tracing hot-path overhead exceeds "
              f"{out['tracing']['threshold_pct']:.0f}% "
              f"({out['tracing']['overhead_pct']:+.2f}%)")
        return 1
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
