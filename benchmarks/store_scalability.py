"""Paper §4.2 text: the file-per-object backend hits a filesystem wall
(severe metadata overhead, write anomalies ~7M files); SGLANG-LSM bounds
file counts.

Three measurements:
  1. REAL: per-operation latency + file count + physical footprint as both
     backends ingest the same KV stream (container scale: up to ~50k
     objects — enough to show the latency/footprint curves diverging).
  2. MODELED: extrapolation of the measured per-file overhead curve to the
     paper's 7M-file regime (methodology per DESIGN.md §7 — creating 7M
     real files is out of budget for this container).
  3. SHARD SWEEP (``--shards 1 2 4 8``): the same ingest stream through a
     monolithic ``KVBlockStore`` (1 shard) and ``ShardedKVBlockStore`` at
     increasing shard counts, reporting aggregate ingest/read throughput,
     LSM write amplification, and per-shard file counts — the scaling axis
     the ROADMAP's "production-scale traffic" target rests on.
  4. I/O-THREAD SWEEP (``--io-threads 1 2 4 8``): the same read stream
     through a 4-shard store, comparing the serial per-sequence loop
     against parallel shard fan-out (``probe_many``/``get_many`` on the
     runtime's ``IOExecutor``) at increasing thread counts — the axis PR 4
     adds on top of sharding (locality -> throughput).
"""

from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

from repro.core.baselines import FilePerObjectStore, fs_footprint
from repro.core.codec import CODEC_INT8, CODEC_RAW, BatchCodec
from repro.core.sharded_store import ShardedKVBlockStore
from repro.core.store import KVBlockStore
from repro.runtime import IOExecutor

from . import common


def ingest(store, n_batches: int, blocks_per_batch: int, block_tokens=16, kv_bytes=1024, seed=0):
    rng = np.random.default_rng(seed)
    lat = []
    template = rng.standard_normal((block_tokens, kv_bytes // 2)).astype(np.float16)
    for b in range(n_batches):
        tokens = rng.integers(0, 50000, size=blocks_per_batch * block_tokens).tolist()
        t0 = time.perf_counter()
        store.put_batch(tokens, [template] * blocks_per_batch)
        lat.append(time.perf_counter() - t0)
        if b % 16 == 0:
            store.maintenance()
    return lat


def run(n_batches: int = 60, blocks_per_batch: int = 64, verbose=True):
    out = {}
    for kind in ("lsm", "file"):
        root = tempfile.mkdtemp(prefix=f"scal_{kind}_")
        if kind == "lsm":
            store = KVBlockStore(os.path.join(root, "s"), block_size=16,
                                 codec=BatchCodec(CODEC_INT8, use_zlib=True))
        else:
            store = FilePerObjectStore(os.path.join(root, "s"), block_size=16,
                                       codec=BatchCodec(CODEC_RAW, use_zlib=False))
        lat = ingest(store, n_batches, blocks_per_batch)
        half = len(lat) // 2
        out[kind] = {
            "objects": n_batches * blocks_per_batch,
            "files": store.file_count,
            "disk_bytes": store.disk_bytes,
            "put_ms_first_half": 1e3 * float(np.mean(lat[:half])),
            "put_ms_second_half": 1e3 * float(np.mean(lat[half:])),
        }
        store.close()
    # modeled extrapolation to the paper's regime
    fl = out["file"]
    per_file_overhead = fs_footprint(16 * 1024) - 16 * 1024  # slack + inode per 16KB object
    out["extrapolation_7M_files"] = {
        "file_backend_metadata_bytes": 7_000_000 * per_file_overhead,
        "lsm_files_at_same_objects": int(out["lsm"]["files"] * 7_000_000 / max(1, fl["files"]) ** 0),
        "note": "LSM file count stays O(levels + log segments) regardless of object count; "
                "file backend metadata grows linearly and degrades (paper: write anomalies at ~7M)",
    }
    if verbose:
        for kind in ("lsm", "file"):
            r = out[kind]
            print(f"{kind:5s} objects={r['objects']:7d} files={r['files']:7d} "
                  f"disk={r['disk_bytes']/1e6:8.1f}MB put {r['put_ms_first_half']:.1f}->"
                  f"{r['put_ms_second_half']:.1f} ms/batch")
        print(f"LSM file-count advantage: {out['file']['files'] / max(1, out['lsm']['files']):.0f}x fewer files")
    common.save_artifact("store_scalability", out)
    return out


# ------------------------------------------------------------- shard sweep
def _mk_sharded(root: str, n_shards: int, block_tokens: int, buffer_bytes: int, **kw):
    # zlib off: the sweep isolates storage-engine scalability (memtable,
    # flush, compaction, log append); codec CPU is backend-invariant noise
    codec = BatchCodec(CODEC_INT8, use_zlib=False)
    if n_shards == 1:  # the monolithic baseline, not a 1-shard wrapper
        return KVBlockStore(os.path.join(root, "s"), block_size=block_tokens,
                            codec=codec, buffer_bytes=buffer_bytes, **kw)
    return ShardedKVBlockStore(os.path.join(root, "s"), n_shards=n_shards,
                               block_size=block_tokens, codec=codec,
                               buffer_bytes=buffer_bytes, **kw)


def shard_sweep(
    shard_counts=(1, 2, 4, 8),
    n_batches: int = 128,
    blocks_per_batch: int = 32,
    block_tokens: int = 16,
    kv_bytes: int = 256,
    buffer_bytes: int = 128 * 1024,
    maintenance_every: int = 4,
    repeats: int = 3,
    verbose=True,
):
    """Same ingest stream through every shard count.  The stream is
    pre-generated (byte-identical traffic per configuration); batches have
    independent first blocks, so hash routing spreads them across shards.
    Defaults put the engine under flush/compaction pressure (small buffer,
    small payloads) — the regime where per-shard memtables, controllers,
    and compaction trees pay off.

    Configurations are interleaved across ``repeats`` rounds and the
    best-of throughput is reported (standard microbenchmark practice:
    max-throughput filters scheduler/IO noise, which on a shared container
    can swing single runs severalfold)."""
    rng = np.random.default_rng(0)
    template = rng.standard_normal((block_tokens, kv_bytes // 2)).astype(np.float16)
    stream = [
        rng.integers(0, 50000, size=blocks_per_batch * block_tokens).tolist()
        for _ in range(n_batches)
    ]
    total_blocks = n_batches * blocks_per_batch
    out = {}
    for rep in range(repeats):
        for n in shard_counts:
            root = tempfile.mkdtemp(prefix=f"scal_shards{n}_r{rep}_")
            store = _mk_sharded(root, n, block_tokens, buffer_bytes)
            t0 = time.perf_counter()
            for b, tokens in enumerate(stream):
                store.put_batch(tokens, [template] * blocks_per_batch)
                if (b + 1) % maintenance_every == 0:
                    store.maintenance()
            store.flush()
            ingest_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            hit_blocks = 0
            for tokens in stream:
                got = store.get_batch(tokens, store.probe(tokens))
                hit_blocks += len(got)
            read_s = time.perf_counter() - t0
            per_shard_files = (
                store.shard_file_counts() if isinstance(store, ShardedKVBlockStore) else [store.file_count]
            )
            rec = {
                "shards": n,
                "ingest_blocks_per_s": total_blocks / ingest_s,
                "read_blocks_per_s": hit_blocks / max(1e-9, read_s),
                "write_amplification": store.write_amplification,
                "files_per_shard": per_shard_files,
                "files_total": store.file_count,
                "disk_bytes": store.disk_bytes,
            }
            store.close()
            best = out.get(n)
            if best is None or rec["ingest_blocks_per_s"] > best["ingest_blocks_per_s"]:
                out[n] = rec
    for n in shard_counts:
        if verbose:
            r = out[n]
            print(f"shards={n} ingest {r['ingest_blocks_per_s']:8.0f} blk/s  "
                  f"read {r['read_blocks_per_s']:8.0f} blk/s  "
                  f"WA {r['write_amplification']:.2f}  files/shard {r['files_per_shard']}")
    if verbose and 1 in out and 4 in out:
        speedup = out[4]["ingest_blocks_per_s"] / out[1]["ingest_blocks_per_s"]
        print(f"4-shard vs monolithic ingest: {speedup:.2f}x")
    common.save_artifact("store_scalability_shards", out)
    return out


# -------------------------------------------------------- io-thread sweep
def io_thread_sweep(
    io_threads=(1, 2, 4, 8),
    n_shards: int = 4,
    n_seqs: int = 48,
    blocks_per_seq: int = 6,
    block_tokens: int = 16,
    kv_bytes: int = 32768,
    repeats: int = 10,
    verbose=True,
):
    """Serial-loop vs parallel-fan-out ``get_batch`` throughput on one
    4-shard store.  The store is populated and probed once; each
    configuration then replays the identical get stream, so the only
    variable is dispatch — a per-sequence loop vs ``get_many`` shard
    groups on an ``IOExecutor``.  Configurations are interleaved across
    ``repeats`` rounds and best-of is reported (the shard sweep's policy:
    max-throughput filters scheduler/IO noise on a shared container, and
    interleaving ensures every configuration sees the same machine).
    Payloads are codec-realistic (int8+zlib): decompression and
    dequantization release the GIL — exactly the work the fan-out threads
    overlap.  The executor caps workers at host cores (see ``IOExecutor``);
    both requested and actual widths are reported."""
    rng = np.random.default_rng(0)
    template = rng.standard_normal((block_tokens, kv_bytes // 2)).astype(np.float16)
    seqs = [
        rng.integers(0, 50000, size=block_tokens * blocks_per_seq).tolist()
        for _ in range(n_seqs)
    ]
    total_blocks = n_seqs * blocks_per_seq
    root = tempfile.mkdtemp(prefix="scal_iothreads_")
    store = ShardedKVBlockStore(os.path.join(root, "s"), n_shards=n_shards,
                                block_size=block_tokens,
                                codec=BatchCodec(CODEC_INT8, use_zlib=True))
    for tokens in seqs:
        store.put_batch(tokens, [template] * blocks_per_seq)
    store.flush()
    items = list(zip(seqs, store.probe_many(seqs)))

    def serial_loop() -> float:
        t0 = time.perf_counter()
        n = sum(len(store.get_batch(t, p)) for t, p in items)
        assert n == total_blocks
        return n / (time.perf_counter() - t0)

    def fan_out() -> float:
        t0 = time.perf_counter()
        n = sum(len(g) for g in store.get_many(items))
        assert n == total_blocks
        return n / (time.perf_counter() - t0)

    executors = {nt: IOExecutor(max_workers=nt) for nt in io_threads}
    rounds = []  # per-round {config: blocks_per_s}, measured back to back
    configs = ["serial"] + list(io_threads)
    for rep in range(repeats):
        # rotate measurement order each round: a fixed order aliases slow
        # container phases (cache/cpu contention) onto fixed configurations
        order = configs[rep % len(configs):] + configs[: rep % len(configs)]
        row = {}
        for cfg in order:
            if cfg == "serial":
                row["serial"] = serial_loop()
            else:
                store.set_io_executor(executors[cfg])
                row[cfg] = fan_out()
        rounds.append(row)
    store.set_io_executor(None)
    # Speedup from *paired* samples: container load drifts on a minutes
    # scale, so a configuration's throughput is only comparable to the
    # serial loop measured seconds away in the same round.  Best paired
    # ratio = the speedup the fan-out demonstrates under matched machine
    # conditions; absolute best-of throughputs are reported alongside.
    best_serial = max(r["serial"] for r in rounds)
    out = {
        "n_shards": n_shards,
        "n_seqs": n_seqs,
        "blocks_per_seq": blocks_per_seq,
        "kv_bytes": kv_bytes,
        "cpu_count": os.cpu_count(),
        "repeats": repeats,
        "serial_loop_blocks_per_s": best_serial,
        "threads": {},
    }
    for nt in io_threads:
        best = max(r[nt] for r in rounds)
        paired = max(r[nt] / r["serial"] for r in rounds)
        out["threads"][nt] = {
            "fanout_blocks_per_s": best,
            "speedup_vs_serial_loop": paired,
            "workers": executors[nt].max_workers,
        }
        if verbose:
            print(f"io-threads={nt} (workers={executors[nt].max_workers}): "
                  f"fan-out {best:8.0f} blk/s  "
                  f"({paired:.2f}x paired serial loop; serial best {best_serial:.0f} blk/s)")
        executors[nt].close()
    store.close()
    common.save_artifact("store_scalability_io_threads", out)
    return out


# ----------------------------------------------------------- codec sweep
def codec_sweep(
    n_seqs: int = 64,
    blocks_per_seq: int = 8,
    block_tokens: int = 16,
    kv_bytes: int = 1024,
    repeats: int = 3,
    verbose=True,
):
    """Single-store codec-policy comparison: the same ingest+read stream
    through raw, int8, int8+zlib, and the adaptive ``tiered`` policy
    (hot puts raw; ``maintenance()`` demotes sealed files down-tier —
    ``core.tiering``).  Reports ingest/read throughput and the on-disk
    footprint; for ``tiered``, the footprint before and after demotion
    settles plus the demoted-block count.  Configurations are
    interleaved across ``repeats`` rounds, best-of reported (the shard
    sweep's noise policy).  The closing gate is the tentpole's hot-path
    claim: the tiered policy's ingest throughput must track raw's."""
    from repro.core.tiering import TieringPolicy

    rng = np.random.default_rng(3)
    feat = kv_bytes // 4
    seqs, payloads = [], []
    for _ in range(n_seqs):
        seqs.append(rng.integers(0, 50000,
                                 size=blocks_per_seq * block_tokens).tolist())
        scale = rng.uniform(0.5, 2.0)
        payloads.append([
            (scale * rng.standard_normal((block_tokens, feat))).astype(np.float32)
            for _ in range(blocks_per_seq)
        ])
    total_blocks = n_seqs * blocks_per_seq
    variants = {
        "raw": lambda: dict(codec=BatchCodec(CODEC_RAW, use_zlib=False)),
        "int8": lambda: dict(codec=BatchCodec(CODEC_INT8, use_zlib=False)),
        "int8-zlib": lambda: dict(codec=BatchCodec(CODEC_INT8, use_zlib=True)),
        "tiered": lambda: dict(
            tiering=TieringPolicy(warm_after_s=0.0, cold_after_s=0.0)),
    }
    out = {}
    for rep in range(repeats):
        for name, kw in variants.items():
            root = tempfile.mkdtemp(prefix=f"scal_codec_{name}_r{rep}_")
            store = KVBlockStore(os.path.join(root, "s"),
                                 block_size=block_tokens,
                                 vlog_file_bytes=256 * 1024, **kw())
            t0 = time.perf_counter()
            for tokens, blocks in zip(seqs, payloads):
                store.put_batch(tokens, blocks)
            store.flush()
            ingest_s = time.perf_counter() - t0
            footprint_hot = store.disk_bytes
            demoted = 0
            for _ in range(12):  # let the tier recoder settle
                d = int(((store.maintenance().get("tiering") or {})
                         .get("demoted_blocks", 0)) or 0)
                demoted += d
                if d == 0:
                    break
            t0 = time.perf_counter()
            hit = sum(len(store.get_batch(t, store.probe(t))) for t in seqs)
            read_s = time.perf_counter() - t0
            rec = {
                "ingest_blocks_per_s": total_blocks / ingest_s,
                "read_blocks_per_s": hit / max(1e-9, read_s),
                "disk_bytes": store.disk_bytes,
                "disk_bytes_before_demotion": footprint_hot,
                "demoted_blocks": demoted,
                "served_blocks": hit,
            }
            store.close()
            best = out.get(name)
            if best is None or rec["ingest_blocks_per_s"] > best["ingest_blocks_per_s"]:
                out[name] = rec
    raw = out["raw"]
    for name, rec in out.items():
        rec["footprint_vs_raw"] = rec["disk_bytes"] / max(1, raw["disk_bytes"])
    out["tiered"]["put_regression_pct"] = 100.0 * (
        1.0 - out["tiered"]["ingest_blocks_per_s"] / raw["ingest_blocks_per_s"])
    if verbose:
        for name, rec in out.items():
            print(f"codec={name:9s} ingest {rec['ingest_blocks_per_s']:8.0f} blk/s  "
                  f"read {rec['read_blocks_per_s']:8.0f} blk/s  "
                  f"disk {rec['disk_bytes']/1e6:6.1f}MB "
                  f"({rec['footprint_vs_raw']:.2f}x raw)")
        print(f"tiered demotion: {out['tiered']['demoted_blocks']} blocks, "
              f"{out['tiered']['disk_bytes_before_demotion']/1e6:.1f}MB -> "
              f"{out['tiered']['disk_bytes']/1e6:.1f}MB; "
              f"put regression vs raw {out['tiered']['put_regression_pct']:+.1f}%")
    common.save_artifact("store_scalability_codecs", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shards", type=int, nargs="*", default=None,
                    help="shard counts to sweep (e.g. --shards 1 2 4 8); "
                         "omit to run the backend comparison only")
    ap.add_argument("--io-threads", type=int, nargs="*", default=None,
                    help="I/O thread counts for the parallel fan-out sweep "
                         "(e.g. --io-threads 1 2 4 8)")
    ap.add_argument("--n-batches", type=int, default=60)
    ap.add_argument("--blocks-per-batch", type=int, default=64)
    ap.add_argument("--skip-backends", action="store_true",
                    help="skip the lsm-vs-file comparison")
    ap.add_argument("--codecs", action="store_true",
                    help="run the codec-policy sweep (raw / int8 / "
                         "int8-zlib / tiered)")
    args = ap.parse_args(argv)
    if not args.skip_backends:
        run(n_batches=args.n_batches, blocks_per_batch=args.blocks_per_batch)
    if args.shards:
        shard_sweep(shard_counts=tuple(args.shards))
    if args.io_threads:
        io_thread_sweep(io_threads=tuple(args.io_threads))
    if args.codecs:
        codec_sweep()


if __name__ == "__main__":
    main()
