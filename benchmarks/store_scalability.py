"""Paper §4.2 text: the file-per-object backend hits a filesystem wall
(severe metadata overhead, write anomalies ~7M files); SGLANG-LSM bounds
file counts.

Two measurements:
  1. REAL: per-operation latency + file count + physical footprint as both
     backends ingest the same KV stream (container scale: up to ~50k
     objects — enough to show the latency/footprint curves diverging).
  2. MODELED: extrapolation of the measured per-file overhead curve to the
     paper's 7M-file regime (methodology per DESIGN.md §7 — creating 7M
     real files is out of budget for this container).
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.baselines import FilePerObjectStore, fs_footprint
from repro.core.codec import CODEC_INT8, CODEC_RAW, BatchCodec
from repro.core.store import KVBlockStore

from . import common


def ingest(store, n_batches: int, blocks_per_batch: int, block_tokens=16, kv_bytes=1024, seed=0):
    rng = np.random.default_rng(seed)
    lat = []
    template = rng.standard_normal((block_tokens, kv_bytes // 2)).astype(np.float16)
    for b in range(n_batches):
        tokens = rng.integers(0, 50000, size=blocks_per_batch * block_tokens).tolist()
        t0 = time.perf_counter()
        store.put_batch(tokens, [template] * blocks_per_batch)
        lat.append(time.perf_counter() - t0)
        if b % 16 == 0:
            store.maintenance()
    return lat


def run(n_batches: int = 60, blocks_per_batch: int = 64, verbose=True):
    out = {}
    for kind in ("lsm", "file"):
        root = tempfile.mkdtemp(prefix=f"scal_{kind}_")
        if kind == "lsm":
            store = KVBlockStore(os.path.join(root, "s"), block_size=16,
                                 codec=BatchCodec(CODEC_INT8, use_zlib=True))
        else:
            store = FilePerObjectStore(os.path.join(root, "s"), block_size=16,
                                       codec=BatchCodec(CODEC_RAW, use_zlib=False))
        lat = ingest(store, n_batches, blocks_per_batch)
        half = len(lat) // 2
        out[kind] = {
            "objects": n_batches * blocks_per_batch,
            "files": store.file_count,
            "disk_bytes": store.disk_bytes,
            "put_ms_first_half": 1e3 * float(np.mean(lat[:half])),
            "put_ms_second_half": 1e3 * float(np.mean(lat[half:])),
        }
        store.close()
    # modeled extrapolation to the paper's regime
    fl = out["file"]
    per_file_overhead = fs_footprint(16 * 1024) - 16 * 1024  # slack + inode per 16KB object
    out["extrapolation_7M_files"] = {
        "file_backend_metadata_bytes": 7_000_000 * per_file_overhead,
        "lsm_files_at_same_objects": int(out["lsm"]["files"] * 7_000_000 / max(1, fl["files"]) ** 0),
        "note": "LSM file count stays O(levels + log segments) regardless of object count; "
                "file backend metadata grows linearly and degrades (paper: write anomalies at ~7M)",
    }
    if verbose:
        for kind in ("lsm", "file"):
            r = out[kind]
            print(f"{kind:5s} objects={r['objects']:7d} files={r['files']:7d} "
                  f"disk={r['disk_bytes']/1e6:8.1f}MB put {r['put_ms_first_half']:.1f}->"
                  f"{r['put_ms_second_half']:.1f} ms/batch")
        print(f"LSM file-count advantage: {out['file']['files'] / max(1, out['lsm']['files']):.0f}x fewer files")
    common.save_artifact("store_scalability", out)
    return out


if __name__ == "__main__":
    run()
