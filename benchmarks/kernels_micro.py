"""Kernel microbenchmarks.

This container has no TPU, so Pallas kernels are validated in interpret
mode (correctness vs ref.py — also covered by tests/) and their *TPU*
performance is reported as roofline terms: bytes moved at HBM per the
BlockSpec tiling vs the XLA-lowered oracle's HBM traffic (from hlocost on
the compiled oracle).  This quantifies exactly what each kernel buys.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlocost import analyze_text
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

from . import common


def _oracle_traffic(fn, *avals) -> float:
    text = jax.jit(fn).lower(*avals).compile().as_text()
    return analyze_text(text).bytes


def flash_attention_case(B=4, S=2048, H=16, KVH=4, D=128):
    from repro.kernels.flash_attention.ref import attention_ref

    q = jax.ShapeDtypeStruct((B, S, H, D), jnp.bfloat16)
    kv = jax.ShapeDtypeStruct((B, S, KVH, D), jnp.bfloat16)
    oracle_bytes = _oracle_traffic(lambda q, k, v: attention_ref(q, k, v, causal=True), q, kv, kv)
    # kernel HBM traffic: Q, K, V in + O out (scores live in VMEM scratch)
    kernel_bytes = (B * S * H * D + 2 * B * S * KVH * D + B * S * H * D) * 2
    flops = 4.0 * B * H * D * S * (S + 1) / 2
    return {
        "oracle_hbm_bytes": oracle_bytes,
        "kernel_hbm_bytes": kernel_bytes,
        "traffic_reduction": oracle_bytes / kernel_bytes,
        "kernel_mem_s": kernel_bytes / HBM_BW,
        "compute_s": flops / PEAK_FLOPS_BF16,
        "bound": "compute" if flops / PEAK_FLOPS_BF16 > kernel_bytes / HBM_BW else "memory",
    }


def rwkv6_case(B=8, H=32, S=4096, N=64):
    from repro.kernels.rwkv6.ref import wkv_ref

    r = jax.ShapeDtypeStruct((B, H, S, N), jnp.float32)
    u = jax.ShapeDtypeStruct((H, N), jnp.float32)
    st = jax.ShapeDtypeStruct((B, H, N, N), jnp.float32)
    oracle_bytes = _oracle_traffic(wkv_ref, r, r, r, r, u, st)
    # kernel: r/k/v/w in + y out + state in/out once (stays in VMEM across chunks)
    kernel_bytes = (4 * B * H * S * N + B * H * S * N + 2 * B * H * N * N) * 4
    return {
        "oracle_hbm_bytes": oracle_bytes,
        "kernel_hbm_bytes": kernel_bytes,
        "traffic_reduction": oracle_bytes / kernel_bytes,
        "kernel_mem_s": kernel_bytes / HBM_BW,
    }


def kv_codec_case(T=256, C=8192):
    from repro.kernels.kv_codec.ref import quantize_ref

    x = jax.ShapeDtypeStruct((T, C), jnp.bfloat16)
    oracle_bytes = _oracle_traffic(quantize_ref, x)
    kernel_bytes = T * C * 2 + T * C * 1 + C * 4  # in bf16 + out int8 + scales
    return {
        "oracle_hbm_bytes": oracle_bytes,
        "kernel_hbm_bytes": kernel_bytes,
        "traffic_reduction": oracle_bytes / kernel_bytes,
    }


def mamba2_case(B=8, S=4096, H=32, P=64, N=64):
    from repro.kernels.mamba2.ref import ssd_ref

    x = jax.ShapeDtypeStruct((B, S, H, P), jnp.float32)
    bc = jax.ShapeDtypeStruct((B, S, N), jnp.float32)
    ad = jax.ShapeDtypeStruct((B, S, H), jnp.float32)
    st = jax.ShapeDtypeStruct((B, H, P, N), jnp.float32)
    oracle_bytes = _oracle_traffic(ssd_ref, x, bc, bc, ad, ad, st)
    # kernel: x/B/C/a/dt in + y out + state once (VMEM-resident across chunks)
    kernel_bytes = (2 * B * S * H * P + 2 * B * S * N + 2 * B * S * H + 2 * B * H * P * N) * 4
    return {
        "oracle_hbm_bytes": oracle_bytes,
        "kernel_hbm_bytes": kernel_bytes,
        "traffic_reduction": oracle_bytes / kernel_bytes,
        "kernel_mem_s": kernel_bytes / HBM_BW,
    }


def paged_decode_case(B=64, H=32, KVH=8, D=128, page=64, NB=512):
    from repro.kernels.decode_attention.ref import paged_decode_ref

    P = B * NB
    q = jax.ShapeDtypeStruct((B, H, D), jnp.bfloat16)
    pages = jax.ShapeDtypeStruct((P, page, KVH, D), jnp.bfloat16)
    tb = jax.ShapeDtypeStruct((B, NB), jnp.int32)
    ln = jax.ShapeDtypeStruct((B,), jnp.int32)
    oracle_bytes = _oracle_traffic(paged_decode_ref, q, pages, pages, tb, ln)
    # kernel reads each mapped page once; oracle gathers pages into a dense
    # copy first (2x the KV traffic) and round-trips f32 scores
    kernel_bytes = (B * H * D + 2 * B * NB * page * KVH * D + B * H * D) * 2
    return {
        "oracle_hbm_bytes": oracle_bytes,
        "kernel_hbm_bytes": kernel_bytes,
        "traffic_reduction": oracle_bytes / kernel_bytes,
        "kernel_mem_s": kernel_bytes / HBM_BW,
    }


def run(verbose=True):
    out = {
        "flash_attention": flash_attention_case(),
        "rwkv6_wkv": rwkv6_case(),
        "mamba2_ssd": mamba2_case(),
        "kv_codec": kv_codec_case(),
        "paged_decode": paged_decode_case(),
    }
    if verbose:
        for name, r in out.items():
            print(f"{name:16s} oracle {r['oracle_hbm_bytes']/1e9:8.2f}GB -> kernel "
                  f"{r['kernel_hbm_bytes']/1e9:8.2f}GB  ({r['traffic_reduction']:.1f}x less HBM traffic)")
    common.save_artifact("kernels_micro", out)
    return out


if __name__ == "__main__":
    run()
