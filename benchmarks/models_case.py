"""Paper Figure 5(a,b): per-model case study — TTFT improvement shrinks as
KV bytes/token grow (cache reuse saves less relative to recompute).

We sweep three of the assigned architectures with small/medium/large
KV-per-token footprints (the paper used GLM-4-8B 40KB / GLM-4-32B 60KB /
Llama-3-8B 120KB)."""

from __future__ import annotations

import dataclasses
import tempfile

from repro.configs import get_config

from . import common

# (arch, kv bytes/token scaled 1/64 to container scale)
CASES = ("glm4-9b", "qwen3-14b", "qwen2.5-32b")


def run(scale: common.BenchScale = None, verbose=True):
    out = {}
    for arch in CASES:
        cfg = get_config(arch)
        kv_bpt = max(256, cfg.kv_bytes_per_token // 64)  # container scale
        s = dataclasses.replace(
            scale or common.BenchScale(), kv_bytes_per_token=kv_bpt, prompt_len=512
        )
        results = {}
        for kind in ("lsm", "file"):
            root = common.fresh_dir(tempfile.mkdtemp(prefix=f"case_{arch}_{kind}_"))
            eng = common.make_engine(root, kind, s, arch=arch)
            results[kind] = common.run_staged(eng, s)
        out[arch] = {"kv_bytes_per_token": kv_bpt, **common.summarize(results)}
        if verbose:
            lsm, fl = out[arch]["lsm"], out[arch]["file"]
            print(f"{arch:14s} kv/tok={kv_bpt:6d}B  hit {lsm['hit_rate']:.3f} vs {fl['hit_rate']:.3f}  "
                  f"TTFT {lsm['ttft_s']:.3f}s vs {fl['ttft_s']:.3f}s "
                  f"({100*(lsm['ttft_s']/fl['ttft_s']-1):+.1f}%)")
    common.save_artifact("models_case", out)
    return out


if __name__ == "__main__":
    run()
