"""Paper Appendix B: store-operation microbenchmarks — put_batch / probe /
get_batch latency vs batch size, plus Bloom-filter probe pruning — for the
monolithic ``KVBlockStore`` and the 4-way ``ShardedKVBlockStore``."""

from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

from repro.core.codec import CODEC_INT8, BatchCodec
from repro.core.sharded_store import ShardedKVBlockStore
from repro.core.store import KVBlockStore

from . import common


def _mk_store(backend: str, root: str):
    codec = BatchCodec(CODEC_INT8, use_zlib=True)
    if backend == "lsm-sharded":
        return ShardedKVBlockStore(os.path.join(root, "s"), n_shards=4, block_size=16, codec=codec)
    if backend == "lsm":
        return KVBlockStore(os.path.join(root, "s"), block_size=16, codec=codec)
    raise ValueError(f"unknown backend {backend!r} (choose 'lsm' or 'lsm-sharded')")


def run_backend(backend: str, batch_sizes=(1, 4, 16, 64), verbose=True):
    root = tempfile.mkdtemp(prefix=f"storeops_{backend}_")
    store = _mk_store(backend, root)
    rng = np.random.default_rng(0)
    template = rng.standard_normal((16, 512)).astype(np.float16)
    out = {"put": {}, "get": {}, "probe": {}}

    seqs = {}
    for nb in batch_sizes:
        tokens = rng.integers(0, 50000, size=nb * 16).tolist()
        seqs[nb] = tokens
        t0 = time.perf_counter()
        store.put_batch(tokens, [template] * nb)
        out["put"][nb] = (time.perf_counter() - t0) * 1e3
    store.flush()

    for nb, tokens in seqs.items():
        t0 = time.perf_counter()
        got = store.get_batch(tokens, nb * 16)
        out["get"][nb] = (time.perf_counter() - t0) * 1e3
        assert len(got) == nb

    # probe: hit vs guaranteed-miss (Bloom should prune the misses)
    big = max(batch_sizes)
    hit_tokens = seqs[big]
    miss_tokens = rng.integers(50001, 99999, size=big * 16).tolist()
    t0 = time.perf_counter()
    n = store.probe(hit_tokens)
    out["probe"]["hit_ms"] = (time.perf_counter() - t0) * 1e3
    assert n == big * 16
    lk0 = store.stats.probe_lookups
    t0 = time.perf_counter()
    n = store.probe(miss_tokens)
    out["probe"]["miss_ms"] = (time.perf_counter() - t0) * 1e3
    out["probe"]["miss_lookups"] = store.stats.probe_lookups - lk0
    assert n == 0
    out["compression_ratio"] = store.stats.compression_ratio
    out["files"] = store.file_count

    if verbose:
        print(f"[{backend}]")
        print("  put_batch ms:", {k: round(v, 2) for k, v in out["put"].items()})
        print("  get_batch ms:", {k: round(v, 2) for k, v in out["get"].items()})
        print("  probe:", {k: (round(v, 3) if isinstance(v, float) else v) for k, v in out["probe"].items()})
        print(f"  compression ratio: {out['compression_ratio']:.2f}x, files: {out['files']}")
    store.close()
    return out


def run(verbose=True, backends=("lsm", "lsm-sharded"), batch_sizes=(1, 4, 16, 64)):
    out = {b: run_backend(b, batch_sizes=batch_sizes, verbose=verbose) for b in backends}
    common.save_artifact("store_ops", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke: smaller batches")
    ap.add_argument("--backends", nargs="*", default=["lsm", "lsm-sharded"],
                    choices=["lsm", "lsm-sharded"])
    args = ap.parse_args(argv)
    sizes = (1, 4, 16) if args.quick else (1, 4, 16, 64)
    run(backends=tuple(args.backends), batch_sizes=sizes)


if __name__ == "__main__":
    main()
