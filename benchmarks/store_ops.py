"""Paper Appendix B: store-operation microbenchmarks — put_batch / probe /
get_batch latency vs batch size, plus Bloom-filter probe pruning."""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.codec import CODEC_INT8, BatchCodec
from repro.core.store import KVBlockStore

from . import common


def run(verbose=True):
    root = tempfile.mkdtemp(prefix="storeops_")
    store = KVBlockStore(os.path.join(root, "s"), block_size=16,
                         codec=BatchCodec(CODEC_INT8, use_zlib=True))
    rng = np.random.default_rng(0)
    template = rng.standard_normal((16, 512)).astype(np.float16)
    out = {"put": {}, "get": {}, "probe": {}}

    seqs = {}
    for nb in (1, 4, 16, 64):
        tokens = rng.integers(0, 50000, size=nb * 16).tolist()
        seqs[nb] = tokens
        t0 = time.perf_counter()
        store.put_batch(tokens, [template] * nb)
        out["put"][nb] = (time.perf_counter() - t0) * 1e3
    store.flush()

    for nb, tokens in seqs.items():
        t0 = time.perf_counter()
        got = store.get_batch(tokens, nb * 16)
        out["get"][nb] = (time.perf_counter() - t0) * 1e3
        assert len(got) == nb

    # probe: hit vs guaranteed-miss (Bloom should prune the misses)
    hit_tokens = seqs[64]
    miss_tokens = rng.integers(50001, 99999, size=64 * 16).tolist()
    t0 = time.perf_counter()
    n = store.probe(hit_tokens)
    out["probe"]["hit_ms"] = (time.perf_counter() - t0) * 1e3
    assert n == 64 * 16
    lk0 = store.stats.probe_lookups
    t0 = time.perf_counter()
    n = store.probe(miss_tokens)
    out["probe"]["miss_ms"] = (time.perf_counter() - t0) * 1e3
    out["probe"]["miss_lookups"] = store.stats.probe_lookups - lk0
    assert n == 0
    out["compression_ratio"] = store.stats.compression_ratio

    if verbose:
        print("put_batch ms:", {k: round(v, 2) for k, v in out["put"].items()})
        print("get_batch ms:", {k: round(v, 2) for k, v in out["get"].items()})
        print("probe:", {k: (round(v, 3) if isinstance(v, float) else v) for k, v in out["probe"].items()})
        print(f"compression ratio: {out['compression_ratio']:.2f}x")
    store.close()
    common.save_artifact("store_ops", out)
    return out


if __name__ == "__main__":
    run()
