"""Roofline table (assignment deliverable g): read the dry-run artifacts
and print the per-(arch x shape x mesh) three-term analysis."""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, cells, get_config
from repro.launch.dryrun import ART_DIR, cell_path

from . import common


def load_cells(pods: int = 1) -> List[Dict]:
    rows = []
    for arch, shape, skip in cells(include_skipped=True):
        if skip:
            rows.append({"arch": arch, "shape": shape, "skipped": skip})
            continue
        p = cell_path(arch, shape, pods)
        if not os.path.exists(p):
            rows.append({"arch": arch, "shape": shape, "missing": True})
            continue
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def run(pods: int = 1, verbose=True):
    rows = load_cells(pods)
    ok = [r for r in rows if r.get("ok")]
    if verbose:
        hdr = (f"{'arch':18s} {'shape':12s} {'compute':>9s} {'memory':>9s} {'coll':>9s} "
               f"{'bound':>10s} {'useful':>7s} {'MFU':>6s}")
        print(hdr)
        print("-" * len(hdr))
        for r in rows:
            if r.get("skipped"):
                print(f"{r['arch']:18s} {r['shape']:12s} SKIP ({r['skipped'][:48]})")
                continue
            if r.get("missing"):
                print(f"{r['arch']:18s} {r['shape']:12s} MISSING")
                continue
            rl = r["roofline"]
            print(f"{r['arch']:18s} {r['shape']:12s} {rl['compute_s']*1e3:8.1f}ms {rl['memory_s']*1e3:8.1f}ms "
                  f"{rl['collective_s']*1e3:8.1f}ms {rl['bottleneck']:>10s} "
                  f"{rl['useful_flop_ratio']:7.3f} {rl['mfu']:6.3f}")
    common.save_artifact(f"roofline_{pods}pod", {"rows": rows})
    return rows


if __name__ == "__main__":
    import sys

    run(pods=int(sys.argv[1]) if len(sys.argv) > 1 else 1)
