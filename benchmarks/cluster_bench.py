"""Cluster benchmark: horizontal scale-out of the socket-served cache.

Three measurements, all against real ``repro.cluster.node`` child
processes on localhost driven by one ``ClusterKVBlockStore`` client:

1. CAPACITY SCALE-OUT (the headline number, and the paper's enterprise
   claim): every node gets the *same fixed cache budget* — the
   deployment shape, where adding nodes is how aggregate capacity
   grows.  A corpus sized to the 4-node aggregate is committed and then
   read back: a 1-node cluster can only hold ~1/4 of it (FIFO file
   eviction enforces the budget), so most ``get_many`` reads come back
   empty and the blocks must be recomputed upstream; 4 nodes hold the
   whole working set and serve it in full.  Sustained *served-block*
   throughput (blocks actually returned per second) is the metric —
   capacity, hit rate, and serving rate in one number, exactly what the
   engine sees.

2. SERVING RATE (fixed per-node budget): the deployment shape again,
   measured through the engine-facing *streaming* read path.  Every
   node count serves the same corpus under the same per-node cache
   budget, so small clusters evict (short serves) while the full
   cluster streams everything — sustained served-block throughput is
   the metric, and the sweep additionally reports the latency split
   the multiplexed transport is built for: time-to-first-block vs
   full-batch latency per sequence (the engine starts installing block
   0 at TTFB; the barrier design paid the full-batch time).  CPU
   utilization (client + node processes vs wall) is attached because
   shared containers serialize much of the cross-process socket work —
   absolute rates are noisy there; ratios are the signal.  See
   docs/BENCHMARKS.md.

3. COMPRESSION TIERS: the capacity question re-asked per codec policy
   at ONE raw-calibrated budget — raw vs static int8+zlib vs the
   adaptive ``tiered`` policy (hot puts raw, maintenance demotes idle
   files down-tier).  Effective-capacity multiplier, wire bytes per
   served block (compressed payloads ship end to end), per-tier
   OP_METRICS gauges, and a paired put-overhead check that the policy
   costs nothing on the ingest hot path.

4. FAILOVER: an R=2 cluster loses a node after commit and must serve
   every committed block from the survivor (zero lost blocks;
   ``examples/failover.py`` demonstrates the full kill/rejoin story).

5. ELASTICITY: live membership change under load.  A 2-node cluster
   holding a committed corpus scales out to 4 *mid-run* — reads keep
   hitting through the two-ring transition, one maintenance cycle
   drains the background block migration (time-to-rebalance recorded),
   and the post-rebalance per-node served-block load must sit within a
   1.3x max/mean imbalance bound.  A SIGKILL leg (R=2) then verifies
   the repair path: hit rate holds through the outage and the next
   maintenance cycle restores full replication (detection-to-repaired
   lag recorded).

``run()`` writes the ``cluster`` artifact and returns the dict
``benchmarks/run.py`` serializes into ``BENCH_cluster.json``.
"""

from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster import ClusterKVBlockStore, spawn_local_node

from . import common


# ------------------------------------------------------------------ corpus
def make_corpus(
    n_seqs: int,
    blocks_per_seq: int,
    block_tokens: int,
    kv_bytes_per_token: int,
    seed: int = 7,
) -> Tuple[List[List[int]], List[List[np.ndarray]]]:
    """Synthetic prefix corpus: distinct token sequences plus smooth
    low-magnitude KV blocks (int8-quantizable, mildly compressible —
    the regime the on-disk codec is tuned for)."""
    rng = np.random.default_rng(seed)
    feat = kv_bytes_per_token // 4  # f32 features per token
    seqs, blocks = [], []
    for _ in range(n_seqs):
        seqs.append(rng.integers(1, 50_000, size=blocks_per_seq * block_tokens,
                                 dtype=np.int64).tolist())
        scale = rng.uniform(0.5, 2.0)
        blocks.append([
            (scale * rng.standard_normal((block_tokens, feat))).astype(np.float32)
            for _ in range(blocks_per_seq)
        ])
    return seqs, blocks


def _proc_cpu_s(pid: int) -> Optional[float]:
    """CPU seconds of ``pid`` via procfs; ``None`` where /proc does not
    exist (macOS) or the process is gone."""
    try:
        with open(f"/proc/{pid}/stat") as f:
            parts = f.read().split()
    except OSError:
        return None
    return (int(parts[13]) + int(parts[14])) / os.sysconf("SC_CLK_TCK")


class _LocalCluster:
    """n spawned node processes + one connected ClusterKVBlockStore."""

    def __init__(self, n_nodes: int, block_tokens: int, replication: int = 1,
                 node_io_threads: int = 2, client_io_threads: int = 16,
                 backend: str = "lsm", codec: str = "int8-zlib",
                 budget_bytes: int = 0, vlog_file_bytes: int = 0,
                 vnodes: int = 64,
                 node_extra_args: Optional[List[str]] = None):
        self._spawn_kw = dict(block_size=block_tokens, backend=backend,
                              codec=codec, io_threads=node_io_threads,
                              budget_bytes=budget_bytes,
                              vlog_file_bytes=vlog_file_bytes,
                              extra_args=node_extra_args)
        self.roots = [tempfile.mkdtemp(prefix=f"clbench_{n_nodes}n_{i}_")
                      for i in range(n_nodes)]
        self.nodes = [spawn_local_node(root, **self._spawn_kw)
                      for root in self.roots]
        self.store = ClusterKVBlockStore(
            [n.address for n in self.nodes],
            replication=replication,
            block_size=block_tokens,
            io_threads=client_io_threads,
            vnodes=vnodes,
            node_ids=[f"node-{i}" for i in range(n_nodes)],  # stable placement
        )

    def join_node(self) -> int:
        """Spawn one more node process (same backend/codec/budget) and
        join it to the live cluster; returns its index."""
        idx = len(self.nodes)
        root = tempfile.mkdtemp(prefix=f"clbench_join_{idx}_")
        self.roots.append(root)
        node = spawn_local_node(root, **self._spawn_kw)
        self.nodes.append(node)
        return self.store.add_node(node.address, node_id=f"node-{idx}")

    def cpu_s(self) -> Optional[float]:
        """CPU seconds consumed so far by the node processes + this one;
        ``None`` on hosts without procfs."""
        samples = [_proc_cpu_s(n.proc.pid) for n in self.nodes if n.alive]
        samples.append(_proc_cpu_s(os.getpid()))
        if any(s is None for s in samples):
            return None
        return sum(samples)

    def kill_node(self, idx: int) -> None:
        self.nodes[idx].kill()

    def close(self) -> None:
        self.store.close()
        for n in self.nodes:
            n.close()
        for root in self.roots:
            shutil.rmtree(root, ignore_errors=True)


# ------------------------------------------------------- capacity scale-out
def capacity_sweep(
    node_counts: Sequence[int] = (1, 2, 4),
    n_seqs: int = 96,
    blocks_per_seq: int = 12,
    block_tokens: int = 16,
    kv_bytes_per_token: int = 1024,
    budget_slack: float = 1.4,
    repeats: int = 3,
    codec: str = "int8",
    verbose: bool = True,
) -> Dict:
    """Fixed per-node budget sized so max(node_counts) nodes hold the
    whole corpus (with ``budget_slack`` headroom for placement skew and
    store overhead) — fewer nodes must evict.  A calibration pass
    measures the corpus's actual on-disk footprint (codec + index
    overhead included), so budgets are exact for any codec."""
    seqs, blocks = make_corpus(n_seqs, blocks_per_seq, block_tokens,
                               kv_bytes_per_token)
    n_tokens = blocks_per_seq * block_tokens
    total_blocks = n_seqs * blocks_per_seq
    corpus_bytes = total_blocks * block_tokens * kv_bytes_per_token
    get_items = [(s, n_tokens) for s in seqs]
    put_items = [(s, bs, 0) for s, bs in zip(seqs, blocks)]

    # calibration: one unbudgeted node measures the true disk footprint
    cal = _LocalCluster(1, block_tokens, backend="lsm", codec=codec)
    try:
        cal.store.put_many(put_items)
        cal.store.flush()
        disk_footprint = cal.store.disk_bytes
    finally:
        cal.close()
    budget = int(disk_footprint * budget_slack / max(node_counts))

    out: Dict = {
        "corpus_bytes": corpus_bytes,
        "total_blocks": total_blocks,
        "disk_footprint_bytes": disk_footprint,
        "per_node_budget_bytes": budget,
        "budget_slack": budget_slack,
        "codec": codec,
        "nodes": {},
    }
    for n in node_counts:
        cl = _LocalCluster(n, block_tokens, backend="lsm", codec=codec,
                           budget_bytes=budget, vlog_file_bytes=budget // 8)
        try:
            cl.store.put_many(put_items)
            cl.store.flush()
            cl.store.maintenance()  # deterministic budget enforcement
            best, served = 0.0, 0
            for _ in range(repeats):
                t0 = time.perf_counter()
                got = cl.store.get_many(get_items)
                dt = time.perf_counter() - t0
                served = sum(len(g) for g in got)
                best = max(best, served / dt)
            row = {
                "served_blocks_per_s": best,
                "served_fraction": served / total_blocks,
                "disk_bytes": cl.store.disk_bytes,
            }
        finally:
            cl.close()
        out["nodes"][n] = row
        if verbose:
            print(f"  {n} node(s) @ {budget >> 20}MiB/node: "
                  f"served {row['served_fraction']:5.1%} of corpus at "
                  f"{best:7.0f} blk/s")
    base = out["nodes"][min(out["nodes"])]
    for n, row in out["nodes"].items():
        row["speedup"] = row["served_blocks_per_s"] / base["served_blocks_per_s"]
    if verbose:
        top = max(out["nodes"])
        print(f"  {top}-node served-block throughput vs 1-node: "
              f"{out['nodes'][top]['speedup']:.2f}x")
    return out


# ------------------------------------------------------ compression sweep
def _drain_demotions(cl: _LocalCluster, max_rounds: int = 12) -> int:
    """Run maintenance cycles until no node demotes anything (the tier
    recoder has settled); returns total demoted blocks."""
    total = 0
    for _ in range(max_rounds):
        rep = cl.store.maintenance()
        demoted = 0
        for nrep in rep["nodes"].values():
            demoted += int(((nrep or {}).get("tiering") or {})
                           .get("demoted_blocks", 0) or 0)
        total += demoted
        if demoted == 0:
            break
    return total


def _tier_gauges(cl: _LocalCluster) -> Dict[str, float]:
    """Cluster-summed tiering gauges off the OP_METRICS scrape — the same
    numbers an operator's dashboard would plot."""
    sums: Dict[str, float] = {}
    for rep in cl.store.scrape_cluster()["nodes"].values():
        if rep.get("unreachable"):
            continue
        for k, v in rep["metrics"]["gauges"].items():
            if k.startswith(("repro_store_tier_", "repro_store_demote")):
                sums[k] = sums.get(k, 0.0) + v
    return sums


def _put_overhead(
    n_seqs: int,
    blocks_per_seq: int,
    block_tokens: int,
    kv_bytes_per_token: int,
    repeats: int = 3,
) -> Dict:
    """Paired local ingest: raw codec vs the tiered policy (which also
    writes raw on the hot path — demotion is maintenance-only).  The
    acceptance gate is that enabling the policy costs nothing at put
    time; interleaved best-of-``repeats`` samples keep container noise
    from deciding the comparison."""
    from repro.core.codec import CODEC_RAW, BatchCodec
    from repro.core.store import KVBlockStore
    from repro.core.tiering import TieringPolicy

    seqs, blocks = make_corpus(n_seqs, blocks_per_seq, block_tokens,
                               kv_bytes_per_token, seed=31)
    put_items = [(s, bs, 0) for s, bs in zip(seqs, blocks)]
    total_blocks = n_seqs * blocks_per_seq
    variants = {
        "raw": lambda: dict(codec=BatchCodec(CODEC_RAW, use_zlib=False)),
        "tiered": lambda: dict(tiering=TieringPolicy()),  # default thresholds:
    }                                                     # nothing demotes mid-run
    best = {name: 0.0 for name in variants}
    for _ in range(repeats):
        for name, kw in variants.items():
            root = tempfile.mkdtemp(prefix=f"clbench_put_{name}_")
            try:
                st = KVBlockStore(root, block_size=block_tokens, **kw())
                t0 = time.perf_counter()
                st.put_many(put_items)
                st.flush()
                dt = time.perf_counter() - t0
                st.close()
            finally:
                shutil.rmtree(root, ignore_errors=True)
            best[name] = max(best[name], total_blocks / dt)
    return {
        "raw_put_blocks_per_s": best["raw"],
        "tiered_put_blocks_per_s": best["tiered"],
        "regression_pct": 100.0 * (1.0 - best["tiered"] / best["raw"]),
    }


def compression_sweep(
    codecs: Sequence[str] = ("raw", "int8-zlib", "tiered"),
    node_counts: Sequence[int] = (1, 2, 4),
    n_seqs: int = 96,
    blocks_per_seq: int = 12,
    block_tokens: int = 16,
    kv_bytes_per_token: int = 1024,
    budget_slack: float = 1.4,
    repeats: int = 3,
    ingest_chunks: int = 6,
    put_repeats: int = 3,
    verbose: bool = True,
) -> Dict:
    """Capacity scale-out per codec policy at ONE fixed budget.

    Unlike ``capacity_sweep`` (which calibrates the budget to whatever
    codec it measures), this sweep calibrates once against the RAW
    footprint and holds the per-node budget fixed across codecs — the
    apples-to-apples question an operator asks: *with the disks I have,
    how much more corpus does a compressed tier let me serve?*

    The ``tiered`` policy (hot puts raw; maintenance demotes idle files
    to int8 / int8+zlib) runs with zero thresholds so every sealed file
    demotes at the next cycle, and ingest is chunked with a maintenance
    call between chunks — the deployment cadence, where off-path
    demotion keeps pace with ingest instead of racing FIFO eviction
    after the fact.  Reported per codec and node count:

    * ``served_fraction`` / ``served_blocks_per_s`` — as capacity_sweep,
    * ``capacity_x_vs_raw`` — served_fraction relative to raw at the
      same node count (the effective-capacity multiplier),
    * ``wire_bytes_per_served_block`` and ``wire_ratio_vs_raw`` — bytes
      on the wire per block served (compressed tiers ship compressed
      payloads end to end; the client decodes at fulfill),
    * for ``tiered``: demoted blocks, per-tier block gauges and
      bytes-saved scraped over OP_METRICS mid-bench.

    A paired local ingest run (``put_overhead``) pins the hot-path
    claim: enabling the tiering policy must not slow raw puts."""
    seqs, blocks = make_corpus(n_seqs, blocks_per_seq, block_tokens,
                               kv_bytes_per_token)
    n_tokens = blocks_per_seq * block_tokens
    total_blocks = n_seqs * blocks_per_seq
    get_items = [(s, n_tokens) for s in seqs]
    put_items = [(s, bs, 0) for s, bs in zip(seqs, blocks)]

    # calibration: the RAW footprint sets the budget for every codec
    cal = _LocalCluster(1, block_tokens, backend="lsm", codec="raw")
    try:
        cal.store.put_many(put_items)
        cal.store.flush()
        raw_footprint = cal.store.disk_bytes
    finally:
        cal.close()
    budget = int(raw_footprint * budget_slack / max(node_counts))

    out: Dict = {
        "corpus_bytes": total_blocks * block_tokens * kv_bytes_per_token,
        "total_blocks": total_blocks,
        "raw_disk_footprint_bytes": raw_footprint,
        "per_node_budget_bytes": budget,
        "budget_slack": budget_slack,
        "node_counts": list(node_counts),
        "codecs": {},
    }
    chunk = max(1, n_seqs // max(1, ingest_chunks))
    for codec in codecs:
        extra = (["--warm-after-s", "0", "--cold-after-s", "0"]
                 if codec == "tiered" else None)
        rows: Dict[int, Dict] = {}
        for n in node_counts:
            cl = _LocalCluster(n, block_tokens, backend="lsm", codec=codec,
                               budget_bytes=budget,
                               vlog_file_bytes=budget // 8,
                               node_extra_args=extra)
            try:
                for i in range(0, n_seqs, chunk):
                    cl.store.put_many(put_items[i:i + chunk])
                    cl.store.flush()
                    cl.store.maintenance()  # demote + budget, ingest cadence
                demoted = _drain_demotions(cl)
                rep0 = cl.store.report(include_nodes=False)
                rx0 = sum(r["bytes_received"] for r in rep0["rpc"].values())
                best, served = 0.0, 0
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    got = cl.store.get_many(get_items)
                    dt = time.perf_counter() - t0
                    served = sum(len(g) for g in got)
                    best = max(best, served / dt)
                rep1 = cl.store.report(include_nodes=False)
                rx = (sum(r["bytes_received"] for r in rep1["rpc"].values())
                      - rx0) / repeats
                row = {
                    "served_blocks_per_s": best,
                    "served_fraction": served / total_blocks,
                    "disk_bytes": cl.store.disk_bytes,
                    "wire_bytes_per_get": rx,
                    "wire_bytes_per_served_block": rx / max(served, 1),
                }
                if codec == "tiered":
                    row["demoted_blocks"] = demoted
                    gauges = _tier_gauges(cl)
                    row["tier_blocks"] = {
                        t: gauges.get(f"repro_store_tier_{t}_blocks", 0.0)
                        for t in ("hot", "warm", "cold")
                    }
                    row["demote_bytes_saved"] = (
                        gauges.get("repro_store_demote_bytes_before", 0.0)
                        - gauges.get("repro_store_demote_bytes_after", 0.0))
            finally:
                cl.close()
            rows[n] = row
            if verbose:
                print(f"  {codec:9s} {n} node(s) @ {budget >> 20}MiB/node: "
                      f"served {row['served_fraction']:5.1%} at {best:7.0f} blk/s, "
                      f"{row['wire_bytes_per_served_block']:6.0f} wire B/blk")
        full = [n for n in node_counts if rows[n]["served_fraction"] >= 0.999]
        out["codecs"][codec] = {
            "nodes": rows,
            "nodes_to_full": min(full) if full else None,
        }

    # derived: effective capacity + wire ratio vs the raw baseline
    raw_rows = out["codecs"].get("raw", {}).get("nodes", {})
    for codec, entry in out["codecs"].items():
        if codec == "raw":
            continue
        for n, row in entry["nodes"].items():
            base = raw_rows.get(n)
            if not base:
                continue
            row["capacity_x_vs_raw"] = (
                row["served_fraction"] / max(base["served_fraction"], 1e-9))
            row["wire_ratio_vs_raw"] = (
                base["wire_bytes_per_served_block"]
                / max(row["wire_bytes_per_served_block"], 1e-9))
    tight = min(node_counts)  # the most budget-constrained point
    out["effective_capacity_x"] = {
        codec: entry["nodes"][tight].get("capacity_x_vs_raw")
        for codec, entry in out["codecs"].items() if codec != "raw"
    }
    out["put_overhead"] = _put_overhead(
        max(8, n_seqs // 2), blocks_per_seq, block_tokens, kv_bytes_per_token,
        repeats=put_repeats)
    if verbose:
        for codec, x in out["effective_capacity_x"].items():
            if x is not None:
                print(f"  {codec}: {x:.2f}x effective capacity vs raw at "
                      f"{tight} node(s)")
        po = out["put_overhead"]
        print(f"  tiered-policy put overhead vs raw codec: "
              f"{po['regression_pct']:+.1f}% "
              f"({po['tiered_put_blocks_per_s']:.0f} vs "
              f"{po['raw_put_blocks_per_s']:.0f} blk/s)")
    return out


# --------------------------------------------------------- serving rate
def serving_sweep(
    node_counts: Sequence[int] = (1, 2, 4),
    n_seqs: int = 32,
    blocks_per_seq: int = 32,
    block_tokens: int = 16,
    kv_bytes_per_token: int = 1024,
    budget_slack: float = 1.4,
    repeats: int = 5,
    stream_sample: int = 8,
    node_io_threads: int = 2,
    client_io_threads: int = 16,
    codec: str = "int8",
    verbose: bool = True,
) -> Dict:
    """Serving rate at a *fixed per-node budget* (the deployment shape:
    capacity grows by adding nodes).  A calibration pass measures the
    corpus's true on-disk footprint; each node then gets
    ``footprint * slack / max(node_counts)`` bytes, so only the full
    cluster holds the whole working set — small clusters evict and
    serve short.  Metrics, best of ``repeats`` (shared-container noise
    policy: the best sample is the least-perturbed one):

    * ``get_blocks_per_s`` — served blocks/s through ``get_many`` (the
      engine's batched streaming read path),
    * ``time_to_first_block_s`` / ``full_batch_get_s`` — per-sequence
      latency split off ``get_batch_stream`` over ``stream_sample``
      fully-served sequences: the engine starts installing at the
      first number; a barrier transport would pay the second."""
    seqs, blocks = make_corpus(n_seqs, blocks_per_seq, block_tokens,
                               kv_bytes_per_token)
    n_tokens = blocks_per_seq * block_tokens
    total_blocks = n_seqs * blocks_per_seq
    get_items = [(s, n_tokens) for s in seqs]
    put_items = [(s, bs, 0) for s, bs in zip(seqs, blocks)]

    # calibration: one unbudgeted node measures the true disk footprint
    cal = _LocalCluster(1, block_tokens, backend="lsm", codec=codec)
    try:
        cal.store.put_many(put_items)
        cal.store.flush()
        disk_footprint = cal.store.disk_bytes
    finally:
        cal.close()
    budget = int(disk_footprint * budget_slack / max(node_counts))

    out: Dict = {
        "cpu_count": os.cpu_count(),
        "n_seqs": n_seqs,
        "blocks_per_seq": blocks_per_seq,
        "block_tokens": block_tokens,
        "kv_bytes_per_token": kv_bytes_per_token,
        "disk_footprint_bytes": disk_footprint,
        "per_node_budget_bytes": budget,
        "budget_slack": budget_slack,
        "codec": codec,
        "node_io_threads": node_io_threads,
        "client_io_threads": client_io_threads,
        "nodes": {},
    }
    for n in node_counts:
        cl = _LocalCluster(n, block_tokens, node_io_threads=node_io_threads,
                           client_io_threads=client_io_threads, codec=codec,
                           budget_bytes=budget, vlog_file_bytes=budget // 8)
        try:
            t0 = time.perf_counter()
            cl.store.put_many(put_items)
            cl.store.flush()
            put_s = time.perf_counter() - t0
            cl.store.maintenance()  # deterministic budget enforcement

            cl.store.get_many(get_items)  # warm page cache + pools
            best_get, served = 0.0, 0
            cpu0, w0 = cl.cpu_s(), time.perf_counter()
            for _ in range(repeats):
                t0 = time.perf_counter()
                got = cl.store.get_many(get_items)
                dt = time.perf_counter() - t0
                served = sum(len(g) for g in got)
                best_get = max(best_get, served / dt)
            cpu1 = cl.cpu_s()
            util = (
                (cpu1 - cpu0) / (time.perf_counter() - w0)
                if cpu0 is not None and cpu1 is not None
                else None
            )

            # latency split: stream a sample of fully-resident sequences
            # (short serves would conflate eviction with transport) and
            # take the best per-sequence sample for both numbers
            full_idx = [i for i, g in enumerate(got)
                        if len(g) == blocks_per_seq][:stream_sample]
            ttfb, full = [], []
            for _ in range(repeats):
                for i in full_idx:
                    t0 = time.perf_counter()
                    stream = cl.store.get_batch_stream(seqs[i], n_tokens)
                    n_got = sum(1 for _ in stream)
                    dt = time.perf_counter() - t0
                    if n_got == blocks_per_seq and stream.first_block_s is not None:
                        ttfb.append(stream.first_block_s)
                        full.append(dt)

            rep = cl.store.report(include_nodes=False)
            row = {
                "get_blocks_per_s": best_get,
                "served_fraction": served / total_blocks,
                "put_blocks_per_s": total_blocks / put_s,
                "time_to_first_block_s": float(np.median(ttfb)) if ttfb else None,
                "full_batch_get_s": float(np.median(full)) if full else None,
                "ttfb_percentiles": common.percentiles(ttfb),
                "full_batch_percentiles": common.percentiles(full),
                "streamed_sequences": len(full_idx),
                "cpu_utilization": util,
                "rpcs": sum(r["rpcs"] for r in rep["rpc"].values()),
                "stream_chunks": sum(r["stream_chunks"] for r in rep["rpc"].values()),
                "bytes_received": sum(r["bytes_received"] for r in rep["rpc"].values()),
            }
        finally:
            cl.close()
        out["nodes"][n] = row
        if verbose:
            util_s = f"{util:.2f} cores" if util is not None else "n/a"
            ttfb_s = (f"{1e3 * row['time_to_first_block_s']:6.1f}ms"
                      if row["time_to_first_block_s"] is not None else "   n/a")
            full_s = (f"{1e3 * row['full_batch_get_s']:6.1f}ms"
                      if row["full_batch_get_s"] is not None else "   n/a")
            print(f"  {n} node(s) @ {budget >> 20}MiB/node: "
                  f"served {row['served_fraction']:5.1%} at {best_get:7.0f} blk/s   "
                  f"ttfb {ttfb_s} / full {full_s}   util {util_s}")
    base = out["nodes"][min(out["nodes"])]
    for n, row in out["nodes"].items():
        row["get_speedup"] = row["get_blocks_per_s"] / base["get_blocks_per_s"]
    if verbose:
        top = max(out["nodes"])
        print(f"  {top}-node serving rate vs 1-node at fixed per-node budget: "
              f"{out['nodes'][top]['get_speedup']:.2f}x")
    return out


# ---------------------------------------------------------------- failover
def failover_check(
    n_seqs: int = 12,
    blocks_per_seq: int = 16,
    block_tokens: int = 16,
    kv_bytes_per_token: int = 512,
    verbose: bool = True,
) -> Dict:
    """R=2 over 2 nodes; SIGKILL one after commit; every committed block
    must still be served by the survivor."""
    seqs, blocks = make_corpus(n_seqs, blocks_per_seq, block_tokens,
                               kv_bytes_per_token, seed=13)
    n_tokens = blocks_per_seq * block_tokens
    cl = _LocalCluster(2, block_tokens, replication=2)
    try:
        cl.store.put_many([(s, bs, 0) for s, bs in zip(seqs, blocks)])
        cl.store.flush()
        cl.kill_node(0)
        lost = 0
        for s, bs in zip(seqs, blocks):
            got = cl.store.get_batch(s, n_tokens)
            lost += blocks_per_seq - len(got)
            for want, have in zip(bs, got):
                np.testing.assert_allclose(
                    have, want, atol=0.1, rtol=0.1)  # int8 quantization error
        rep = cl.store.report()
        out = {
            "replication": 2,
            "committed_blocks": n_seqs * blocks_per_seq,
            "lost_committed_blocks": lost,
            "down_nodes": rep["down"],
            "cluster": rep["cluster"],
        }
    finally:
        cl.close()
    if verbose:
        print(f"  failover: killed 1/2 nodes (R=2); lost committed blocks: "
              f"{lost}/{out['committed_blocks']}")
    return out


# ------------------------------------------------------------- elasticity
def _served_blocks_per_node(cl: _LocalCluster) -> Dict[int, float]:
    """Per-node served-block counters off the OP_METRICS scrape (buffered
    gets plus the sendfile raw path — either way the node served)."""
    out: Dict[int, float] = {}
    for idx, rep in cl.store.scrape_cluster()["nodes"].items():
        if rep.get("unreachable") or rep.get("retired"):
            continue
        g = rep["metrics"]["gauges"]
        out[idx] = (g.get("repro_store_get_blocks", 0.0)
                    + g.get("repro_store_raw_get_blocks", 0.0))
    return out


def elasticity_sweep(
    start_nodes: int = 2,
    end_nodes: int = 4,
    n_seqs: int = 192,
    blocks_per_seq: int = 6,
    block_tokens: int = 16,
    kv_bytes_per_token: int = 512,
    replication: int = 2,
    vnodes: int = 512,
    imbalance_limit: Optional[float] = 1.3,
    kill_leg: bool = True,
    verbose: bool = True,
) -> Dict:
    """Live membership change under load, the tentpole's acceptance run.

    Ingest a corpus on ``start_nodes`` nodes, then scale out to
    ``end_nodes`` **mid-run**: reads must keep hitting through the
    two-ring transition, ONE maintenance cycle must drain the rebalance
    (time-to-rebalance is recorded from the migrator), and after it the
    per-node served-block load over a full read pass must sit within
    ``imbalance_limit`` (max/mean) — the joined nodes actually take
    their share of the serving work.  The high ``vnodes`` default keeps
    ring-arc variance below the sampling noise of the corpus.

    With ``kill_leg``, the sweep then SIGKILLs one member (R=2): the hit
    rate must hold through the outage (degraded reads, never misses),
    the next maintenance cycle must repair back to full replication —
    verified by per-node probes, every sequence fully resident on >=
    ``replication`` live nodes — and the detection-to-repaired lag is
    recorded.  The corpus and ring placement are deterministic (fixed
    seed, stable node ids), so the recorded numbers are reproducible."""
    seqs, blocks = make_corpus(n_seqs, blocks_per_seq, block_tokens,
                               kv_bytes_per_token, seed=41)
    n_tokens = blocks_per_seq * block_tokens
    total_blocks = n_seqs * blocks_per_seq
    get_items = [(s, n_tokens) for s in seqs]

    def hit_rate() -> float:
        return sum(cl.store.probe_many(seqs)) / (n_seqs * n_tokens)

    cl = _LocalCluster(start_nodes, block_tokens, replication=replication,
                       codec="raw", vnodes=vnodes)
    try:
        cl.store.put_many([(s, bs, 0) for s, bs in zip(seqs, blocks)])
        cl.store.flush()
        hit_before = hit_rate()

        # ---- scale out mid-run -------------------------------------
        t_scale = time.perf_counter()
        for _ in range(start_nodes, end_nodes):
            cl.join_node()
        hit_mid = hit_rate()  # two-ring reads: no transition-window misses
        rep = cl.store.maintenance()
        wall_rebalance_s = time.perf_counter() - t_scale
        mig = rep["migration"]
        assert mig.get("done"), "rebalance did not drain in one maintenance cycle"
        ms = cl.store.migrator.stats

        # ---- post-rebalance load distribution ----------------------
        snap0 = _served_blocks_per_node(cl)
        t0 = time.perf_counter()
        got = cl.store.get_many(get_items)
        read_s = time.perf_counter() - t0
        served = sum(len(g) for g in got)
        snap1 = _served_blocks_per_node(cl)
        load = {i: snap1[i] - snap0.get(i, 0.0) for i in snap1}
        mean_load = sum(load.values()) / max(len(load), 1)
        imbalance = max(load.values()) / max(mean_load, 1e-9)
        if imbalance_limit is not None:
            assert imbalance < imbalance_limit, (
                f"post-rebalance served-block imbalance {imbalance:.2f} "
                f">= {imbalance_limit} (per-node load {load})")
        hit_after = hit_rate()

        out: Dict = {
            "start_nodes": start_nodes,
            "end_nodes": end_nodes,
            "replication": replication,
            "vnodes": vnodes,
            "total_blocks": total_blocks,
            "hit_rate_before_scale": hit_before,
            "hit_rate_mid_transition": hit_mid,
            "hit_rate_after_rebalance": hit_after,
            "rebalance_s": ms.rebalance_s,  # migrator task wall time
            "scaleout_wall_s": wall_rebalance_s,  # join -> drained, incl. spawn
            "migrated_blocks": ms.blocks_copied,
            "migrated_bytes": ms.bytes_moved,
            "served_blocks_per_s_after": served / read_s,
            "served_fraction_after": served / total_blocks,
            "per_node_served_blocks": load,
            "load_imbalance_max_over_mean": imbalance,
        }
        if verbose:
            print(f"  scale-out {start_nodes} -> {end_nodes} mid-run: "
                  f"hit {hit_before:.1%} -> {hit_mid:.1%} (transition) -> "
                  f"{hit_after:.1%}; rebalanced {ms.blocks_copied} blocks "
                  f"({ms.bytes_moved >> 10}KiB) in {ms.rebalance_s:.2f}s; "
                  f"load imbalance {imbalance:.2f}x")

        # ---- SIGKILL + repair back to full replication -------------
        if kill_leg:
            victim = cl.store.replicas_for(seqs[0])[0]
            cl.kill_node(victim)
            hit_outage = hit_rate()  # marks the corpse down along the way
            t0 = time.perf_counter()
            rep2 = cl.store.maintenance()
            repair_wall_s = time.perf_counter() - t0
            assert rep2["migration"].get("kind") == "repair" and \
                rep2["migration"].get("done"), "repair did not run to completion"
            # every sequence back at full replication among the living
            under = 0
            for s in seqs:
                full = sum(1 for i in cl.store.live_nodes
                           if cl.store.nodes[i].probe(s) == n_tokens)
                under += int(full < replication)
            hit_repaired = hit_rate()
            out["kill"] = {
                "victim": victim,
                "hit_rate_during_outage": hit_outage,
                "hit_rate_after_repair": hit_repaired,
                "repair_s": cl.store.migrator.stats.repair_s,
                "repair_lag_s": cl.store.migrator.stats.repair_lag_s,
                "repair_wall_s": repair_wall_s,
                "repair_blocks": cl.store.migrator.stats.repair_blocks,
                "seqs_under_replicated_after_repair": under,
            }
            assert under == 0, f"{under} sequences below R={replication} after repair"
            if verbose:
                print(f"  SIGKILL node {victim} (R={replication}): hit held at "
                      f"{hit_outage:.1%} through the outage; repair copied "
                      f"{out['kill']['repair_blocks']} blocks, detection->full-R "
                      f"lag {out['kill']['repair_lag_s']:.2f}s; "
                      f"under-replicated after: {under}")
    finally:
        cl.close()
    return out


def elasticity_smoke(verbose: bool = True) -> Dict:
    """CI-sized elasticity check: 2 -> 3 nodes over a tiny corpus.
    Asserts the rebalance drains within one maintenance cycle, the hit
    rate holds through the transition and recovers to 100%, and (R=2)
    a SIGKILL is repaired back to full replication.  The load-imbalance
    gate is left to the full sweep — a tiny corpus under-samples it."""
    ela = elasticity_sweep(
        start_nodes=2, end_nodes=3,
        n_seqs=24, blocks_per_seq=4, kv_bytes_per_token=256,
        imbalance_limit=None, kill_leg=True,
        verbose=verbose,
    )
    assert ela["hit_rate_mid_transition"] >= 0.999, "misses during transition"
    assert ela["hit_rate_after_rebalance"] >= 0.999, "hit rate did not recover"
    assert ela["migrated_blocks"] > 0, "rebalance moved nothing"
    assert ela["kill"]["seqs_under_replicated_after_repair"] == 0
    if verbose:
        print("  elasticity smoke OK: rebalance "
              f"{ela['migrated_blocks']} blocks in {ela['rebalance_s']:.2f}s, "
              f"repair lag {ela['kill']['repair_lag_s']:.2f}s")
    return ela


# ------------------------------------------------------------ observability
def observability_check(
    n_nodes: int = 4,
    n_seqs: int = 16,
    blocks_per_seq: int = 8,
    block_tokens: int = 16,
    kv_bytes_per_token: int = 512,
    verbose: bool = True,
) -> Dict:
    """Scrape a *live* cluster mid-load: ``n_nodes`` real node processes
    serve a traced ``get_many`` loop from a background thread while the
    main thread issues ``scrape_cluster()`` (OP_METRICS fan-out).  The
    acceptance claim this encodes: a mid-benchmark scrape returns, for
    every node, request counters, backend gauges, and latency histograms
    with p50/p95/p99 — including at least one trace-derived server-side
    span metric — without perturbing or blocking the load."""
    from repro.obs.tracing import TraceContext, activate

    seqs, blocks = make_corpus(n_seqs, blocks_per_seq, block_tokens,
                               kv_bytes_per_token, seed=23)
    n_tokens = blocks_per_seq * block_tokens
    get_items = [(s, n_tokens) for s in seqs]
    cl = _LocalCluster(n_nodes, block_tokens)
    try:
        cl.store.put_many([(s, bs, 0) for s, bs in zip(seqs, blocks)])
        cl.store.flush()
        stop = threading.Event()
        loops = [0]

        def load():
            # every iteration is one traced request: the trace id rides the
            # mux frames to every node the fan-out touches
            while not stop.is_set():
                with activate(TraceContext()):
                    cl.store.get_many(get_items)
                loops[0] += 1

        t = threading.Thread(target=load, daemon=True)
        t.start()
        deadline = time.time() + 10.0
        while loops[0] < 2 and time.time() < deadline:
            time.sleep(0.02)
        t0 = time.perf_counter()
        scrape = cl.store.scrape_cluster()  # mid-load: the loop keeps running
        scrape_s = time.perf_counter() - t0
        stop.set()
        t.join(timeout=30)

        per_node = {}
        for idx, rep in scrape["nodes"].items():
            assert not rep.get("unreachable"), f"node {idx} unreachable mid-bench"
            m = rep["metrics"]
            hreq = m["histograms"]["repro_node_request_seconds"]
            hspan = m["histograms"]["repro_node_trace_server_span_seconds"]
            # streamed reads served straight from the tensor log count as
            # raw_get_blocks (sendfile path), not get_blocks — either way
            # the node served blocks
            served_blocks = (m["gauges"]["repro_store_get_blocks"]
                             + m["gauges"].get("repro_store_raw_get_blocks", 0.0))
            assert m["gauges"]["repro_server_requests"] > 0
            assert served_blocks > 0
            assert hreq["count"] > 0 and hreq["p99"] >= hreq["p50"] >= 0.0
            assert m["counters"]["repro_node_trace_requests_total"] > 0
            assert hspan["count"] > 0, "no trace-derived server-side span metric"
            per_node[idx] = {
                "requests": m["gauges"]["repro_server_requests"],
                "get_blocks": served_blocks,
                "request_p50_s": hreq["p50"],
                "request_p95_s": hreq["p95"],
                "request_p99_s": hreq["p99"],
                "traced_requests": m["counters"]["repro_node_trace_requests_total"],
                "trace_span_count": hspan["count"],
            }
        out = {
            "nodes": n_nodes,
            "load_loops": loops[0],
            "scrape_s": scrape_s,
            "live": scrape["live"],
            "down": scrape["down"],
            "per_node": per_node,
            "traced_requests_total": sum(r["traced_requests"] for r in per_node.values()),
            "trace_spans_total": sum(r["trace_span_count"] for r in per_node.values()),
        }
    finally:
        cl.close()
    if verbose:
        print(f"  observability: scraped {n_nodes} live nodes in "
              f"{1e3 * scrape_s:.1f}ms mid-load; "
              f"{out['traced_requests_total']:.0f} traced requests, "
              f"{out['trace_spans_total']:.0f} server-side spans recorded")
    return out


def run(quick: bool = False, verbose: bool = True) -> Dict:
    if verbose:
        print(" capacity scale-out (fixed per-node budget):")
    cap = capacity_sweep(
        node_counts=(1, 4) if quick else (1, 2, 4),
        repeats=3,
        verbose=verbose,
    )
    if verbose:
        print(" serving rate (streaming reads, fixed per-node budget):")
    srv = serving_sweep(
        node_counts=(1, 4) if quick else (1, 2, 4),
        n_seqs=16 if quick else 32,
        repeats=3 if quick else 5,
        verbose=verbose,
    )
    if verbose:
        print(" compression tiers (fixed raw-calibrated budget per codec):")
    comp = compression_sweep(
        node_counts=(1, 4) if quick else (1, 2, 4),
        n_seqs=48 if quick else 96,
        repeats=2 if quick else 3,
        put_repeats=2 if quick else 3,
        verbose=verbose,
    )
    fo = failover_check(verbose=verbose)
    if verbose:
        print(" elasticity (mid-run scale-out + SIGKILL repair):")
    ela = elasticity_sweep(
        n_seqs=96 if quick else 192,
        blocks_per_seq=4 if quick else 6,
        verbose=verbose,
    )
    if verbose:
        print(" observability (mid-load OP_METRICS scrape of a live cluster):")
    obs = observability_check(verbose=verbose)
    out = {"capacity": cap, "serving": srv, "compression": comp,
           "failover": fo, "elasticity": ela, "observability": obs}
    common.save_artifact("cluster", out)
    return out


def compression_smoke(verbose: bool = True) -> Dict:
    """CI-sized single-node compression check: a deliberately tight
    budget (half the raw footprint) forces raw to evict while the tiered
    policy compresses its way under the budget.  Asserts the tentpole's
    end-to-end claims at toy scale in a few seconds."""
    comp = compression_sweep(
        codecs=("raw", "tiered"),
        node_counts=(1,),
        n_seqs=12, blocks_per_seq=6, kv_bytes_per_token=512,
        budget_slack=0.55,
        repeats=1, ingest_chunks=3, put_repeats=1,
        verbose=verbose,
    )
    raw = comp["codecs"]["raw"]["nodes"][1]
    tiered = comp["codecs"]["tiered"]["nodes"][1]
    assert tiered["demoted_blocks"] > 0, "maintenance demoted nothing"
    assert tiered["tier_blocks"]["cold"] > 0, "no blocks reached the cold tier"
    assert tiered["served_fraction"] >= raw["served_fraction"], (
        f"tiered served {tiered['served_fraction']:.2%} < raw "
        f"{raw['served_fraction']:.2%} at the same budget")
    assert tiered["wire_bytes_per_served_block"] < raw["wire_bytes_per_served_block"], \
        "compressed tiers did not shrink wire bytes"
    if verbose:
        print("  compression smoke OK: tiered served "
              f"{tiered['served_fraction']:.1%} vs raw "
              f"{raw['served_fraction']:.1%} at half-footprint budget")
    return comp


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--compression-smoke", action="store_true",
                    help="single-node tiered-vs-raw check with asserts "
                         "(CI-sized; skips the full sweeps)")
    ap.add_argument("--elasticity-smoke", action="store_true",
                    help="2->3 node live scale-out + SIGKILL repair with "
                         "asserts (CI-sized; skips the full sweeps)")
    args = ap.parse_args(argv)
    if args.compression_smoke:
        compression_smoke()
        return
    if args.elasticity_smoke:
        elasticity_smoke()
        return
    run(quick=args.quick)


if __name__ == "__main__":
    main()
