"""Paper Figure 4: overall cache hit rate + TTFT, staged workload, three
backends (SGLANG-LSM / SGLang(file) / SGLang(memory)) x prompt lengths.

Claims validated (paper §4.2):
  * LSM hit rate >> file backend (paper: 45.4% vs 18.7% at 4k => +143%)
  * LSM TTFT < file backend (paper: up to -24.3% at 16k)
  * benefits grow with prompt length
"""

from __future__ import annotations

import argparse
import dataclasses
import tempfile

from . import common


def run(prompt_lens=(512, 1024), scale: common.BenchScale = None, verbose=True):
    out = {}
    for plen in prompt_lens:
        s = dataclasses.replace(scale or common.BenchScale(), prompt_len=plen)
        results = {}
        for kind in ("lsm", "file", "memory"):
            root = common.fresh_dir(tempfile.mkdtemp(prefix=f"overall_{kind}_"))
            eng = common.make_engine(root, kind, s)
            results[kind] = common.run_staged(eng, s)
        out[plen] = common.summarize(results)
        if verbose:
            print(f"\n== overall @ prompt_len={plen} ==")
            print(f"{'backend':8s} {'hit_rate':>9s} {'TTFT(s)':>9s} {'IO(s)':>9s}")
            for kind, row in out[plen].items():
                print(f"{kind:8s} {row['hit_rate']:9.3f} {row['ttft_s']:9.3f} {row['io_s']:9.4f}")
            lsm, fl = out[plen]["lsm"], out[plen]["file"]
            if fl["hit_rate"] > 0:
                print(f"   hit-rate gain vs file: {100*(lsm['hit_rate']/fl['hit_rate']-1):+.0f}%  "
                      f"TTFT delta: {100*(lsm['ttft_s']/fl['ttft_s']-1):+.1f}%")
    common.save_artifact("overall", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--prompt-lens", default="512,1024")
    ap.add_argument("--requests", type=int, default=30)
    args = ap.parse_args()
    s = common.BenchScale(requests_per_stage=args.requests)
    run(tuple(int(x) for x in args.prompt_lens.split(",")), s)


if __name__ == "__main__":
    main()
