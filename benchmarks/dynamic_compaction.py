"""Paper Figure 5(c): workload-aware dynamic compaction on/off.

Two levels of evidence:

1. serving-level (the paper's view): staged workload through the full
   engine.  At container scale the TTFT delta is within noise (the paper
   itself notes write throughput is bounded by inference latency) — we
   report it plus the controller's tuning decisions.
2. store-level: high-volume alternating write/read phases directly against
   the LSM (where compaction work actually dominates) — measures real I/O
   seconds, write amplification and compaction counts, dynamic vs static.
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
import time

import numpy as np

from repro.core.codec import CODEC_RAW, BatchCodec
from repro.core.store import KVBlockStore

from . import common


def store_phase_bench(adaptive: bool, ops_per_phase: int = 4000, seed: int = 0):
    """Alternating write-heavy / read-heavy phases straight at the store."""
    root = tempfile.mkdtemp(prefix=f"dynstore_{adaptive}_")
    store = KVBlockStore(
        os.path.join(root, "s"),
        block_size=16,
        codec=BatchCodec(CODEC_RAW, use_zlib=False),
        buffer_bytes=64 * 1024,
        adaptive=adaptive,
        controller_window=2048,
    )
    store.controller.min_ops_between_tunings = 512
    rng = np.random.default_rng(seed)
    payload = rng.standard_normal((16, 32)).astype(np.float16)  # small: index-dominant
    known = []
    t_phase = []
    phases = ("w", "r", "w", "r", "w", "r")
    for ph in phases:
        t0 = time.perf_counter()
        if ph == "w":
            for _ in range(ops_per_phase // 8):
                toks = rng.integers(0, 1 << 30, size=8 * 16).tolist()
                store.put_batch(toks, [payload] * 8)
                known.append(toks)
            store.maintenance(compact_steps=64)
        else:
            for _ in range(ops_per_phase):
                toks = known[int(rng.integers(0, len(known)))]
                n = store.probe(toks)
                if n:
                    store.get_batch(toks, min(n, 4 * 16))
        t_phase.append(time.perf_counter() - t0)
    out = {
        "phase_s": [round(t, 3) for t in t_phase],
        "total_s": round(sum(t_phase), 3),
        "write_phase_s": round(sum(t_phase[0::2]), 3),
        "read_phase_s": round(sum(t_phase[1::2]), 3),
        "compactions": store.index.stats.compactions,
        "bytes_compacted": getattr(store.index.stats, "bytes_compacted", None),
        "level_params": store.index.level_params(),
        "retunes": len(store.controller.history),
        "tunings": [{"T": e.T, "K": e.K, "mix": {k: round(v, 2) for k, v in e.mix.items()}}
                    for e in store.controller.history],
    }
    store.close()
    return out


def run(scale: common.BenchScale = None, verbose=True, reps: int = 2):
    s = scale or common.BenchScale()
    out = {}
    # alternate run order across reps to cancel disk-cache ordering noise
    for adaptive in (True, False):
        key = "dynamic" if adaptive else "static"
        ttfts, ios, hits, stages, ctl = [], [], [], None, None
        for rep in range(reps):
            root = common.fresh_dir(tempfile.mkdtemp(prefix=f"dyn_{adaptive}_{rep}_"))
            eng = common.make_engine(root, "lsm", s, adaptive=adaptive)
            stages = common.run_staged(eng, s, seed=rep)
            ctl = eng.h.store.controller
            ttfts.append(float(np.mean([st.mean_ttft_s for st in stages])))
            ios.append(float(np.mean([st.mean_io_s for st in stages])))
            hits.append(float(np.mean([st.hit_rate for st in stages])))
        out[key] = {
            "ttft_s": float(np.mean(ttfts)),
            "io_s": float(np.mean(ios)),
            "hit_rate": float(np.mean(hits)),
            "retunes": len(ctl.history),
            "tunings": [
                {"mix": ev.mix, "T": ev.T, "K": ev.K} for ev in ctl.history
            ],
            "per_stage": [st.__dict__ for st in stages],
        }
    # store-level phase benchmark (both orders to cancel cache effects)
    out["store_level"] = {
        "dynamic": store_phase_bench(True),
        "static": store_phase_bench(False),
    }
    if verbose:
        d, st = out["dynamic"], out["static"]
        print(f"serving: dynamic TTFT {d['ttft_s']:.4f}s vs static {st['ttft_s']:.4f}s "
              f"(retunes={d['retunes']})")
        sd, ss = out["store_level"]["dynamic"], out["store_level"]["static"]
        print(f"store:   dynamic {sd['total_s']:.2f}s (w {sd['write_phase_s']:.2f} r {sd['read_phase_s']:.2f}, "
              f"compactions {sd['compactions']}, tunings {sd['tunings']})")
        print(f"         static  {ss['total_s']:.2f}s (w {ss['write_phase_s']:.2f} r {ss['read_phase_s']:.2f}, "
              f"compactions {ss['compactions']})")
        if ss["total_s"] > 0:
            print(f"store-level delta: {100*(sd['total_s']/ss['total_s']-1):+.1f}%")
    common.save_artifact("dynamic_compaction", out)
    return out


if __name__ == "__main__":
    run()
