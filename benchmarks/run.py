"""Benchmark driver: ``python -m benchmarks.run`` executes every paper
table/figure at container scale plus the kernel and roofline reports.

  --quick  : smaller workloads (CI)
  --skip   : comma-separated benchmark names to skip
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip", default="", help="comma-separated benchmark names to skip")
    args = ap.parse_args(argv)
    skip = set(args.skip.split(",")) if args.skip else set()

    from . import common

    # default scale = the calibrated pressure ratios of DESIGN.md §7 (the
    # corpus/budget proportions where container-scale results track the
    # paper's regime); --full doubles the working set for stress coverage
    scale = common.BenchScale(requests_per_stage=12 if args.quick else 20,
                              corpus_size=48)

    t_all = time.time()
    print("=" * 72)
    print("SGLANG-LSM reproduction benchmarks (container scale; DESIGN.md §7)")
    print("=" * 72)

    if "overall" not in skip:
        print("\n[1/9] overall (paper Fig. 4: hit rate + TTFT, 3 backends) ...")
        from . import overall

        overall.run(prompt_lens=(512,) if args.quick else (512, 1024), scale=scale)

    if "models_case" not in skip:
        print("\n[2/9] models_case (paper Fig. 5a,b: per-model KV size sweep) ...")
        from . import models_case

        models_case.run(scale=scale)

    if "dynamic_compaction" not in skip:
        print("\n[3/9] dynamic_compaction (paper Fig. 5c: adaptive on/off) ...")
        from . import dynamic_compaction

        dynamic_compaction.run(scale=scale)

    if "store_scalability" not in skip:
        print("\n[4/9] store_scalability (paper §4.2: file-count wall) ...")
        from . import store_scalability

        store_scalability.run(n_batches=24 if args.quick else 60)
        store_scalability.shard_sweep(
            shard_counts=(1, 4) if args.quick else (1, 2, 4, 8),
            n_batches=48 if args.quick else 128,
        )

    if "store_ops" not in skip:
        print("\n[5/9] store_ops (paper App. B: put/probe/get micro) ...")
        from . import store_ops

        store_ops.run()

    if "kernels_micro" not in skip:
        print("\n[6/9] kernels_micro (Pallas kernels: HBM-traffic roofline) ...")
        from . import kernels_micro

        kernels_micro.run()

    if "roofline" not in skip:
        print("\n[7/9] roofline (dry-run artifacts -> three-term table) ...")
        from . import roofline

        roofline.run(pods=1)

    if "runtime" not in skip:
        print("\n[8/9] runtime (PR 4: parallel fan-out + pipelined engine) ...")
        import json
        import os

        from . import runtime_bench

        rt = runtime_bench.run(quick=args.quick)
        # machine-readable perf-trajectory record at the repo root: each
        # CI/bench run appends evidence that the concurrency claims hold
        fan = rt["fanout"]
        eng = rt["engine"]
        bench = {
            "benchmark": "runtime",
            "cpu_count": fan["cpu_count"],
            "fanout": {
                "n_shards": fan["n_shards"],
                "serial_loop_blocks_per_s": fan["serial_loop_blocks_per_s"],
                "threads": {
                    str(nt): {
                        "fanout_blocks_per_s": row["fanout_blocks_per_s"],
                        "speedup_vs_serial_loop": row["speedup_vs_serial_loop"],
                        "workers": row.get("workers"),
                    }
                    for nt, row in fan["threads"].items()
                },
            },
            "engine": {
                "serial_mean_ttft_s": eng["serial"]["mean_ttft_s"],
                "pipelined_mean_ttft_s": eng["pipelined"]["mean_ttft_s"],
                "ttft_improvement": eng["ttft_improvement"],
                "serial_mean_io_s": eng["serial"]["mean_io_s"],
                "pipelined_mean_io_wait_s": eng["pipelined"]["mean_io_wait_s"],
                "hit_rate": eng["pipelined"]["hit_rate"],
                "overlap_io_s": eng["overlap_io_s"],
                "serial_ttft_percentiles": eng["serial"]["ttft_percentiles"],
                "pipelined_ttft_percentiles": eng["pipelined"]["ttft_percentiles"],
            },
            "tracing_overhead": {
                "overhead_pct": rt["tracing"]["overhead_pct"],
                "min_ratio": rt["tracing"]["min_ratio"],
                "threshold_pct": rt["tracing"]["threshold_pct"],
                "pass": rt["tracing"]["pass"],
            },
        }
        root_dir = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        with open(os.path.join(root_dir, "BENCH_runtime.json"), "w") as f:
            json.dump(bench, f, indent=1)
        print(f"wrote BENCH_runtime.json (fan-out 4T "
              f"{fan['threads'].get(4, fan['threads'].get('4', {})).get('speedup_vs_serial_loop', 0):.2f}x, "
              f"pipelined TTFT {-100 * eng['ttft_improvement']:+.1f}%, "
              f"tracing overhead {rt['tracing']['overhead_pct']:+.2f}%)")
        if not rt["tracing"]["pass"]:
            # artifact is on disk for diagnosis; the run itself must fail
            raise SystemExit(
                "tracing hot-path overhead exceeds "
                f"{rt['tracing']['threshold_pct']:.0f}% "
                f"({rt['tracing']['overhead_pct']:+.2f}%)")

    if "cluster" not in skip:
        print("\n[9/9] cluster (PR 5: socket-served cache nodes, scale-out) ...")
        import json
        import os

        from . import cluster_bench

        cb = cluster_bench.run(quick=args.quick)
        cap, srv, fo = cb["capacity"], cb["serving"], cb["failover"]
        comp = cb["compression"]
        top = max(int(k) for k in cap["nodes"])
        bench = {
            "benchmark": "cluster",
            "capacity": {
                "per_node_budget_bytes": cap["per_node_budget_bytes"],
                "corpus_bytes": cap["corpus_bytes"],
                "nodes": {
                    str(n): {
                        "served_blocks_per_s": row["served_blocks_per_s"],
                        "served_fraction": row["served_fraction"],
                        "speedup": row["speedup"],
                    }
                    for n, row in cap["nodes"].items()
                },
            },
            "serving": {
                "cpu_count": srv["cpu_count"],
                "per_node_budget_bytes": srv["per_node_budget_bytes"],
                "nodes": {
                    str(n): {
                        "get_blocks_per_s": row["get_blocks_per_s"],
                        "served_fraction": row["served_fraction"],
                        "get_speedup": row["get_speedup"],
                        "time_to_first_block_s": row["time_to_first_block_s"],
                        "full_batch_get_s": row["full_batch_get_s"],
                        "ttfb_percentiles": row["ttfb_percentiles"],
                        "full_batch_percentiles": row["full_batch_percentiles"],
                        "cpu_utilization": row["cpu_utilization"],
                    }
                    for n, row in srv["nodes"].items()
                },
            },
            "compression": {
                "per_node_budget_bytes": comp["per_node_budget_bytes"],
                "raw_disk_footprint_bytes": comp["raw_disk_footprint_bytes"],
                "effective_capacity_x": comp["effective_capacity_x"],
                "put_overhead": comp["put_overhead"],
                "codecs": {
                    codec: {
                        "nodes_to_full": entry["nodes_to_full"],
                        "nodes": {
                            str(n): {
                                "served_blocks_per_s": row["served_blocks_per_s"],
                                "served_fraction": row["served_fraction"],
                                "wire_bytes_per_served_block":
                                    row["wire_bytes_per_served_block"],
                                **({"capacity_x_vs_raw": row["capacity_x_vs_raw"],
                                    "wire_ratio_vs_raw": row["wire_ratio_vs_raw"]}
                                   if "capacity_x_vs_raw" in row else {}),
                                **({"tier_blocks": row["tier_blocks"],
                                    "demoted_blocks": row["demoted_blocks"],
                                    "demote_bytes_saved": row["demote_bytes_saved"]}
                                   if "tier_blocks" in row else {}),
                            }
                            for n, row in entry["nodes"].items()
                        },
                    }
                    for codec, entry in comp["codecs"].items()
                },
            },
            "failover": {
                "replication": fo["replication"],
                "committed_blocks": fo["committed_blocks"],
                "lost_committed_blocks": fo["lost_committed_blocks"],
            },
            "observability": {
                "nodes": cb["observability"]["nodes"],
                "scrape_s": cb["observability"]["scrape_s"],
                "traced_requests_total": cb["observability"]["traced_requests_total"],
                "trace_spans_total": cb["observability"]["trace_spans_total"],
            },
        }
        root_dir = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        with open(os.path.join(root_dir, "BENCH_cluster.json"), "w") as f:
            json.dump(bench, f, indent=1)
        top_srv = max(srv["nodes"])
        srv_row = srv["nodes"][top_srv]
        ttfb = srv_row.get("time_to_first_block_s")
        full = srv_row.get("full_batch_get_s")
        ttfb_note = (f"; ttfb {1e3 * ttfb:.1f}ms vs full batch {1e3 * full:.1f}ms"
                     if ttfb is not None and full is not None else "")
        cap_x = {k: v for k, v in comp["effective_capacity_x"].items()
                 if v is not None}
        comp_note = (
            "; effective capacity "
            + ", ".join(f"{k} {v:.2f}x" for k, v in sorted(cap_x.items()))
            if cap_x else "")
        print(f"wrote BENCH_cluster.json ({top}-node served-block throughput "
              f"{cap['nodes'][top]['speedup']:.2f}x 1-node; serving "
              f"{srv_row['get_speedup']:.2f}x at fixed per-node budget"
              f"{ttfb_note}{comp_note}; failover lost "
              f"{fo['lost_committed_blocks']} committed blocks)")

    print(f"\nall benchmarks done in {time.time() - t_all:.0f}s; artifacts in benchmarks/artifacts/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
