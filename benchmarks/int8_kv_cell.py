"""§Perf iteration A4 (prototype): int8 KV cache for decode — the paper's
batch codec applied on-device.

Lowers two variants of the qwen2.5-32b-shaped decode attention tower on
the production mesh and compares roofline memory terms:

  bf16:  cache (L,B,S,KVH,Dh) bf16, chunked online-softmax readout
  int8:  cache int8 + per-(token,head) f32 scales; dequant fused into the
         per-chunk einsum (scales are 1/256 of the payload)

Run standalone (sets 512 host devices before importing jax):

    PYTHONPATH=src python -m benchmarks.int8_kv_cell
"""

import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import functools
import json


def build_and_measure():
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.hlocost import analyze_text
    from repro.launch.mesh import HBM_BW, make_production_mesh

    # qwen2.5-32b decode_32k attention dims
    L, B, S, KVH, Dh, H = 64, 128, 32768, 8, 128, 40
    G = H // KVH
    CHUNK = 1024
    F32 = jnp.float32

    def readout(q, kc, vc, kv_len, scales=None):
        """One layer's chunked attention readout; kc/vc (B,S,KVH,Dh) in
        storage dtype; scales (B,S,KVH) f32 when int8."""
        n_chunks = S // CHUNK
        kcc = kc.reshape(B, n_chunks, CHUNK, KVH, Dh).transpose(1, 0, 2, 3, 4)
        vcc = vc.reshape(B, n_chunks, CHUNK, KVH, Dh).transpose(1, 0, 2, 3, 4)
        sc = (
            scales.reshape(B, n_chunks, CHUNK, KVH).transpose(1, 0, 2, 3)
            if scales is not None
            else None
        )
        qg = q.reshape(B, 1, KVH, G, Dh)

        def step(carry, xs):
            m, l, acc, ci = carry
            if sc is None:
                kb, vb = xs
                kb = kb.astype(jnp.bfloat16)
                vb = vb.astype(jnp.bfloat16)
            else:
                kb, vb, sb = xs  # int8 + scales: dequant fused per chunk
                kb = (kb.astype(F32) * sb[..., None]).astype(jnp.bfloat16)
                vb = (vb.astype(F32) * sb[..., None]).astype(jnp.bfloat16)
            s = jnp.einsum("bskgd,btkd->bkgst", qg, kb, preferred_element_type=F32)
            s = s * (Dh**-0.5)
            pos = ci * CHUNK + jnp.arange(CHUNK)
            s = jnp.where((pos[None, :] < kv_len[:, None])[:, None, None, None, :], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p.astype(jnp.bfloat16), vb, preferred_element_type=F32
            )
            return (m_new, l_new, acc_new, ci + 1), None

        m0 = jnp.full((B, KVH, G, 1), -jnp.inf, F32)
        l0 = jnp.zeros((B, KVH, G, 1), F32)
        a0 = jnp.zeros((B, KVH, G, 1, Dh), F32)
        xs = (kcc, vcc) if sc is None else (kcc, vcc, sc)
        (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)), xs)
        return (acc / jnp.maximum(l, 1e-30)[..., None]).reshape(B, H, Dh)

    def tower(q, k_all, v_all, kv_len, s_all=None):
        def body(out, xs):
            if s_all is None:
                kc, vc = xs
                return out + readout(q, kc, vc, kv_len), None
            kc, vc, sc = xs
            return out + readout(q, kc, vc, kv_len, sc), None

        out0 = jnp.zeros((B, H, Dh), F32)
        xs = (k_all, v_all) if s_all is None else (k_all, v_all, s_all)
        out, _ = jax.lax.scan(body, out0, xs)
        return out

    mesh = make_production_mesh(multi_pod=False)
    chips = mesh.devices.size
    batch_sh = NamedSharding(mesh, P(("pod", "data") if "pod" in mesh.axis_names else "data"))
    cache_sh = NamedSharding(mesh, P(None, "data", None, None, "model"))  # Dh-sharded
    scale_sh = NamedSharding(mesh, P(None, "data", None, None))
    q_sh = NamedSharding(mesh, P("data", None, "model"))  # H=40 doesn't divide 16; shard Dh

    q = jax.ShapeDtypeStruct((B, H, Dh), jnp.bfloat16)
    lens = jax.ShapeDtypeStruct((B,), jnp.int32)
    results = {}
    for kind in ("bf16", "int8"):
        dt = jnp.bfloat16 if kind == "bf16" else jnp.int8
        kv = jax.ShapeDtypeStruct((L, B, S, KVH, Dh), dt)
        args = [q, kv, kv, lens]
        in_sh = [q_sh, cache_sh, cache_sh, NamedSharding(mesh, P())]
        if kind == "int8":
            args.append(jax.ShapeDtypeStruct((L, B, S, KVH), jnp.float32))
            in_sh.append(scale_sh)
        with mesh:
            fn = tower if kind == "bf16" else (lambda q, k, v, n, s: tower(q, k, v, n, s))
            compiled = jax.jit(fn, in_shardings=tuple(in_sh)).lower(*args).compile()
        t = analyze_text(compiled.as_text())
        results[kind] = {
            "bytes_per_device": t.bytes,
            "memory_s": t.bytes / HBM_BW,
            "collective_bytes": t.collective_bytes,
        }
    results["memory_reduction"] = results["bf16"]["memory_s"] / results["int8"]["memory_s"]
    return results


def main():
    r = build_and_measure()
    print(f"bf16 cache readout: memory {r['bf16']['memory_s']*1e3:8.1f} ms/device")
    print(f"int8 cache readout: memory {r['int8']['memory_s']*1e3:8.1f} ms/device")
    print(f"int8 KV memory-term reduction: {r['memory_reduction']:.2f}x")
    art = os.path.join(os.path.dirname(__file__), "artifacts", "int8_kv_cell.json")
    os.makedirs(os.path.dirname(art), exist_ok=True)
    with open(art, "w") as f:
        json.dump(r, f, indent=1)


if __name__ == "__main__":
    main()
