"""``ClusterKVBlockStore`` — one ``StorageBackend`` over N remote cache
nodes, routed by a consistent-hash ring.

This is the cross-process analogue of ``ShardedKVBlockStore``: the same
first-block routing hash places every extension of a prefix on the same
node (probes and range scans stay node-local), but placement goes
through a ``HashRing`` instead of ``hash % N`` so membership changes
only remap the failed/joined node's arcs.

Replication and failover:

* ``replication = R`` writes every put to the first R *live* nodes of
  the key's ring preference list.  When a node dies mid-write the put
  slides to the next live node — the cluster degrades to serving with
  R copies among the survivors rather than refusing writes.
* Reads consult the first R live preference nodes and take the best
  answer (probe: max prefix; get: longest block run), so a node that
  missed writes while down — or came back with a cold store — can never
  shorten the answer below what a surviving replica holds.  With R ≥ 2
  a single node failure therefore loses **zero committed blocks**.
* A node that fails an RPC (after the client's retries) is marked
  *down*: routing filters it out everywhere until ``refresh_nodes``
  (called from every ``maintenance`` cycle, or explicitly) pings it
  back.  Rejoin is a pure membership flip — the ring never rehashes, so
  the rejoined node resumes exactly its old arcs (LMCache-style cache
  cluster semantics: nodes are cache, the engine recomputes true
  misses, so rebalance never blocks serving).

Elastic membership (``add_node`` / ``remove_node``):

* A membership change builds a **new** ring and holds both rings as a
  ``TransitionView``: writes target the new owners immediately, reads
  consult the new owners *and* the old owners, so every key is served
  from wherever it currently lives while the move is in flight.
* The attached ``BlockMigrator`` (``cluster.migration``) copies exactly
  the moved ring arcs — and re-replicates arcs that lost a copy to a
  death, when R >= 2 — on the maintenance cadence, shipping blocks in
  their stored encoding.  When the copy drains, the old ring is dropped
  (and a removed node retired from routing).
* Node identity is the ring's vocabulary: routing maps ring node *ids*
  through a stable id->client index, so client slots are append-only
  and an index never changes meaning mid-flight.

Fan-out reuses the grouped-parallel machinery of the sharded store: the
multi-sequence ops group positions by replica set and run the groups
concurrently on an ``IOExecutor``, each group riding the client's
batched RPCs (one round trip per node per group).

Because this class satisfies the ``StorageBackend`` protocol,
``CacheHierarchy``, ``ServingEngine``, the write-behind ``CommitQueue``,
and ``MaintenanceService`` work against a cluster unchanged.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.backend import merge_stats
from ..core.store import StoreStats
from ..obs import MetricsRegistry, dataclass_gauges
from ..runtime.executor import IOExecutor
from .client import NodeUnavailable, RemoteKVBlockStore
from .migration import BlockMigrator
from .mux import MuxLoop
from .ring import HashRing, TransitionView, affected_arcs, key_hash
from .server import Address


@dataclass
class ClusterStats:
    failovers: int = 0  # reads answered by a non-primary replica
    degraded_reads: int = 0  # reads served while >=1 preferred node was down
    marked_down: int = 0
    revived: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class ClusterKVBlockStore:
    """Consistent-hash routed, replicated client over N cache nodes."""

    name = "cluster"

    def __init__(
        self,
        nodes: Sequence[Union[RemoteKVBlockStore, Address]],
        replication: int = 1,
        block_size: Optional[int] = None,
        vnodes: int = 64,
        io_threads: int = 0,
        io_executor: Optional[IOExecutor] = None,
        node_ids: Optional[Sequence[str]] = None,
        **client_kwargs,
    ):
        """``nodes`` are connected clients or addresses (clients are then
        constructed here with ``client_kwargs``).  ``replication`` is
        clamped to the cluster size; R >= 2 survives single-node loss with
        zero lost committed blocks.

        ``node_ids`` are the stable logical identities hashed onto the
        ring (defaults to ``str(address)``).  Deployments should pass
        durable names: ring placement then survives a node coming back
        on a different port/host, and is reproducible across runs."""
        if not nodes:
            raise ValueError("cluster needs at least one node")
        # one selector thread services every node connection's read side:
        # client-side concurrency is "requests in flight", not threads
        self._mux_loop: Optional[MuxLoop] = None
        if any(not isinstance(n, RemoteKVBlockStore) for n in nodes) and (
            "mux_loop" not in client_kwargs
        ):
            self._mux_loop = MuxLoop()
            client_kwargs = dict(client_kwargs, mux_loop=self._mux_loop)
        self.nodes: List[RemoteKVBlockStore] = []
        for n in nodes:
            if isinstance(n, RemoteKVBlockStore):
                self.nodes.append(n)
            else:
                self.nodes.append(
                    RemoteKVBlockStore(n, block_size=block_size, **client_kwargs)
                )
                block_size = block_size or self.nodes[-1].block_size
        sizes = {c.block_size for c in self.nodes}
        if len(sizes) != 1:
            raise ValueError(f"nodes disagree on block_size: {sorted(sizes)}")
        self.block_size = sizes.pop()
        # retained so later add_node calls build clients the same way, and
        # so replication re-expands when the cluster grows past it
        self._client_kwargs = dict(client_kwargs)
        self._requested_replication = max(1, replication)
        self.replication = max(1, min(replication, len(self.nodes)))
        if node_ids is None:
            node_ids = [str(c.address) for c in self.nodes]
        if len(node_ids) != len(self.nodes) or len(set(node_ids)) != len(node_ids):
            raise ValueError("node_ids must be unique, one per node")
        self.ring = HashRing(list(node_ids), vnodes=vnodes)
        # ring node id -> index into self.nodes.  Client slots are
        # append-only (removed nodes are *retired*, never popped), so an
        # index keeps its meaning across membership changes.
        self._node_index: Dict[str, int] = {nid: i for i, nid in enumerate(node_ids)}
        self.cluster_stats = ClusterStats()
        self._down: set = set()
        self._retired: set = set()
        self._pending_retire: set = set()
        self._down_since: Dict[int, float] = {}  # mark-down monotonic stamps
        self._last_repaired: frozenset = frozenset()
        self._old_ring: Optional[HashRing] = None
        self._transition: Optional[TransitionView] = None
        self._lock = threading.Lock()
        self.migrator = BlockMigrator(self)
        if io_executor is not None:
            self._executor, self._owns_executor = io_executor, False
        elif io_threads > 0:
            # RPC workers block on sockets with the GIL released, so the
            # pool may be wider than the core count (see IOExecutor)
            self._executor = IOExecutor(max_workers=io_threads, cap_to_cpu=False)
            self._owns_executor = True
        else:
            self._executor, self._owns_executor = None, False
        # client-side registry: cluster routing counters plus the summed
        # per-node transport view; node-side metrics ride scrape_cluster()
        self.registry = MetricsRegistry()
        self.registry.register_collector(
            dataclass_gauges("repro_cluster", self.cluster_stats, lock=self._lock,
                             extra=lambda: {
                                 "repro_cluster_nodes": float(len(self.nodes)),
                                 "repro_cluster_live": float(len(self.live_nodes)),
                                 "repro_cluster_replication": float(self.replication),
                             }))
        self.registry.register_collector(
            dataclass_gauges("repro_migration", self.migrator.stats, lock=self._lock,
                             extra=lambda: {
                                 "repro_migration_active": float(self.migrator.active),
                             }))
        self.registry.register_collector(self._rpc_gauges)

    def _rpc_gauges(self) -> Dict[str, float]:
        """Collector: every client's transport stats summed as
        ``repro_rpc_*`` gauges (per-node splits come from the scrape)."""
        out: Dict[str, float] = {}
        for c in self.nodes:
            for k, v in vars(c.rpc_stats).items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    out[f"repro_rpc_{k}"] = out.get(f"repro_rpc_{k}", 0.0) + float(v)
        return out

    # -------------------------------------------------------------- routing
    def _pref_indices(self, khash: int, ring: Optional[HashRing] = None) -> List[int]:
        """A ring's preference list mapped from ring-local indices to
        cluster node indices via node id (ids are the stable vocabulary —
        two rings of different membership agree on them)."""
        ring = ring or self.ring
        return [self._node_index[ring.node_ids[i]] for i in ring.preference(khash)]

    def _live_pref_hash(self, khash: int, read: bool = False) -> List[int]:
        pref = self._pref_indices(khash)
        with self._lock:
            dead = self._down | self._retired
        live = [i for i in pref if i not in dead]
        if not live:
            raise NodeUnavailable("every replica for this key range is down")
        if read and any(i in dead for i in pref[: self.replication]):
            with self._lock:
                self.cluster_stats.degraded_reads += 1
        return live

    def _live_pref(self, tokens: Sequence[int], read: bool = False) -> List[int]:
        """Current-ring preference order with down/retired nodes filtered
        out.  ``read`` marks the call as a read for the degraded-read
        counter (a read whose *ideal* replica set had a down member is
        served, but with less redundancy than configured)."""
        return self._live_pref_hash(key_hash(tokens, self.block_size), read=read)

    def _read_replicas(self, tokens: Sequence[int]) -> List[int]:
        """The node indices a read should consult: the first R live nodes
        of the current ring — plus, during a membership transition, the
        first R live *old-ring* owners, so a key not yet migrated is
        still served from where it lives.  Order is new owners first
        (they are the steady-state answer and warm up as the migrator
        fills them)."""
        khash = key_hash(tokens, self.block_size)
        out = self._live_pref_hash(khash, read=True)[: self.replication]
        old = self._old_ring
        if old is not None:
            with self._lock:
                dead = self._down | self._retired
            old_pref = [i for i in self._pref_indices(khash, old) if i not in dead]
            for i in old_pref[: self.replication]:
                if i not in out:
                    out.append(i)
        return out

    def replicas_for(self, tokens: Sequence[int]) -> List[int]:
        """The node indices a put of ``tokens`` targets right now."""
        return self._live_pref(tokens)[: self.replication]

    def mark_down(self, idx: int) -> None:
        with self._lock:
            if idx not in self._down:
                self._down.add(idx)
                self._down_since.setdefault(idx, time.monotonic())
                self.cluster_stats.marked_down += 1

    @property
    def down_nodes(self) -> List[int]:
        with self._lock:
            return sorted(self._down)

    @property
    def live_nodes(self) -> List[int]:
        with self._lock:
            return [
                i for i in range(len(self.nodes))
                if i not in self._down and i not in self._retired
            ]

    @property
    def retired_nodes(self) -> List[int]:
        with self._lock:
            return sorted(self._retired)

    def refresh_nodes(self) -> List[int]:
        """Ping every down node; revive the ones that answer.  Returns the
        revived indices.  Rejoin is a membership flip only — rings never
        rehash, so the node resumes its current arcs immediately."""
        revived = []
        with self._lock:
            down = sorted(self._down - self._retired)
        for i in down:
            if self.nodes[i].ping():
                with self._lock:
                    self._down.discard(i)
                    self._down_since.pop(i, None)
                    # membership of the live set changed: future deaths
                    # must re-trigger repair even for a previously
                    # repaired down-set
                    self._last_repaired = frozenset()
                    self.cluster_stats.revived += 1
                revived.append(i)
        return revived

    # --------------------------------------------------- elastic membership
    @property
    def in_transition(self) -> bool:
        return self._transition is not None

    def add_node(
        self,
        node: Union[RemoteKVBlockStore, Address],
        node_id: Optional[str] = None,
    ) -> int:
        """Join a node to the cluster.  Returns its index.  Writes route
        to the grown ring immediately; the migrator copies the moved arcs
        on the maintenance cadence, and reads consult both rings until it
        finishes."""
        if isinstance(node, RemoteKVBlockStore):
            client = node
        else:
            client = RemoteKVBlockStore(
                node, block_size=self.block_size, **self._client_kwargs
            )
        if client.block_size != self.block_size:
            raise ValueError(
                f"node block_size {client.block_size} != cluster {self.block_size}"
            )
        nid = node_id if node_id is not None else str(client.address)
        with self._lock:
            if nid in self._node_index:
                raise ValueError(f"duplicate node id {nid!r}")
            self.nodes.append(client)
            idx = len(self.nodes) - 1
            self._node_index[nid] = idx
        new_ring = HashRing(list(self.ring.node_ids) + [nid], vnodes=self.ring.vnodes)
        self._begin_transition(new_ring)
        return idx

    def remove_node(self, node: Union[int, str]) -> int:
        """Drain a node out of the cluster (by index or ring id).  The
        node keeps serving reads as an old-ring owner — and acts as a
        migration source — until its arcs have been copied off; then it
        is retired from routing.  Returns its index."""
        with self._lock:
            if isinstance(node, str):
                if node not in self._node_index:
                    raise ValueError(f"unknown node id {node!r}")
                nid, idx = node, self._node_index[node]
            else:
                idx = int(node)
                ids = [k for k, v in self._node_index.items() if v == idx]
                if not ids:
                    raise ValueError(f"unknown node index {idx}")
                nid = ids[0]
            if nid not in self.ring.node_ids:
                raise ValueError(f"node {nid!r} is not a ring member")
            if len(self.ring) <= 1:
                raise ValueError("cannot remove the last node")
            self._pending_retire.add(idx)
        new_ring = HashRing(
            [n for n in self.ring.node_ids if n != nid], vnodes=self.ring.vnodes
        )
        self._begin_transition(new_ring)
        return idx

    def _begin_transition(self, new_ring: HashRing) -> None:
        """Swap to ``new_ring`` and (re)start the rebalance.  A change
        arriving mid-transition folds in: the *original* ring stays the
        old/read view, so keys still un-migrated from it are never
        orphaned, and the migrator restarts against the union of moved
        arcs."""
        with self._lock:
            base = self._old_ring if self._old_ring is not None else self.ring
            self.ring = new_ring
            self.replication = max(
                1, min(self._requested_replication, len(new_ring))
            )
            self._old_ring = base
            self._transition = TransitionView(base, new_ring, self.replication)
        self.migrator.begin_rebalance(self._transition)

    def _complete_transition(self) -> None:
        """Called by the migrator when the rebalance copy has drained:
        drop the old ring and retire any removed nodes from routing."""
        with self._lock:
            self._old_ring = None
            self._transition = None
            self._retired |= self._pending_retire
            self._pending_retire = set()
            self._down -= self._retired
            for i in self._retired:
                self._down_since.pop(i, None)

    def _note_repaired(self, downset: frozenset) -> None:
        with self._lock:
            self._last_repaired = frozenset(downset)

    def migrate_step(self, max_pages: Optional[int] = None) -> dict:
        """One unit of background data movement, driven from every
        ``maintenance`` cycle.  Rebalance tasks (membership changes) are
        started by ``_begin_transition``; this is also where a death is
        noticed and a repair task launched: with R >= 2, arcs whose
        replica set includes a down node are re-copied from the survivors
        so the cluster returns to full replication."""
        if (
            not self.migrator.active
            and self._transition is None
            and self.replication >= 2
        ):
            with self._lock:
                down_members = frozenset(
                    i for i in self._down
                    if i not in self._retired and i not in self._pending_retire
                )
                already = self._last_repaired
            if down_members and down_members != already:
                ids = [
                    nid for nid, i in self._node_index.items() if i in down_members
                ]
                arcs = affected_arcs(self.ring, ids, self.replication)
                with self._lock:
                    stamps = [
                        self._down_since[i] for i in down_members
                        if i in self._down_since
                    ]
                down_t0 = min(stamps) if stamps else None
                self.migrator.begin_repair(down_members, arcs, down_t0)
        if self.migrator.active:
            return self.migrator.step(max_pages)
        return {"active": False}

    # ----------------------------------------------------- single-key ops
    def put_batch(
        self,
        tokens: Sequence[int],
        blocks: Sequence[np.ndarray],
        start_block: int = 0,
        skip_existing: bool = True,
    ) -> int:
        """Write to the first R live preference nodes; a mid-write failure
        marks the node down and slides to the next live node, so the put
        keeps R copies among survivors whenever possible."""
        written: List[int] = []
        for idx in self._live_pref(tokens):
            if len(written) >= self.replication:
                break
            try:
                written.append(
                    self.nodes[idx].put_batch(
                        tokens, blocks, start_block=start_block,
                        skip_existing=skip_existing,
                    )
                )
            except NodeUnavailable:
                self.mark_down(idx)
        if not written:
            raise NodeUnavailable("no replica accepted the write")
        return max(written)

    def probe(self, tokens: Sequence[int]) -> int:
        """Max contiguous prefix over the first R live replicas (a replica
        that was down for some writes can only under-report; max restores
        the survivors' view)."""
        best = 0
        full = (len(tokens) // self.block_size) * self.block_size
        for rank, idx in enumerate(self._read_replicas(tokens)):
            try:
                got = self.nodes[idx].probe(tokens)
            except NodeUnavailable:
                self.mark_down(idx)
                continue
            if rank > 0 and got > best:
                with self._lock:
                    self.cluster_stats.failovers += 1
            best = max(best, got)
            if best >= full:
                break
        return best

    def get_batch(self, tokens: Sequence[int], n_tokens: int) -> List[np.ndarray]:
        best: List[np.ndarray] = []
        want_blocks = n_tokens // self.block_size
        for rank, idx in enumerate(self._read_replicas(tokens)):
            try:
                got = self.nodes[idx].get_batch(tokens, n_tokens)
            except NodeUnavailable:
                self.mark_down(idx)
                continue
            if len(got) > len(best):
                if rank > 0:
                    with self._lock:
                        self.cluster_stats.failovers += 1
                best = got
            if len(best) >= want_blocks:
                break
        return best

    def get_batch_stream(self, tokens: Sequence[int], n_tokens: int) -> "ClusterBlockStream":
        """Streaming read with mid-stream failover: blocks are yielded as
        they arrive from the primary replica; if the stream breaks after
        ``k`` blocks, the next live replica resumes — blocks are
        content-addressed, so replica ``r``'s block ``k`` is bit-identical
        to the dead primary's and the stitched prefix stays exact.  A
        short stream is a short *prefix*, never a hole: the consumer
        commits exactly the blocks it received."""
        return ClusterBlockStream(self, tokens, n_tokens)

    # ------------------------------------------------------------- fan-out
    def _groups(
        self, seqs: Sequence[Sequence[int]], read: bool = False
    ) -> Dict[Tuple[int, ...], List[int]]:
        """Positions grouped by their current replica tuple; one group =
        one batched RPC per replica node.  Reads go through the
        transition-aware replica set so in-flight migrations never hide
        a key."""
        groups: Dict[Tuple[int, ...], List[int]] = {}
        for pos, tokens in enumerate(seqs):
            if read:
                key = tuple(self._read_replicas(tokens))
            else:
                key = tuple(self._live_pref(tokens)[: self.replication])
            groups.setdefault(key, []).append(pos)
        return groups

    def _run_groups(self, groups, task) -> None:
        """Run ``task(replicas, positions)`` for every group, in parallel
        on the executor when one is attached: one batched RPC per node
        per group.  Keeping whole groups in single round trips beats
        chunking them across pooled connections — per-RPC costs (frame
        handling, executor handoff, syscalls) outweigh the intra-node
        pipelining that smaller chunks would buy."""
        items = list(groups.items())
        if self._executor is not None and len(items) > 1:
            self._executor.map_parallel(lambda kv: task(kv[0], kv[1]), items)
            return
        for replicas, positions in items:
            task(replicas, positions)

    def probe_many(self, seqs: Sequence[Sequence[int]]) -> List[int]:
        out = [0] * len(seqs)

        def task(replicas: Tuple[int, ...], positions: List[int]) -> None:
            batch = [seqs[p] for p in positions]
            answered = False
            for rank, idx in enumerate(replicas):
                try:
                    res = self.nodes[idx].probe_many(batch)
                except NodeUnavailable:
                    self.mark_down(idx)
                    continue
                for p, got in zip(positions, res):
                    if rank > 0 and got > out[p]:
                        with self._lock:
                            self.cluster_stats.failovers += 1
                    out[p] = max(out[p], got)
                answered = True
            if not answered:  # whole replica tuple went down: re-route
                for p in positions:
                    out[p] = self.probe(seqs[p])

        self._run_groups(self._groups(seqs, read=True), task)
        return out

    def get_many(
        self, items: Sequence[Tuple[Sequence[int], int]]
    ) -> List[List[np.ndarray]]:
        out: List[List[np.ndarray]] = [[] for _ in items]

        def task(replicas: Tuple[int, ...], positions: List[int]) -> None:
            pending = list(positions)
            for rank, idx in enumerate(replicas):
                if not pending:
                    return
                batch = [(items[p][0], items[p][1]) for p in pending]
                try:
                    res = self.nodes[idx].get_many(batch)
                except NodeUnavailable:
                    self.mark_down(idx)
                    continue
                still = []
                for p, got in zip(pending, res):
                    if len(got) > len(out[p]):
                        if rank > 0:
                            with self._lock:
                                self.cluster_stats.failovers += 1
                        out[p] = got
                    if len(out[p]) < items[p][1] // self.block_size:
                        still.append(p)  # deficient: ask the next replica
                pending = still
            for p in pending:  # replica tuple exhausted: re-route fully
                got = self.get_batch(items[p][0], items[p][1])
                if len(got) > len(out[p]):
                    out[p] = got

        self._run_groups(self._groups([t for t, _ in items], read=True), task)
        return out

    def put_many(
        self, items: Sequence[Tuple[Sequence[int], Sequence[np.ndarray], int]]
    ) -> List[int]:
        out = [0] * len(items)

        def task(replicas: Tuple[int, ...], positions: List[int]) -> None:
            batch = [items[p] for p in positions]
            successes = 0
            for idx in replicas:
                try:
                    res = self.nodes[idx].put_many(batch)
                except NodeUnavailable:
                    self.mark_down(idx)
                    continue
                for p, wrote in zip(positions, res):
                    out[p] = max(out[p], wrote)
                successes += 1
            if successes < self.replication and len(self.live_nodes) > successes:
                # a replica died mid-batch: slide to the next live
                # preference nodes (put_batch recomputes them; surviving
                # copies dedup via skip_existing) so the batch keeps R
                # copies among survivors — same contract as put_batch
                for p in positions:
                    t, bs, s = items[p]
                    out[p] = max(out[p], self.put_batch(t, bs, start_block=s))

        self._run_groups(self._groups([t for t, _, _ in items]), task)
        return out

    # ---------------------------------------------------------- maintenance
    def maintenance(self, compact_steps: int = 8) -> dict:
        """Fan one maintenance cycle out to every live node (parallel when
        an executor is attached) and piggyback down-node rejoin checks —
        the cadence the serving engine already drives.

        Ordering matters: migration runs *before* the per-node fan-out so
        freshly copied blocks land at their destinations before those
        nodes enforce their budgets (a block is never evicted in the same
        cycle it arrives), and a source cannot evict-then-copy within one
        cycle."""
        revived = self.refresh_nodes()
        mig = self.migrate_step()
        live = self.live_nodes
        rep: dict = {"compactions": 0, "nodes": {}, "revived": revived,
                     "down": self.down_nodes, "migration": mig}

        def one(i: int) -> Optional[dict]:
            try:
                return self.nodes[i].maintenance(compact_steps)
            except NodeUnavailable:
                self.mark_down(i)
                return None

        if self._executor is not None and len(live) > 1:
            reports = self._executor.map_parallel(one, live)
        else:
            reports = [one(i) for i in live]
        for i, nrep in zip(live, reports):
            if nrep is None:
                continue
            rep["nodes"][i] = nrep
            rep["compactions"] += nrep.get("compactions", 0)
        return rep

    # ------------------------------------------------------------ lifecycle
    def flush(self) -> None:
        for i in self.live_nodes:
            try:
                self.nodes[i].flush()
            except NodeUnavailable:
                self.mark_down(i)

    def close(self) -> None:
        """Close the client connections; node processes are owned by their
        spawner and stay up."""
        if self._owns_executor and self._executor is not None:
            self._executor.close()
        for c in self.nodes:
            c.close()
        if self._mux_loop is not None:
            self._mux_loop.close()

    # ---------------------------------------------------------------- stats
    def _sum_live(self, attr: str) -> int:
        total = 0
        for i in self.live_nodes:
            try:
                total += getattr(self.nodes[i], attr)
            except NodeUnavailable:
                self.mark_down(i)
        return total

    @property
    def stats(self) -> StoreStats:
        parts = []
        for i in self.live_nodes:
            try:
                parts.append(self.nodes[i].stats)
            except NodeUnavailable:
                self.mark_down(i)
        return merge_stats(parts)

    @property
    def disk_bytes(self) -> int:
        return self._sum_live("disk_bytes")

    @property
    def file_count(self) -> int:
        return self._sum_live("file_count")

    def node_reports(self) -> Dict[int, dict]:
        """Raw per-node reports — backend stats, server transport
        counters, and this side's client transport view.  Unreachable
        nodes are marked down and omitted."""
        out: Dict[int, dict] = {}
        for i in self.live_nodes:
            try:
                out[i] = self.nodes[i].node_report()
            except NodeUnavailable:
                self.mark_down(i)
        return out

    def report(self, include_nodes: bool = True) -> dict:
        """Cluster-level telemetry: membership, failover counters, the
        per-client transport stats, and (by default) a compact per-node
        backend/server summary aggregated from each node's STATS."""
        rep = {
            "n_nodes": len(self.nodes),
            "replication": self.replication,
            "live": self.live_nodes,
            "down": self.down_nodes,
            "retired": self.retired_nodes,
            "in_transition": self.in_transition,
            "cluster": self.cluster_stats.as_dict(),
            "migration": self.migrator.stats.as_dict(),
            "rpc": {i: c.rpc_stats.as_dict() for i, c in enumerate(self.nodes)},
        }
        if include_nodes:
            nodes = {}
            for i, nrep in self.node_reports().items():
                st, srv = nrep.get("stats", {}), nrep.get("server", {})
                nodes[i] = {
                    "name": nrep.get("name"),
                    "disk_bytes": nrep.get("disk_bytes"),
                    "file_count": nrep.get("file_count"),
                    "get_blocks": st.get("get_blocks"),
                    "put_blocks": st.get("put_blocks"),
                    "raw_gets": st.get("raw_gets"),
                    "streams": srv.get("streams"),
                    "stream_chunks": srv.get("stream_chunks"),
                    "sendfile_bytes": srv.get("sendfile_bytes"),
                }
            rep["nodes"] = nodes
        return rep

    def scrape_cluster(self) -> dict:
        """One aggregated metrics scrape of the whole cluster.

        Every node contributes its full ``OP_METRICS`` snapshot
        (counters, gauges, latency histograms, recent traces).  A node
        that cannot be reached is *reported*, never waited on past the
        client timeout: already-down nodes are skipped without an RPC,
        and a node that fails mid-scrape is marked down and recorded as
        ``{"unreachable": True, "error": ...}`` — the scrape itself
        always succeeds.  The client-side view (routing + transport
        registry) rides along under ``"cluster"``."""
        nodes: Dict[int, dict] = {}
        down = set(self.down_nodes)
        retired = set(self.retired_nodes)
        for i, client in enumerate(self.nodes):
            if i in retired:
                nodes[i] = {"retired": True}
                continue
            if i in down:
                nodes[i] = {"unreachable": True, "error": "marked down"}
                continue
            try:
                nodes[i] = client.metrics()
            except NodeUnavailable as e:
                self.mark_down(i)
                nodes[i] = {"unreachable": True, "error": str(e)}
        return {
            "nodes": nodes,
            "live": self.live_nodes,
            "down": self.down_nodes,
            "retired": self.retired_nodes,
            "cluster": self.registry.snapshot(),
        }


class ClusterBlockStream:
    """Iterator over one sequence's blocks, stitched across replicas on
    mid-stream failure.  ``first_block_s`` is time-to-first-block from
    construction; ``served`` counts blocks yielded; ``failovers`` counts
    replica switches that contributed blocks."""

    def __init__(self, store: ClusterKVBlockStore, tokens: Sequence[int], n_tokens: int):
        self._store = store
        self._tokens = list(tokens)
        self._n_tokens = int(n_tokens)
        self._t0 = time.perf_counter()
        self.first_block_s: Optional[float] = None
        self.served = 0
        self.failovers = 0

    def __iter__(self) -> Iterator[np.ndarray]:
        store = self._store
        want = self._n_tokens // store.block_size
        if want == 0:
            return
        replicas = store._read_replicas(self._tokens)
        for rank, idx in enumerate(replicas):
            if self.served >= want:
                return
            contributed = False
            try:
                node_stream = store.nodes[idx].get_batch_stream(
                    self._tokens, self._n_tokens
                )
                # a later replica re-streams from block 0; skip what was
                # already yielded (content addressing: identical bytes)
                skip = self.served
                for b in node_stream:
                    if skip:
                        skip -= 1
                        continue
                    if rank > 0 and not contributed:
                        contributed = True
                        self.failovers += 1
                        with store._lock:
                            store.cluster_stats.failovers += 1
                    if self.first_block_s is None:
                        self.first_block_s = time.perf_counter() - self._t0
                    self.served += 1
                    yield b
                if self.served >= want:
                    return
                # clean but short: a cold replica may still extend the run
            except NodeUnavailable:
                store.mark_down(idx)
                continue
        # replicas exhausted: the stream ends as a (possibly short) prefix
