"""``CacheNodeServer`` — one cache node: a socket front-end over any
thread-safe ``StorageBackend``.

The server is a thin RPC shim, deliberately: every byte of storage logic
stays in the backend (which already carries the ``core/backend.py``
thread-safety contract), so a node is "an existing store, served".

Architecture (one node):

    acceptor/selector thread          IOExecutor (N workers)
    ─────────────────────────────────────────────────────────
    accept, read socket bytes,   ──►  decode request
    reassemble frames                 run the backend op
    (non-blocking, all conns)         send the response frame
                                 ◄──  re-arm the connection

A connection is *unregistered* from the selector while its request is
being served and re-armed afterwards, so one connection has at most one
request in flight (matching the synchronous client) and response writes
never interleave.  Requests from *different* connections run
concurrently on the executor — the same bounded pool discipline as the
in-process runtime layer: when all workers are busy the selector thread
blocks on admission, which backpressures every client instead of
queueing unboundedly.

Transports: TCP (``host``/``port``) or ``AF_UNIX`` (``unix_path``) — the
frame protocol is transport-agnostic.
"""

from __future__ import annotations

import os
import selectors
import socket
import threading
from dataclasses import dataclass
from typing import Optional, Tuple, Union

from ..core.store import StoreStats
from ..runtime.executor import IOExecutor
from . import protocol as P

Address = Union[Tuple[str, int], str]  # (host, port) or unix socket path


@dataclass
class ServerStats:
    connections_accepted: int = 0
    connections_open: int = 0
    requests: int = 0
    errors: int = 0  # backend/op failures reported to the client
    protocol_errors: int = 0  # malformed frames (connection dropped)
    bytes_in: int = 0
    bytes_out: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class _Conn:
    __slots__ = ("sock", "buf", "alive")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.buf = bytearray()
        self.alive = True


class CacheNodeServer:
    def __init__(
        self,
        backend,
        host: str = "127.0.0.1",
        port: int = 0,
        unix_path: Optional[str] = None,
        io_threads: int = 2,
        io_executor: Optional[IOExecutor] = None,
        max_frame_bytes: int = P.MAX_FRAME_BYTES,
        send_timeout_s: float = 30.0,
    ):
        """``send_timeout_s`` bounds response writes: a client that stops
        reading (stalled, hostile) gets dropped instead of wedging an
        executor worker forever — with a small pool, unbounded sends
        would eventually wedge every worker and stop the whole node."""
        self.backend = backend
        self.max_frame_bytes = max_frame_bytes
        self.send_timeout_s = send_timeout_s
        self.stats = ServerStats()
        self._stats_lock = threading.Lock()
        if io_executor is not None:
            self._executor, self._owns_executor = io_executor, False
        else:
            # handlers are short (one request), so pending-job admission can
            # be generous: stalls mean every worker is mid-request already
            self._executor = IOExecutor(max_workers=max(1, io_threads), max_pending=64)
            self._owns_executor = True
        if unix_path is not None:
            self._listener = socket.socket(socket.AF_UNIX)
            if os.path.exists(unix_path):
                os.unlink(unix_path)
            self._listener.bind(unix_path)
            self.address: Address = unix_path
        else:
            self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self._listener.bind((host, port))
            self.address = self._listener.getsockname()
        self._listener.listen(128)
        self._listener.setblocking(False)
        self._selector = selectors.DefaultSelector()
        self._selector.register(self._listener, selectors.EVENT_READ, "accept")
        # self-pipe so executor workers can wake the selector to re-arm conns
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._selector.register(self._wake_r, selectors.EVENT_READ, "wake")
        self._rearm: list = []
        self._rearm_lock = threading.Lock()
        self._conns: set = set()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, name="cache-node", daemon=True)

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "CacheNodeServer":
        self._thread.start()
        return self

    def close(self) -> None:
        self._stop.set()
        self._wake()
        self._thread.join(timeout=10)
        for conn in list(self._conns):
            self._drop(conn, unregister=False)
        try:
            self._selector.close()
        except OSError:
            pass
        self._listener.close()
        self._wake_r.close()
        self._wake_w.close()
        if isinstance(self.address, str) and os.path.exists(self.address):
            os.unlink(self.address)
        if self._owns_executor:
            self._executor.close()

    def __enter__(self) -> "CacheNodeServer":
        return self.start() if not self._thread.is_alive() else self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ selector
    def _wake(self) -> None:
        try:
            self._wake_w.send(b"x")
        except OSError:
            pass

    def _loop(self) -> None:
        while not self._stop.is_set():
            events = self._selector.select(timeout=0.5)
            with self._rearm_lock:
                rearm, self._rearm = self._rearm, []
            for conn in rearm:
                if conn.alive:
                    self._pump(conn)
            for key, _ in events:
                if key.data == "accept":
                    self._accept()
                elif key.data == "wake":
                    try:
                        self._wake_r.recv(4096)
                    except OSError:
                        pass
                else:
                    self._read(key.data)

    def _accept(self) -> None:
        while True:
            try:
                sock, _ = self._listener.accept()
            except (BlockingIOError, OSError):
                return
            sock.setblocking(False)
            conn = _Conn(sock)
            self._conns.add(conn)
            with self._stats_lock:
                self.stats.connections_accepted += 1
                self.stats.connections_open += 1
            self._selector.register(sock, selectors.EVENT_READ, conn)

    def _read(self, conn: _Conn) -> None:
        try:
            chunk = conn.sock.recv(1 << 20)
        except BlockingIOError:
            return
        except OSError:
            self._drop(conn)
            return
        if not chunk:
            self._drop(conn)
            return
        conn.buf += chunk
        with self._stats_lock:
            self.stats.bytes_in += len(chunk)
        self._pump(conn, registered=True)

    def _pump(self, conn: _Conn, registered: bool = False) -> None:
        """If a full frame is buffered, hand it to the executor (the conn
        leaves the selector until the response is sent); otherwise (re-)arm
        the connection for reading."""
        if len(conn.buf) >= 4:
            length = int.from_bytes(conn.buf[:4], "big")
            if length > self.max_frame_bytes:
                # reject before allocating/reading the body: a corrupt
                # length word must not OOM the node or desync the stream
                with self._stats_lock:
                    self.stats.protocol_errors += 1
                self._send_best_effort(
                    conn, P.encode_error(f"frame of {length} bytes exceeds cap")
                )
                self._drop(conn, unregister=registered)
                return
            if len(conn.buf) >= 4 + length:
                frame = bytes(conn.buf[4 : 4 + length])
                del conn.buf[: 4 + length]
                if registered:
                    self._selector.unregister(conn.sock)
                self._executor.submit(self._handle, conn, frame)
                return
        if not registered:
            self._selector.register(conn.sock, selectors.EVENT_READ, conn)

    def _drop(self, conn: _Conn, unregister: bool = True) -> None:
        if not conn.alive:
            return
        conn.alive = False
        if unregister:
            try:
                self._selector.unregister(conn.sock)
            except (KeyError, ValueError, OSError):
                pass
        try:
            conn.sock.close()
        except OSError:
            pass
        self._conns.discard(conn)
        with self._stats_lock:
            self.stats.connections_open -= 1

    def _send_best_effort(self, conn: _Conn, payload: bytes) -> None:
        try:
            conn.sock.settimeout(self.send_timeout_s)
            P.send_frame(conn.sock, payload)
        except OSError:
            pass

    # ------------------------------------------------------------ handling
    def _handle(self, conn: _Conn, frame: bytes) -> None:
        """Executor worker: decode, run the backend op, respond, re-arm."""
        try:
            op, args = P.decode_request(frame)
        except P.ProtocolError as e:
            with self._stats_lock:
                self.stats.protocol_errors += 1
            self._send_best_effort(conn, P.encode_error(f"protocol error: {e}"))
            self._drop(conn, unregister=False)
            return
        try:
            result = self._dispatch(op, args)
            payload = P.encode_ok(op, result)
        except Exception as e:  # noqa: BLE001 — reported to the client
            with self._stats_lock:
                self.stats.errors += 1
            payload = P.encode_error(f"{type(e).__name__}: {e}")
        with self._stats_lock:
            self.stats.requests += 1
            self.stats.bytes_out += len(payload) + 4
        try:
            # bounded send: socket.timeout is an OSError, so a stalled
            # client is dropped rather than wedging this worker
            conn.sock.settimeout(self.send_timeout_s)
            P.send_frame(conn.sock, payload)
            conn.sock.setblocking(False)
        except OSError:
            self._drop(conn, unregister=False)
            return
        # another pipelined frame may already be buffered; else re-arm
        with self._rearm_lock:
            self._rearm.append(conn)
        self._wake()

    def _dispatch(self, op: int, args: tuple):
        b = self.backend
        if op == P.OP_PING:
            return None
        if op == P.OP_PROBE:
            return b.probe(args[0])
        if op == P.OP_PROBE_MANY:
            return b.probe_many(args[0])
        if op == P.OP_GET:
            return b.get_batch(args[0], args[1])
        if op == P.OP_GET_MANY:
            return b.get_many(args[0])
        if op == P.OP_PUT:
            tokens, blocks, start_block, skip_existing = args
            return b.put_batch(tokens, blocks, start_block=start_block,
                               skip_existing=skip_existing)
        if op == P.OP_PUT_MANY:
            return b.put_many(args[0])
        if op == P.OP_STATS:
            st = b.stats
            fields = {
                k: v for k, v in st.__dict__.items()
                if isinstance(v, (int, float))
            } if not isinstance(st, StoreStats) else dict(st.__dict__)
            return {
                "name": getattr(b, "name", "?"),
                "block_size": b.block_size,
                "disk_bytes": b.disk_bytes,
                "file_count": b.file_count,
                "stats": fields,
                "server": self.stats.as_dict(),
            }
        if op == P.OP_MAINTENANCE:
            return b.maintenance(args[0])
        if op == P.OP_FLUSH:
            b.flush()
            return None
        raise P.ProtocolError(f"unknown opcode {op}")
