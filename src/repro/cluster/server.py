"""``CacheNodeServer`` — one cache node: a socket front-end over any
thread-safe ``StorageBackend``.

The server is a thin RPC shim, deliberately: every byte of storage logic
stays in the backend (which already carries the ``core/backend.py``
thread-safety contract), so a node is "an existing store, served".

Architecture (one node):

    acceptor/selector thread          IOExecutor (N workers)
    ─────────────────────────────────────────────────────────
    accept, read socket bytes,   ──►  decode request
    reassemble frames,                run the backend op
    submit each to the pool           send tagged response frame(s)
    (non-blocking, all conns)         (per-connection write lock)

Connections are **pipelined**: every complete frame is handed to the
executor as it arrives, so one connection can have many requests in
flight and responses return in completion order, tagged with the request
id the client chose — this is the server half of the multiplexed
protocol.  Writes from concurrent workers serialize on a per-connection
lock; frames never interleave.  When all workers are busy the selector
thread blocks on pool admission, which backpressures every client
instead of queueing unboundedly.

Streaming gets (``OP_GET_STREAM`` / ``OP_GET_MANY_STREAM``) emit CHUNK
frames as blocks become available and an END frame with per-sequence
totals.  Two send paths:

* **scatter-gather** — decoded blocks go out with one ``sendmsg`` per
  chunk (mux header + chunk header + packed tensor region), no concat
  copy;
* **zero-copy** — when the backend can hand the chunk as a contiguous
  tensor-log extent (``get_batch_raw``), the records are pushed with
  ``os.sendfile`` straight from the log file to the socket: the payload
  bytes never enter Python, and the node's CPU stays out of the read
  path entirely (the client decodes — it was going to pay that CPU
  anyway).  The open file descriptor pins the inode, so eviction
  unlinking the file mid-send is harmless.

Transports: TCP (``host``/``port``) or ``AF_UNIX`` (``unix_path``) — the
frame protocol is transport-agnostic (``os.sendfile`` works on both).
"""

from __future__ import annotations

import errno
import os
import select
import selectors
import socket
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Optional, Tuple, Union

from ..core.store import StoreStats
from ..obs import MetricsRegistry, dataclass_gauges
from ..runtime.executor import IOExecutor
from . import protocol as P
from .ring import in_arc, raw_key_hash

Address = Union[Tuple[str, int], str]  # (host, port) or unix socket path

_DONTWAIT = getattr(socket, "MSG_DONTWAIT", 0)
# errnos that mean "sendfile cannot work here at all" (vs. a dead peer):
# flip to the copying path instead of erroring every stream.
_SENDFILE_UNSUPPORTED = {errno.EINVAL, errno.ENOSYS, errno.EOPNOTSUPP, errno.ENOTSOCK}


@dataclass
class ServerStats:
    connections_accepted: int = 0
    connections_open: int = 0
    requests: int = 0
    errors: int = 0  # backend/op failures reported to the client
    protocol_errors: int = 0  # malformed frames (connection dropped)
    bytes_in: int = 0
    bytes_out: int = 0
    streams: int = 0
    stream_chunks: int = 0
    stream_blocks: int = 0
    raw_extents: int = 0  # chunks served straight from the tensor log
    sendfile_bytes: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class _Conn:
    __slots__ = ("sock", "buf", "alive", "wlock")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.buf = bytearray()
        self.alive = True
        self.wlock = threading.Lock()  # concurrent workers; frames never interleave


class CacheNodeServer:
    def __init__(
        self,
        backend,
        host: str = "127.0.0.1",
        port: int = 0,
        unix_path: Optional[str] = None,
        io_threads: int = 2,
        io_executor: Optional[IOExecutor] = None,
        max_frame_bytes: int = P.MAX_FRAME_BYTES,
        send_timeout_s: float = 30.0,
        zero_copy: bool = True,
        max_chunk_blocks: int = 1024,
        registry: Optional[MetricsRegistry] = None,
    ):
        """``send_timeout_s`` bounds response writes: a client that stops
        reading (stalled, hostile) gets dropped instead of wedging an
        executor worker forever — with a small pool, unbounded sends
        would eventually wedge every worker and stop the whole node.
        ``zero_copy=False`` disables the sendfile path (every chunk is
        read + decoded + re-encoded host-side, for A/B measurement)."""
        self.backend = backend
        self.max_frame_bytes = max_frame_bytes
        self.send_timeout_s = send_timeout_s
        self.max_chunk_blocks = max(1, int(max_chunk_blocks))
        self.zero_copy = bool(zero_copy) and hasattr(os, "sendfile")
        self.stats = ServerStats()
        self._stats_lock = threading.Lock()
        # ---- observability: one registry per node, scraped via OP_METRICS
        # (or the --metrics-port HTTP endpoint).  Server/backend stats are
        # bridged in as collectors; request latencies land in histograms.
        self.registry = registry or MetricsRegistry()
        self.registry.register_collector(
            dataclass_gauges("repro_server", self.stats, lock=self._stats_lock))
        self.registry.register_collector(self._backend_gauges)
        self._h_request = self.registry.histogram(
            "repro_node_request_seconds", "server-side latency of every request")
        self._h_trace_span = self.registry.histogram(
            "repro_node_trace_server_span_seconds",
            "server-side span of requests that carried a trace id")
        self._c_trace_requests = self.registry.counter(
            "repro_node_trace_requests_total", "requests that carried a trace id")
        self._recent_traces: deque = deque(maxlen=16)  # hex ids, newest last
        if io_executor is not None:
            self._executor, self._owns_executor = io_executor, False
        else:
            # handlers are short (one request), so pending-job admission can
            # be generous: stalls mean every worker is mid-request already.
            # io_threads is the node's *serving width* — these workers block
            # on disk reads and sendall/sendfile with the GIL released, so
            # the width must not be silently clamped to the core count (a
            # 1-core host still wants 2 in-flight requests so a slow get
            # cannot head-of-line block the connection)
            self._executor = IOExecutor(
                max_workers=max(1, io_threads), max_pending=64, cap_to_cpu=False
            )
            self._owns_executor = True
        if unix_path is not None:
            self._listener = socket.socket(socket.AF_UNIX)
            if os.path.exists(unix_path):
                os.unlink(unix_path)
            self._listener.bind(unix_path)
            self.address: Address = unix_path
        else:
            self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self._listener.bind((host, port))
            self.address = self._listener.getsockname()
        self._listener.listen(128)
        self._listener.setblocking(False)
        self._selector = selectors.DefaultSelector()
        self._selector.register(self._listener, selectors.EVENT_READ, "accept")
        # self-pipe so close() can wake the selector promptly
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._selector.register(self._wake_r, selectors.EVENT_READ, "wake")
        self._conns: set = set()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, name="cache-node", daemon=True)

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "CacheNodeServer":
        self._thread.start()
        return self

    def close(self) -> None:
        self._stop.set()
        self._wake()
        self._thread.join(timeout=10)
        for conn in list(self._conns):
            self._drop(conn, unregister=False)
        try:
            self._selector.close()
        except OSError:
            pass
        self._listener.close()
        self._wake_r.close()
        self._wake_w.close()
        if isinstance(self.address, str) and os.path.exists(self.address):
            os.unlink(self.address)
        if self._owns_executor:
            self._executor.close()

    def __enter__(self) -> "CacheNodeServer":
        return self.start() if not self._thread.is_alive() else self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ selector
    def _wake(self) -> None:
        try:
            self._wake_w.send(b"x")
        except OSError:
            pass

    def _loop(self) -> None:
        while not self._stop.is_set():
            events = self._selector.select(timeout=0.5)
            for key, _ in events:
                if key.data == "accept":
                    self._accept()
                elif key.data == "wake":
                    try:
                        self._wake_r.recv(4096)
                    except OSError:
                        pass
                else:
                    self._read(key.data)

    def _accept(self) -> None:
        while True:
            try:
                sock, _ = self._listener.accept()
            except (BlockingIOError, OSError):
                return
            # timeout mode: reads happen when the selector says readable;
            # writes (from executor workers) block at most send_timeout_s
            sock.settimeout(self.send_timeout_s)
            conn = _Conn(sock)
            self._conns.add(conn)
            with self._stats_lock:
                self.stats.connections_accepted += 1
                self.stats.connections_open += 1
            self._selector.register(sock, selectors.EVENT_READ, conn)

    def _read(self, conn: _Conn) -> None:
        try:
            chunk = conn.sock.recv(1 << 20, _DONTWAIT)
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self._drop(conn)
            return
        if not chunk:
            self._drop(conn)
            return
        conn.buf += chunk
        with self._stats_lock:
            self.stats.bytes_in += len(chunk)
        self._pump(conn)

    def _pump(self, conn: _Conn) -> None:
        """Hand every complete buffered frame to the executor — requests
        on one connection are pipelined, not one-at-a-time."""
        while conn.alive and len(conn.buf) >= 4:
            length = int.from_bytes(conn.buf[:4], "big")
            if length > self.max_frame_bytes:
                # reject before allocating/reading the body: a corrupt
                # length word must not OOM the node or desync the stream
                with self._stats_lock:
                    self.stats.protocol_errors += 1
                # tag the error with the claimed rid if its bytes arrived
                rid = int.from_bytes(conn.buf[4:8], "big") if len(conn.buf) >= 8 else 0
                self._send_best_effort(
                    conn, rid, P.encode_error(f"frame of {length} bytes exceeds cap")
                )
                self._drop(conn)
                return
            if len(conn.buf) < 4 + length:
                return
            payload = bytes(conn.buf[4 : 4 + length])
            del conn.buf[: 4 + length]
            try:
                rid, kind, trace, body = P.split_mux_ex(payload)
            except P.ProtocolError:
                with self._stats_lock:
                    self.stats.protocol_errors += 1
                self._send_best_effort(conn, 0, P.encode_error("malformed mux frame"))
                self._drop(conn)
                return
            if kind != P.KIND_REQUEST:
                with self._stats_lock:
                    self.stats.protocol_errors += 1
                self._send_best_effort(
                    conn, rid, P.encode_error(f"unexpected frame kind {kind}")
                )
                self._drop(conn)
                return
            self._executor.submit(self._handle, conn, rid, bytes(body), trace)

    def _drop(self, conn: _Conn, unregister: bool = True) -> None:
        if not conn.alive:
            return
        conn.alive = False
        if unregister:
            try:
                self._selector.unregister(conn.sock)
            except (KeyError, ValueError, OSError):
                pass
        try:
            conn.sock.close()
        except OSError:
            pass
        self._conns.discard(conn)
        with self._stats_lock:
            self.stats.connections_open -= 1

    # ------------------------------------------------------------- sending
    def _send(self, conn: _Conn, rid: int, kind: int, parts) -> int:
        """One tagged frame, under the connection's write lock.  OSError
        (including the bounded-send timeout) propagates to the caller,
        which drops the connection."""
        with conn.wlock:
            n = P.send_frame_parts(conn.sock, [P.pack_mux(rid, kind)] + list(parts))
        with self._stats_lock:
            self.stats.bytes_out += n
        return n

    def _send_best_effort(self, conn: _Conn, rid: int, payload: bytes) -> None:
        try:
            self._send(conn, rid, P.KIND_RESPONSE, [payload])
        except OSError:
            pass

    # ------------------------------------------------------------ handling
    def _handle(self, conn: _Conn, rid: int, request: bytes,
                trace: Optional[bytes] = None) -> None:
        """Executor worker: decode, run the backend op, respond.  The
        op's wall time lands in the request/per-op histograms; if the
        frame carried a trace id, the same interval closes the trace out
        server-side (span histogram + recent-traces ring)."""
        t0 = time.perf_counter()
        try:
            op, args = P.decode_request(request)
        except P.ProtocolError as e:
            with self._stats_lock:
                self.stats.protocol_errors += 1
            self._send_best_effort(conn, rid, P.encode_error(f"protocol error: {e}"))
            self._drop(conn)
            return
        if op in P.STREAM_OPS:
            self._handle_stream(conn, rid, op, args, trace=trace, t0=t0)
            return
        try:
            result = self._dispatch(op, args)
            payload = P.encode_ok(op, result)
        except Exception as e:  # noqa: BLE001 — reported to the client
            with self._stats_lock:
                self.stats.errors += 1
            payload = P.encode_error(f"{type(e).__name__}: {e}")
        with self._stats_lock:
            self.stats.requests += 1
        self._observe_op(op, time.perf_counter() - t0, trace)
        try:
            self._send(conn, rid, P.KIND_RESPONSE, [payload])
        except OSError:
            self._drop(conn)

    def _observe_op(self, op: int, elapsed_s: float, trace: Optional[bytes]) -> None:
        self._h_request.observe(elapsed_s)
        self.registry.histogram(
            f"repro_node_op_seconds_{P.OP_NAMES.get(op, op)}").observe(elapsed_s)
        if trace is not None:
            self._c_trace_requests.inc()
            self._h_trace_span.observe(elapsed_s)
            self._recent_traces.append(trace.hex())

    # ----------------------------------------------------------- streaming
    def _handle_stream(self, conn: _Conn, rid: int, op: int, args: tuple,
                       trace: Optional[bytes] = None,
                       t0: Optional[float] = None) -> None:
        if t0 is None:
            t0 = time.perf_counter()
        if op == P.OP_GET_STREAM:
            tokens, n_tokens, chunk_blocks = args
            items = [(tokens, n_tokens)]
        else:
            items, chunk_blocks = args
        chunk_blocks = max(1, min(int(chunk_blocks), self.max_chunk_blocks))
        with self._stats_lock:
            self.stats.requests += 1
            self.stats.streams += 1
        counts = []
        try:
            for seq_index, (tokens, n_tokens) in enumerate(items):
                counts.append(
                    self._stream_item(conn, rid, seq_index, tokens, n_tokens, chunk_blocks)
                )
        except OSError:
            self._drop(conn)
            return
        except Exception as e:  # noqa: BLE001 — abort the stream, report
            with self._stats_lock:
                self.stats.errors += 1
            self._observe_op(op, time.perf_counter() - t0, trace)
            try:
                self._send(conn, rid, P.KIND_END, [P.encode_error(f"{type(e).__name__}: {e}")])
            except OSError:
                self._drop(conn)
            return
        self._observe_op(op, time.perf_counter() - t0, trace)
        try:
            self._send(conn, rid, P.KIND_END, [P.encode_stream_end(counts)])
        except OSError:
            self._drop(conn)

    def _stream_item(
        self, conn: _Conn, rid: int, seq_index: int, tokens, n_tokens: int, chunk_blocks: int
    ) -> int:
        """Stream one sequence's blocks as CHUNK frames; returns blocks
        served.  Prefers the zero-copy extent path, falls back to the
        decoded path (which re-encodes over the wire format)."""
        if self.zero_copy:
            raw_fn = getattr(self.backend, "get_batch_raw", None)
            if raw_fn is not None:
                rb = raw_fn(tokens, n_tokens)
                if rb is not None:
                    try:
                        return self._stream_raw(conn, rid, seq_index, rb, chunk_blocks)
                    finally:
                        rb.close()
        # buffered fallback: ship still-encoded payloads (layout 3) when
        # the backend can hand them out — the compressed-bytes complement
        # of the sendfile path, so even non-extent reads keep the wire
        # compressed.  Backends without the method send decoded blocks.
        enc_fn = getattr(self.backend, "get_batch_encoded", None)
        blocks = (enc_fn or self.backend.get_batch)(tokens, n_tokens)
        for start in range(0, len(blocks), chunk_blocks):
            part = blocks[start : start + chunk_blocks]
            self._send(
                conn, rid, P.KIND_CHUNK, P.encode_stream_chunk(seq_index, start, part)
            )
            with self._stats_lock:
                self.stats.stream_chunks += 1
                self.stats.stream_blocks += len(part)
        return len(blocks)

    def _stream_raw(self, conn: _Conn, rid: int, seq_index: int, rb, chunk_blocks: int) -> int:
        """Zero-copy chunk emission: frame headers via ``sendmsg``, then
        ``os.sendfile`` pushes the raw log records kernel-to-kernel."""
        in_fd = rb.file.fileno()
        offset = rb.offset
        i = 0
        while i < rb.n_blocks:
            lens = rb.record_lengths[i : i + chunk_blocks]
            nbytes = sum(lens)
            hdr = P.encode_vlog_chunk_header(seq_index, i, len(lens), nbytes)
            mux = P.pack_mux(rid, P.KIND_CHUNK)
            frame_len = len(mux) + len(hdr) + nbytes
            with conn.wlock:
                conn.sock.sendall(
                    frame_len.to_bytes(4, "big") + mux + hdr
                )
                self._sendfile(conn.sock, in_fd, offset, nbytes)
            with self._stats_lock:
                self.stats.bytes_out += 4 + frame_len
                self.stats.stream_chunks += 1
                self.stats.stream_blocks += len(lens)
                self.stats.raw_extents += 1
                self.stats.sendfile_bytes += nbytes
            offset += nbytes
            i += len(lens)
        return rb.n_blocks

    def _sendfile(self, sock: socket.socket, in_fd: int, offset: int, nbytes: int) -> None:
        """``os.sendfile`` with the same bounded-send discipline as
        ``sendall``: the socket fd is non-blocking (timeout mode), so
        loop on EAGAIN with a writability wait and an overall deadline."""
        out_fd = sock.fileno()
        sent = 0
        deadline = time.monotonic() + self.send_timeout_s
        while sent < nbytes:
            try:
                n = os.sendfile(out_fd, in_fd, offset + sent, nbytes - sent)
            except (BlockingIOError, InterruptedError):
                n = 0
            except OSError as e:
                if e.errno in _SENDFILE_UNSUPPORTED and sent == 0 and self.zero_copy:
                    # environment can't sendfile at all: fall back to a
                    # plain copy of the records (frame header already out,
                    # so the byte stream must be completed either way)
                    self.zero_copy = False
                    self._copy_file_range(sock, in_fd, offset, nbytes, deadline)
                    return
                raise
            if n == 0:
                if time.monotonic() > deadline:
                    raise socket.timeout(f"sendfile stalled after {sent}/{nbytes} bytes")
                select.select([], [out_fd], [], 0.2)
                continue
            sent += n

    def _copy_file_range(
        self, sock: socket.socket, in_fd: int, offset: int, nbytes: int, deadline: float
    ) -> None:
        remaining = nbytes
        pos = offset
        while remaining:
            if time.monotonic() > deadline:
                raise socket.timeout("stream send stalled")
            data = os.pread(in_fd, min(remaining, 1 << 20), pos)
            if not data:
                raise OSError(f"log file truncated {remaining} bytes short")
            sock.sendall(data)
            pos += len(data)
            remaining -= len(data)

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, op: int, args: tuple):
        b = self.backend
        if op == P.OP_PING:
            return None
        if op == P.OP_PROBE:
            return b.probe(args[0])
        if op == P.OP_PROBE_MANY:
            return b.probe_many(args[0])
        if op == P.OP_GET:
            # prefer still-encoded payloads (layout 3): the wire carries
            # the compressed bytes the disk stores; the client decodes
            enc = getattr(b, "get_batch_encoded", None)
            if enc is not None:
                return enc(args[0], args[1])
            return b.get_batch(args[0], args[1])
        if op == P.OP_GET_MANY:
            enc = getattr(b, "get_batch_encoded", None)
            if enc is not None:
                return [enc(tokens, n) for tokens, n in args[0]]
            return b.get_many(args[0])
        if op == P.OP_PUT:
            tokens, blocks, start_block, skip_existing = args
            return b.put_batch(tokens, blocks, start_block=start_block,
                               skip_existing=skip_existing)
        if op == P.OP_PUT_MANY:
            return b.put_many(args[0])
        if op == P.OP_STATS:
            st = b.stats
            fields = {
                k: v for k, v in st.__dict__.items()
                if isinstance(v, (int, float))
            } if not isinstance(st, StoreStats) else dict(st.__dict__)
            return {
                "name": getattr(b, "name", "?"),
                "block_size": b.block_size,
                "disk_bytes": b.disk_bytes,
                "file_count": b.file_count,
                "stats": fields,
                "server": self.stats.as_dict(),
            }
        if op == P.OP_METRICS:
            return self.metrics_report()
        if op == P.OP_MAINTENANCE:
            return b.maintenance(args[0])
        if op == P.OP_FLUSH:
            b.flush()
            return None
        # elasticity trio (cluster.migration) — optional backend methods,
        # duck-typed like get_batch_encoded.  The ring-arc filter runs
        # here, not in the backend: core stays placement-agnostic, and
        # the hash is recomputed from the key bytes (raw_key_hash), so a
        # node needs no token decode to place its own data.
        if op == P.OP_SCAN:
            cursor, limit, ranges = args
            fn = getattr(b, "scan_keys", None)
            if fn is None:
                raise RuntimeError(
                    f"backend {getattr(b, 'name', '?')} does not support key scans")
            keys, next_cursor = fn(cursor, limit)
            if ranges:
                keys = [
                    k for k in keys
                    if any(in_arc(lo, hi, raw_key_hash(k, b.block_size))
                           for lo, hi in ranges)
                ]
            return keys, next_cursor
        if op == P.OP_PULL:
            fn = getattr(b, "export_encoded", None)
            if fn is None:
                raise RuntimeError(
                    f"backend {getattr(b, 'name', '?')} does not support block export")
            return fn(args[0])
        if op == P.OP_PUSH:
            records, skip_existing = args
            fn = getattr(b, "import_encoded", None)
            if fn is None:
                raise RuntimeError(
                    f"backend {getattr(b, 'name', '?')} does not support block import")
            return fn(records, skip_existing=skip_existing)
        raise P.ProtocolError(f"unknown opcode {op}")

    # ------------------------------------------------------- observability
    def _backend_gauges(self) -> dict:
        """Collector: backend store + LSM stats as ``repro_store_*`` /
        ``repro_lsm_*`` gauges (summed across shards for sharded
        backends), plus disk usage.  Tolerant of minimal backends."""
        b = self.backend
        out: dict = {}
        st = getattr(b, "stats", None)
        if st is not None:
            for k, v in vars(st).items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    out[f"repro_store_{k}"] = float(v)
        for attr, name in (("disk_bytes", "repro_node_disk_bytes"),
                           ("file_count", "repro_node_file_count")):
            try:
                v = getattr(b, attr, None)
            except OSError:
                v = None
            if isinstance(v, (int, float)):
                out[name] = float(v)
        stores = getattr(b, "shards", None) or [b]
        lsm: dict = {}
        for s in stores:
            idx = getattr(s, "index", None)
            lst = getattr(idx, "stats", None)
            if lst is None:
                continue
            for k, v in vars(lst).items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    lsm[f"repro_lsm_{k}"] = lsm.get(f"repro_lsm_{k}", 0.0) + float(v)
        out.update(lsm)
        return out

    def metrics_report(self) -> dict:
        """Full registry snapshot plus node identity and the most recent
        trace ids this node closed out — the ``OP_METRICS`` body."""
        return {
            "name": getattr(self.backend, "name", "?"),
            "block_size": getattr(self.backend, "block_size", 0),
            "metrics": self.registry.snapshot(),
            "traces": list(self._recent_traces),
        }
