"""Client-side connection multiplexing: one socket per node, many RPCs
in flight.

The old transport pooled sockets and parked one thread per outstanding
RPC inside ``recv`` — in-flight depth equaled pool size, and a batch of
small probes serialized behind one large get.  Here a single
``MuxLoop`` selector thread owns the *read* side of every node
connection: it drains sockets, reassembles length-prefixed frames,
routes each frame by request id to the waiter that issued it, and hands
the bytes over — decode happens on the waiting caller's thread, so the
loop never stalls the sockets behind tensor decode CPU.

Writes go straight from caller threads (serialized per connection by a
send lock, bounded by the socket timeout); the kernel interleaves the
two directions, which is what makes the protocol full duplex: a
``get_batch`` stream can be arriving while the next batch of requests
is going out.

Failure semantics, per the cluster error taxonomy:

* socket errors, timeouts, and framing violations poison the whole
  connection — every pending waiter fails with the transport error, and
  the caller maps it to retry / ``NodeUnavailable``;
* malformed frame *bodies* are the receiving caller's problem
  (``ProtocolError`` raised from its decode, never retried) — the frame
  boundary itself was sound, so other requests on the connection are
  unaffected.
"""

from __future__ import annotations

import queue
import selectors
import socket
import threading
from typing import Dict, Optional, Union

from . import protocol as P

_DONTWAIT = getattr(socket, "MSG_DONTWAIT", 0)


class _UnaryWaiter:
    """One caller blocked on a single RESPONSE frame."""

    __slots__ = ("_event", "payload", "exc")

    def __init__(self):
        self._event = threading.Event()
        self.payload: Optional[bytes] = None
        self.exc: Optional[BaseException] = None

    def complete(self, payload: bytes) -> None:
        self.payload = payload
        self._event.set()

    def fail(self, exc: BaseException) -> None:
        self.exc = exc
        self._event.set()

    def wait(self, timeout: Optional[float]) -> bytes:
        if not self._event.wait(timeout):
            raise socket.timeout(f"no response within {timeout}s")
        if self.exc is not None:
            raise self.exc
        assert self.payload is not None
        return self.payload


class _StreamWaiter:
    """One caller consuming CHUNK frames until END.  Events are
    ``("chunk", bytes)``, ``("end", bytes)`` or ``("err", exc)``."""

    __slots__ = ("_q",)

    def __init__(self):
        self._q: "queue.Queue" = queue.Queue()

    def complete(self, payload: bytes) -> None:  # RESPONSE to a stream op
        self._q.put(("err", P.ProtocolError("unary response to a streaming request")))

    def feed_chunk(self, payload: bytes) -> None:
        self._q.put(("chunk", payload))

    def finish(self, payload: bytes) -> None:
        self._q.put(("end", payload))

    def fail(self, exc: BaseException) -> None:
        self._q.put(("err", exc))

    def next_event(self, timeout: Optional[float]):
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            raise socket.timeout(f"no stream frame within {timeout}s") from None


Waiter = Union[_UnaryWaiter, _StreamWaiter]


class MuxConnection:
    """One multiplexed connection.  Callers attach a waiter, send their
    tagged request, and block on the waiter; the loop thread routes
    arriving frames by request id."""

    def __init__(
        self,
        sock: socket.socket,
        loop: "MuxLoop",
        max_frame_bytes: int = P.MAX_FRAME_BYTES,
        timeout_s: float = 30.0,
    ):
        sock.settimeout(timeout_s)  # bounds writes; reads ride the selector
        self.sock = sock
        self.loop = loop
        self.max_frame_bytes = max_frame_bytes
        self.timeout_s = timeout_s
        self.alive = True
        self._buf = bytearray()
        self._wlock = threading.Lock()  # serializes frame writes
        self._plock = threading.Lock()  # pending map + rid allocation + alive
        self._pending: Dict[int, Waiter] = {}
        self._next_rid = 1
        self.orphan_frames = 0  # frames for an rid nobody is waiting on
        loop.register(self)

    # ------------------------------------------------------------- send side
    def attach(self, waiter: Waiter) -> int:
        """Reserve a request id for ``waiter``; the caller must send the
        request (or ``detach``) afterwards."""
        with self._plock:
            if not self.alive:
                raise ConnectionError("connection is closed")
            rid = self._next_rid
            self._next_rid = (self._next_rid + 1) & 0xFFFFFFFF or 1
            self._pending[rid] = waiter
            return rid

    def detach(self, rid: int) -> None:
        with self._plock:
            self._pending.pop(rid, None)

    def send_request(self, rid: int, request: bytes,
                     trace: Optional[bytes] = None) -> int:
        """Write one tagged REQUEST frame; returns bytes sent.  ``trace``
        rides as the optional trace-id field of the mux header.  A send
        failure poisons the connection (the stream position is unknown)."""
        parts = [P.pack_mux(rid, P.KIND_REQUEST, trace), request]
        try:
            with self._wlock:
                return P.send_frame_parts(self.sock, parts)
        except OSError as e:
            self.poison(e)
            raise

    # ------------------------------------------------------------- loop side
    def on_readable(self) -> None:
        """Loop thread: drain the socket, route complete frames."""
        for _ in range(8):  # bounded so one firehose conn can't starve others
            try:
                data = self.sock.recv(1 << 20, _DONTWAIT)
            except (BlockingIOError, InterruptedError):
                return
            except OSError as e:
                self.poison(e)
                return
            if not data:
                self.poison(
                    P.TruncatedFrame("peer closed mid-RPC")
                    if self._pending_count()
                    else ConnectionError("peer closed the connection")
                )
                return
            self._buf += data
            if not self._route_frames():
                return
            if len(data) < (1 << 20):
                return

    def _pending_count(self) -> int:
        with self._plock:
            return len(self._pending)

    def _route_frames(self) -> bool:
        while len(self._buf) >= 4:
            length = int.from_bytes(self._buf[:4], "big")
            if length > self.max_frame_bytes:
                self.poison(P.FrameTooLarge(f"frame of {length} bytes exceeds cap"))
                return False
            if len(self._buf) < 4 + length:
                break
            payload = bytes(self._buf[4 : 4 + length])
            del self._buf[: 4 + length]
            try:
                rid, kind, body = P.split_mux(payload)
            except P.ProtocolError as e:
                self.poison(e)  # framing is broken — nothing on this conn is safe
                return False
            self._route(rid, kind, bytes(body))
        return True

    def _route(self, rid: int, kind: int, body: bytes) -> None:
        with self._plock:
            waiter = self._pending.get(rid)
            if kind in (P.KIND_RESPONSE, P.KIND_END):
                self._pending.pop(rid, None)
        if waiter is None:
            self.orphan_frames += 1  # late frame for a timed-out/abandoned rid
            return
        if kind == P.KIND_RESPONSE:
            waiter.complete(body)
        elif kind == P.KIND_CHUNK:
            if isinstance(waiter, _StreamWaiter):
                waiter.feed_chunk(body)
            else:
                waiter.fail(P.ProtocolError("stream chunk for a unary request"))
        elif kind == P.KIND_END:
            if isinstance(waiter, _StreamWaiter):
                waiter.finish(body)
            else:
                waiter.fail(P.ProtocolError("stream end for a unary request"))
        else:  # KIND_REQUEST from a server is nonsense
            waiter.fail(P.ProtocolError(f"unexpected frame kind {kind}"))

    # -------------------------------------------------------------- teardown
    def poison(self, exc: BaseException) -> None:
        """Fail every pending waiter and close the socket.  Idempotent;
        safe from any thread."""
        with self._plock:
            if not self.alive:
                return
            self.alive = False
            pending, self._pending = self._pending, {}
        self.loop.unregister(self)
        try:
            self.sock.close()
        except OSError:
            pass
        for waiter in pending.values():
            waiter.fail(exc)

    def close(self) -> None:
        self.poison(ConnectionError("connection closed by client"))


class MuxLoop:
    """The client I/O loop: one daemon thread selecting over every
    registered ``MuxConnection``.  Shared across all node clients of a
    cluster store, so client-side read concurrency costs one thread
    total, not one per in-flight RPC."""

    def __init__(self):
        self._selector = selectors.DefaultSelector()
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._selector.register(self._wake_r, selectors.EVENT_READ, None)
        self._lock = threading.Lock()
        self._pending_reg: list = []
        self._pending_unreg: list = []
        self._closed = False
        self._thread = threading.Thread(target=self._run, name="mux-loop", daemon=True)
        self._thread.start()

    def register(self, conn: MuxConnection) -> None:
        with self._lock:
            if self._closed:
                raise RuntimeError("MuxLoop is closed")
            self._pending_reg.append(conn)
        self._wake()

    def unregister(self, conn: MuxConnection) -> None:
        with self._lock:
            if conn in self._pending_reg:
                self._pending_reg.remove(conn)
            else:
                self._pending_unreg.append(conn)
        self._wake()

    def _wake(self) -> None:
        try:
            self._wake_w.send(b"x")
        except OSError:
            pass

    def _run(self) -> None:
        registered: set = set()
        while True:
            with self._lock:
                closed = self._closed
                reg, self._pending_reg = self._pending_reg, []
                unreg, self._pending_unreg = self._pending_unreg, []
            if closed:
                for conn in registered:
                    conn.poison(ConnectionError("mux loop shut down"))
                return
            for conn in unreg:
                if conn in registered:
                    registered.discard(conn)
                    try:
                        self._selector.unregister(conn.sock)
                    except (KeyError, ValueError, OSError):
                        pass
            for conn in reg:
                try:
                    self._selector.register(conn.sock, selectors.EVENT_READ, conn)
                    registered.add(conn)
                except (ValueError, OSError) as e:
                    conn.poison(e if isinstance(e, OSError) else ConnectionError(str(e)))
            for key, _ in self._selector.select(timeout=0.5):
                if key.data is None:
                    try:
                        self._wake_r.recv(4096)
                    except OSError:
                        pass
                else:
                    key.data.on_readable()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._wake()
        self._thread.join(timeout=10)
        try:
            self._selector.close()
        except OSError:
            pass
        self._wake_r.close()
        self._wake_w.close()
