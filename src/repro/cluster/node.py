"""Cache-node process: ``python -m repro.cluster.node --root DIR ...``

Runs one ``CacheNodeServer`` over a local backend until killed — the
deployable unit of the cache cluster.  Imports stay storage-only (no
jax), so a node starts in milliseconds and runs on cacheless CPU hosts.

``spawn_local_node`` / ``NodeProcess`` are the in-repo process manager:
examples, benchmarks, and tests use them to stand up real multi-process
clusters on localhost (the node prints ``READY port=N`` once the socket
is bound; the parent blocks on that line).  Production deployments run
the same module under their own supervisor.
"""

from __future__ import annotations

import argparse
import os
import select
import signal
import subprocess
import sys
import threading
import time
from typing import List, Optional

from ..core.baselines import MemoryOnlyStore
from ..core.codec import CODEC_INT8, CODEC_RAW, BatchCodec
from ..core.sharded_store import ShardedKVBlockStore
from ..core.store import KVBlockStore
from ..core.tiering import TieringPolicy
from .server import CacheNodeServer


def make_backend(args) -> object:
    codec = {
        "raw": BatchCodec(CODEC_RAW, use_zlib=False),
        "int8": BatchCodec(CODEC_INT8, use_zlib=False),
        "int8-zlib": BatchCodec(CODEC_INT8, use_zlib=True),
        "tiered": None,  # adaptive policy: puts are raw, maintenance demotes
    }[args.codec]
    budget = args.budget_bytes if args.budget_bytes > 0 else None
    if args.backend == "memory":
        return MemoryOnlyStore(budget or 1 << 30, block_size=args.block_size)
    extra = {}
    if args.vlog_file_bytes > 0:
        extra["vlog_file_bytes"] = args.vlog_file_bytes
    if args.codec == "tiered":
        extra["tiering"] = TieringPolicy(
            warm_after_s=args.warm_after_s, cold_after_s=args.cold_after_s
        )
    if args.backend == "sharded":
        return ShardedKVBlockStore(
            args.root, n_shards=args.shards, block_size=args.block_size,
            codec=codec, budget_bytes=budget, io_threads=args.store_io_threads,
            **extra,
        )
    return KVBlockStore(args.root, block_size=args.block_size, codec=codec,
                        budget_bytes=budget, **extra)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="one KV-cache cluster node")
    ap.add_argument("--root", required=True, help="backend data directory")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0, help="0 = ephemeral")
    ap.add_argument("--unix-path", default=None, help="serve AF_UNIX instead of TCP")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--backend", choices=("lsm", "sharded", "memory"), default="lsm")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--codec", choices=("raw", "int8", "int8-zlib", "tiered"),
                    default="int8-zlib",
                    help="'tiered' writes raw and lets maintenance demote "
                         "idle blocks to int8 / int8+zlib (core.tiering)")
    ap.add_argument("--warm-after-s", type=float, default=30.0,
                    help="tiered codec: demote a sealed log file idle this "
                         "long to int8 (0 = next maintenance cycle)")
    ap.add_argument("--cold-after-s", type=float, default=120.0,
                    help="tiered codec: demote to int8+zlib after this idle")
    ap.add_argument("--budget-bytes", type=int, default=0, help="0 = unbounded")
    ap.add_argument("--vlog-file-bytes", type=int, default=0,
                    help="tensor-log roll size; 0 = backend default (bounds "
                         "FIFO-eviction granularity for budgeted nodes)")
    ap.add_argument("--io-threads", type=int, default=2,
                    help="server-side request concurrency (the node's serving width)")
    ap.add_argument("--store-io-threads", type=int, default=0,
                    help="sharded backend's internal fan-out threads")
    ap.add_argument("--no-zero-copy", action="store_true",
                    help="disable the sendfile streaming path (A/B measurement)")
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="serve Prometheus text exposition of the node's "
                         "metrics registry on this HTTP port (0 = ephemeral, "
                         "-1 = disabled)")
    args = ap.parse_args(argv)

    backend = make_backend(args)
    server = CacheNodeServer(
        backend, host=args.host, port=args.port, unix_path=args.unix_path,
        io_threads=args.io_threads, zero_copy=not args.no_zero_copy,
    ).start()
    httpd = None
    if args.metrics_port >= 0:
        from ..obs.httpd import MetricsHTTPServer
        httpd = MetricsHTTPServer(server.registry, host=args.host,
                                  port=args.metrics_port)
        # printed before READY so spawn_local_node picks it up while
        # scanning for the READY line
        print(f"METRICS port={httpd.port}", flush=True)
    if isinstance(server.address, str):
        print(f"READY unix={server.address}", flush=True)
    else:
        print(f"READY port={server.address[1]}", flush=True)

    stop = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_: stop.set())
    stop.wait()
    if httpd is not None:
        httpd.close()
    server.close()
    backend.flush()
    backend.close()
    return 0


# ------------------------------------------------------------ spawn helpers
class NodeProcess:
    """Handle on one spawned local node: address + process control."""

    def __init__(self, proc: subprocess.Popen, address, root: str,
                 metrics_port: Optional[int] = None):
        self.proc = proc
        self.address = address
        self.root = root
        self.metrics_port = metrics_port  # HTTP exposition port, if enabled

    @property
    def alive(self) -> bool:
        return self.proc.poll() is None

    def kill(self) -> None:
        """Hard kill (SIGKILL) — the failure the failover demo injects."""
        if self.alive:
            self.proc.kill()
            self.proc.wait(timeout=10)

    def terminate(self) -> None:
        if self.alive:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self.kill()

    def close(self) -> None:
        self.terminate()
        if self.proc.stdout is not None:
            self.proc.stdout.close()


def spawn_local_node(
    root: str,
    port: int = 0,
    host: str = "127.0.0.1",
    block_size: int = 16,
    backend: str = "lsm",
    codec: str = "int8-zlib",
    io_threads: int = 2,
    budget_bytes: int = 0,
    vlog_file_bytes: int = 0,
    ready_timeout_s: float = 30.0,
    metrics_port: Optional[int] = None,
    extra_args: Optional[List[str]] = None,
) -> NodeProcess:
    """Start ``python -m repro.cluster.node`` as a child process and block
    until its socket is bound (the ``READY`` line).  ``metrics_port``
    enables the HTTP exposition endpoint (0 = ephemeral; the bound port
    comes back on the handle's ``metrics_port``)."""
    src_dir = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [
        sys.executable, "-m", "repro.cluster.node",
        "--root", root, "--host", host, "--port", str(port),
        "--block-size", str(block_size), "--backend", backend,
        "--codec", codec, "--io-threads", str(io_threads),
        "--budget-bytes", str(budget_bytes),
        "--vlog-file-bytes", str(vlog_file_bytes),
    ] + (extra_args or [])
    if metrics_port is not None:
        cmd += ["--metrics-port", str(metrics_port)]
    proc = subprocess.Popen(
        cmd, env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True
    )
    deadline = time.time() + ready_timeout_s
    bound_metrics: Optional[int] = None
    address = None
    # Read the raw fd and split lines by hand: select() + buffered
    # readline() race when the child prints METRICS and READY
    # back-to-back — one readline() can pull both lines into the
    # userspace buffer and return only the first, after which select()
    # on the drained OS pipe never fires again.
    fd = proc.stdout.fileno()
    pending = b""
    last = ""
    while time.time() < deadline and address is None:
        if proc.poll() is not None:
            out = pending.decode(errors="replace") + (proc.stdout.read() or "")
            raise RuntimeError(f"node exited at startup (rc={proc.returncode}): {out}")
        readable, _, _ = select.select([fd], [], [], 0.25)
        if not readable:
            continue
        chunk = os.read(fd, 4096)
        if not chunk:
            continue  # EOF: let proc.poll() report the exit
        pending += chunk
        while b"\n" in pending:
            raw, _, pending = pending.partition(b"\n")
            last = raw.decode(errors="replace")
            if last.startswith("METRICS"):  # printed before READY
                bound_metrics = int(last.split("METRICS", 1)[1].strip().partition("=")[2])
            elif last.startswith("READY"):
                token = last.split("READY", 1)[1].strip()
                key, _, value = token.partition("=")
                address = value if key == "unix" else (host, int(value))
                break
    if address is None:
        proc.kill()
        raise TimeoutError(f"node gave no READY within {ready_timeout_s}s: {last!r}")
    return NodeProcess(proc, address, root, metrics_port=bound_metrics)


if __name__ == "__main__":
    sys.exit(main())
