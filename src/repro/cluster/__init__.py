"""Cache-cluster layer: thread-safe ``StorageBackend``s served over
sockets, consumed through a consistent-hash-routed, replicated client.

This package is the *cache distribution* axis of the repo — scaling the
disk tier across processes and hosts (LMCache-style cache cluster).  It
is unrelated to ``repro.distributed``, which shards *model training*
(JAX meshes).  See ``docs/ARCHITECTURE.md``.

    CacheNodeServer     one node: socket RPC shim over any backend
    RemoteKVBlockStore  StorageBackend client for one node (pooling,
                        batched RPCs, retry)
    ClusterKVBlockStore StorageBackend over N nodes (HashRing routing,
                        replication, read-failover, down/rejoin tracking)
    spawn_local_node    child-process node manager for demos/benchmarks
"""

from .client import NodeUnavailable, RemoteKVBlockStore, RpcStats
from .cluster_store import ClusterKVBlockStore, ClusterStats
from .node import NodeProcess, spawn_local_node
from .protocol import (
    MAX_FRAME_BYTES,
    FrameTooLarge,
    ProtocolError,
    RemoteError,
    TruncatedFrame,
)
from .ring import HashRing, key_hash
from .server import CacheNodeServer, ServerStats

__all__ = [
    "CacheNodeServer",
    "ServerStats",
    "RemoteKVBlockStore",
    "RpcStats",
    "NodeUnavailable",
    "ClusterKVBlockStore",
    "ClusterStats",
    "HashRing",
    "key_hash",
    "NodeProcess",
    "spawn_local_node",
    "ProtocolError",
    "FrameTooLarge",
    "TruncatedFrame",
    "RemoteError",
    "MAX_FRAME_BYTES",
]
