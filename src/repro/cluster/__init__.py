"""Cache-cluster layer: thread-safe ``StorageBackend``s served over
sockets, consumed through a consistent-hash-routed, replicated client.

This package is the *cache distribution* axis of the repo — scaling the
disk tier across processes and hosts (LMCache-style cache cluster).  It
is unrelated to ``repro.distributed``, which shards *model training*
(JAX meshes).  See ``docs/ARCHITECTURE.md``.

    CacheNodeServer     one node: pipelined socket RPC shim over any
                        backend (sendmsg scatter-gather + sendfile
                        zero-copy streaming)
    RemoteKVBlockStore  StorageBackend client for one node (multiplexed
                        connection, batched RPCs, streaming gets, retry)
    ClusterKVBlockStore StorageBackend over N nodes (HashRing routing,
                        replication, read-failover — including
                        mid-stream — down/rejoin tracking, elastic
                        add_node/remove_node membership)
    BlockMigrator       background arc migration + replica repair on the
                        maintenance cadence
    MuxLoop             shared client-side selector thread
    spawn_local_node    child-process node manager for demos/benchmarks
"""

from .client import BlockStream, NodeUnavailable, RemoteKVBlockStore, RpcStats
from .cluster_store import ClusterBlockStream, ClusterKVBlockStore, ClusterStats
from .migration import BlockMigrator, MigrationStats
from .mux import MuxConnection, MuxLoop
from .node import NodeProcess, spawn_local_node
from .protocol import (
    MAX_FRAME_BYTES,
    FrameTooLarge,
    ProtocolError,
    RemoteError,
    TruncatedFrame,
)
from .ring import HashRing, TransitionView, key_hash, raw_key_hash
from .server import CacheNodeServer, ServerStats

__all__ = [
    "CacheNodeServer",
    "ServerStats",
    "RemoteKVBlockStore",
    "RpcStats",
    "BlockStream",
    "NodeUnavailable",
    "ClusterKVBlockStore",
    "ClusterBlockStream",
    "ClusterStats",
    "MuxLoop",
    "MuxConnection",
    "HashRing",
    "TransitionView",
    "BlockMigrator",
    "MigrationStats",
    "key_hash",
    "raw_key_hash",
    "NodeProcess",
    "spawn_local_node",
    "ProtocolError",
    "FrameTooLarge",
    "TruncatedFrame",
    "RemoteError",
    "MAX_FRAME_BYTES",
]
