"""``RemoteKVBlockStore`` — a ``StorageBackend`` whose storage lives in
another process.

The client speaks the frame protocol to one ``CacheNodeServer`` and
exposes the full backend contract, so everything built against the
protocol (``CacheHierarchy``, ``ServingEngine``, the write-behind
``CommitQueue``, benchmarks) runs against a remote node unchanged — the
network hop is a constructor argument, never a code change.

Mechanics:

* **Connection pooling** — a small pool of sockets, checked out per RPC;
  concurrent callers (the engine's I/O executor, the commit-queue drain
  thread) each get their own connection, so RPCs overlap instead of
  serializing on one stream.  Thread-safe by the same coarse-lock
  discipline as the baseline backends.
* **Request batching** — the multi-sequence ops (``probe_many`` /
  ``get_many`` / ``put_many``) ship as *one* RPC, so a whole engine
  batch pays one round trip instead of one per sequence (the §3.4 batch
  operations claim, extended across the wire).  ``put_many`` batches are
  split when their payload would approach the frame cap.
* **Retry** — connection-level failures (reset, truncated frame,
  timeout) are retried on a fresh connection up to ``retries`` times.
  Every backend op is idempotent (puts are content-addressed, probes and
  gets are reads), so retry is always safe.  Persistent failure raises
  ``NodeUnavailable`` — the signal ``ClusterKVBlockStore`` uses to mark
  the node down and fail over.  ``RemoteError`` (the node ran the op and
  *reported* a failure) is never retried.

``stats`` / ``disk_bytes`` / ``file_count`` are served by the node (the
remote store's counters); the client keeps its own transport-level
``rpc_stats`` (RPCs, retries, bytes) for the cluster layer's telemetry.
"""

from __future__ import annotations

import socket
import threading
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.store import StoreStats
from . import protocol as P
from .server import Address


class NodeUnavailable(ConnectionError):
    """The node could not be reached (after retries)."""


@dataclass
class RpcStats:
    rpcs: int = 0
    retries: int = 0
    connects: int = 0
    failures: int = 0  # RPCs abandoned after all retries
    bytes_sent: int = 0
    bytes_received: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class RemoteKVBlockStore:
    """Client-side ``StorageBackend`` over one remote cache node."""

    name = "remote"

    def __init__(
        self,
        address: Address,
        block_size: Optional[int] = None,
        pool_size: int = 2,
        timeout_s: float = 30.0,
        connect_timeout_s: float = 5.0,
        retries: int = 2,
        max_frame_bytes: int = P.MAX_FRAME_BYTES,
        put_chunk_bytes: int = 32 * 1024 * 1024,
    ):
        """``block_size=None`` fetches it from the node at construction
        (requires the node to be up); pass it explicitly to construct a
        client for a node that may currently be down."""
        self.address = address
        self.pool_size = pool_size
        self.timeout_s = timeout_s
        self.connect_timeout_s = connect_timeout_s
        self.retries = retries
        self.max_frame_bytes = max_frame_bytes
        self.put_chunk_bytes = put_chunk_bytes
        self.rpc_stats = RpcStats()
        self._lock = threading.Lock()
        self._idle: List[socket.socket] = []
        self._closed = False
        if block_size is None:
            block_size = int(self._rpc(P.OP_STATS)["block_size"])
        self.block_size = block_size

    # ------------------------------------------------------------ transport
    def _connect(self) -> socket.socket:
        try:
            if isinstance(self.address, str):
                sock = socket.socket(socket.AF_UNIX)
                sock.settimeout(self.connect_timeout_s)
                sock.connect(self.address)
            else:
                sock = socket.create_connection(
                    tuple(self.address), timeout=self.connect_timeout_s
                )
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError as e:
            raise NodeUnavailable(f"connect to {self.address}: {e}") from e
        sock.settimeout(self.timeout_s)
        with self._lock:
            self.rpc_stats.connects += 1
        return sock

    def _checkout(self) -> socket.socket:
        with self._lock:
            if self._idle:
                return self._idle.pop()
        return self._connect()

    def _checkin(self, sock: socket.socket) -> None:
        with self._lock:
            if not self._closed and len(self._idle) < self.pool_size:
                self._idle.append(sock)
                return
        try:
            sock.close()
        except OSError:
            pass

    def _rpc(self, op: int, *args):
        request = P.encode_request(op, *args)
        if len(request) + 4 > self.max_frame_bytes:
            raise ValueError(
                f"request of {len(request)} bytes exceeds frame cap "
                f"{self.max_frame_bytes}; split the batch"
            )
        last: Optional[Exception] = None
        for attempt in range(self.retries + 1):
            if attempt:
                with self._lock:
                    self.rpc_stats.retries += 1
            sock: Optional[socket.socket] = None
            try:
                sock = self._checkout()
                P.send_frame(sock, request)
                payload = P.recv_frame(sock, self.max_frame_bytes)
                if payload is None:
                    raise P.TruncatedFrame("node closed the connection mid-RPC")
                result = P.decode_response(op, payload)
                with self._lock:
                    self.rpc_stats.rpcs += 1
                    self.rpc_stats.bytes_sent += len(request) + 4
                    self.rpc_stats.bytes_received += len(payload) + 4
                self._checkin(sock)
                return result
            except P.RemoteError:
                # the node is healthy and executed the op: not retryable
                self._checkin(sock)
                raise
            except (OSError, P.ProtocolError) as e:
                last = e
                if sock is not None:
                    try:
                        sock.close()
                    except OSError:
                        pass
        with self._lock:
            self.rpc_stats.failures += 1
        raise NodeUnavailable(f"node {self.address} unreachable: {last}") from last

    def ping(self) -> bool:
        """One round trip; ``False`` if the node is unreachable."""
        try:
            self._rpc(P.OP_PING)
            return True
        except NodeUnavailable:
            return False

    # ------------------------------------------------------------- contract
    def put_batch(
        self,
        tokens: Sequence[int],
        blocks: Sequence[np.ndarray],
        start_block: int = 0,
        skip_existing: bool = True,
    ) -> int:
        return int(
            self._rpc(P.OP_PUT, list(tokens), list(blocks), start_block, skip_existing)
        )

    def probe(self, tokens: Sequence[int]) -> int:
        return int(self._rpc(P.OP_PROBE, list(tokens)))

    def get_batch(self, tokens: Sequence[int], n_tokens: int) -> List[np.ndarray]:
        return self._rpc(P.OP_GET, list(tokens), int(n_tokens))

    def probe_many(self, seqs: Sequence[Sequence[int]]) -> List[int]:
        if not seqs:
            return []
        return [int(v) for v in self._rpc(P.OP_PROBE_MANY, [list(s) for s in seqs])]

    def get_many(
        self, items: Sequence[Tuple[Sequence[int], int]]
    ) -> List[List[np.ndarray]]:
        if not items:
            return []
        return self._rpc(P.OP_GET_MANY, [(list(t), int(n)) for t, n in items])

    def put_many(
        self, items: Sequence[Tuple[Sequence[int], Sequence[np.ndarray], int]]
    ) -> List[int]:
        if not items:
            return []
        # chunk by payload bytes so one giant batch can't trip the frame cap
        out: List[int] = []
        chunk: list = []
        chunk_bytes = 0
        for tokens, blocks, start in items:
            nbytes = sum(np.asarray(b).nbytes for b in blocks)
            if chunk and chunk_bytes + nbytes > self.put_chunk_bytes:
                out.extend(int(v) for v in self._rpc(P.OP_PUT_MANY, chunk))
                chunk, chunk_bytes = [], 0
            chunk.append((list(tokens), list(blocks), int(start)))
            chunk_bytes += nbytes
        if chunk:
            out.extend(int(v) for v in self._rpc(P.OP_PUT_MANY, chunk))
        return out

    def maintenance(self, compact_steps: int = 8) -> dict:
        return self._rpc(P.OP_MAINTENANCE, int(compact_steps))

    def flush(self) -> None:
        self._rpc(P.OP_FLUSH)

    def close(self) -> None:
        """Close the client's connections (the node itself stays up — its
        lifecycle belongs to whoever spawned it)."""
        with self._lock:
            self._closed = True
            idle, self._idle = self._idle, []
        for sock in idle:
            try:
                sock.close()
            except OSError:
                pass

    # ---------------------------------------------------------------- stats
    def node_report(self) -> dict:
        """Raw node-side report: store stats + server transport counters."""
        return self._rpc(P.OP_STATS)

    @property
    def stats(self) -> StoreStats:
        remote = self.node_report()["stats"]
        out = StoreStats()
        for k, v in remote.items():
            if hasattr(out, k):
                setattr(out, k, v)
        return out

    @property
    def disk_bytes(self) -> int:
        return int(self.node_report()["disk_bytes"])

    @property
    def file_count(self) -> int:
        return int(self.node_report()["file_count"])
