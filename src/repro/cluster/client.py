"""``RemoteKVBlockStore`` — a ``StorageBackend`` whose storage lives in
another process.

The client speaks the multiplexed frame protocol to one
``CacheNodeServer`` and exposes the full backend contract, so everything
built against the protocol (``CacheHierarchy``, ``ServingEngine``, the
write-behind ``CommitQueue``, benchmarks) runs against a remote node
unchanged — the network hop is a constructor argument, never a code
change.

Mechanics:

* **Multiplexing** — one connection per node; every RPC is tagged with a
  request id, so any number of callers (the engine's I/O executor, the
  commit-queue drain thread) have requests in flight *concurrently* on
  the same socket, and responses return in whatever order the node
  finishes them.  The read side is serviced by a shared ``MuxLoop``
  selector thread (pass ``mux_loop`` to share one loop across a whole
  cluster's clients); decode runs on the calling thread.
* **Streaming gets** — ``get_batch_stream`` yields blocks as their
  chunks arrive, so a consumer starts on block 0 while blocks 1..N are
  still on the wire; ``get_batch``/``get_many`` are assembled from the
  same chunk stream.  ``BlockStream.first_block_s`` measures
  time-to-first-block, the metric the serving benchmarks report.
* **Request batching** — the multi-sequence ops (``probe_many`` /
  ``get_many`` / ``put_many``) ship as *one* RPC, so a whole engine
  batch pays one round trip instead of one per sequence.  ``put_many``
  batches are split when their payload would approach the frame cap.

Error taxonomy (strict, and load-bearing for the cluster layer):

* **Transport errors** — socket errors, timeouts, connection loss, and
  *framing* violations (``TruncatedFrame``, ``FrameTooLarge``) — are
  retried on a fresh connection up to ``retries`` times; persistent
  failure raises ``NodeUnavailable``, the signal the cluster store uses
  to mark the node down and fail over.  Every backend op is idempotent
  (puts are content-addressed, probes and gets are reads), so retry is
  always safe.  A stream that breaks after its first chunk is **not**
  retried here — it raises ``NodeUnavailable`` immediately so the
  caller can fail over to a replica without re-paying the prefix.
* **Application errors** — ``RemoteError`` (the node ran the op and
  reported a failure) and ``ProtocolError`` from *body* decode (the
  frame arrived whole but its contents are malformed) — are never
  retried and never mapped to ``NodeUnavailable``: they indicate a bug
  or corruption, not an unreachable node, and hiding them behind retry
  would turn data errors into spurious failovers.

On any error path the request id is detached before the exception
propagates, so a waiter is never leaked; a send failure or framing
violation poisons the whole connection (its stream position is
unknown), failing all of its in-flight requests with the transport
error, and the next RPC dials fresh.
"""

from __future__ import annotations

import threading
import time
import socket
from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core.store import StoreStats
from ..obs.tracing import current_trace
from . import protocol as P
from .mux import MuxConnection, MuxLoop, _StreamWaiter, _UnaryWaiter
from .server import Address

# transport-level failures: retryable, and the NodeUnavailable trigger.
# Plain ProtocolError (malformed body) is deliberately NOT here.
TRANSPORT_ERRORS = (OSError, P.TruncatedFrame, P.FrameTooLarge)


class NodeUnavailable(ConnectionError):
    """The node could not be reached (after retries)."""


@dataclass
class RpcStats:
    rpcs: int = 0
    retries: int = 0
    connects: int = 0
    failures: int = 0  # RPCs abandoned after all retries
    bytes_sent: int = 0
    bytes_received: int = 0
    streams: int = 0
    stream_chunks: int = 0
    stream_blocks: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class BlockStream:
    """Iterator over one sequence's blocks as they arrive off the wire.

    ``first_block_s`` is the wall-clock delay from request send to the
    first block being available (time-to-first-block); ``served`` counts
    blocks yielded so far.  Iteration raises ``NodeUnavailable`` if the
    transport dies mid-stream — a partial prefix was yielded, and it is
    the *caller's* job to treat it as partial (the cluster store resumes
    from a replica; the hierarchy truncates to what arrived)."""

    def __init__(self, events: Iterator):
        self._events = events
        self._t0 = time.perf_counter()
        self.first_block_s: Optional[float] = None
        self.served = 0

    def __iter__(self):
        for kind, data in self._events:
            if kind == "chunk":
                _, start_block, blocks = data
                if start_block != self.served:
                    raise P.ProtocolError(
                        f"stream chunk starts at block {start_block}, expected {self.served}"
                    )
                for b in blocks:
                    if self.first_block_s is None:
                        self.first_block_s = time.perf_counter() - self._t0
                    self.served += 1
                    yield b
            else:  # end
                counts = data
                if counts and counts[0] != self.served:
                    raise P.ProtocolError(
                        f"stream end reports {counts[0]} blocks, received {self.served}"
                    )
                return

    def close(self) -> None:
        self._events.close()


class RemoteKVBlockStore:
    """Client-side ``StorageBackend`` over one remote cache node."""

    name = "remote"

    def __init__(
        self,
        address: Address,
        block_size: Optional[int] = None,
        timeout_s: float = 30.0,
        connect_timeout_s: float = 5.0,
        retries: int = 2,
        max_frame_bytes: int = P.MAX_FRAME_BYTES,
        put_chunk_bytes: int = 32 * 1024 * 1024,
        chunk_blocks: int = 4,
        mux_loop: Optional[MuxLoop] = None,
        pool_size: Optional[int] = None,  # retained for compat; mux needs one conn
    ):
        """``block_size=None`` fetches it from the node at construction
        (requires the node to be up); pass it explicitly to construct a
        client for a node that may currently be down.  ``chunk_blocks``
        is the streaming granularity requested from the node (blocks per
        CHUNK frame).  Pass a shared ``mux_loop`` to run many node
        clients off one selector thread (the cluster store does)."""
        self.address = address
        self.timeout_s = timeout_s
        self.connect_timeout_s = connect_timeout_s
        self.retries = retries
        self.max_frame_bytes = max_frame_bytes
        self.put_chunk_bytes = put_chunk_bytes
        self.chunk_blocks = max(1, int(chunk_blocks))
        self.rpc_stats = RpcStats()
        self._lock = threading.Lock()
        self._mux: Optional[MuxConnection] = None
        self._owns_loop = mux_loop is None
        self._loop = mux_loop if mux_loop is not None else MuxLoop()
        self._closed = False
        if block_size is None:
            block_size = int(self._rpc(P.OP_STATS)["block_size"])
        self.block_size = block_size

    # ------------------------------------------------------------ transport
    def _dial(self) -> socket.socket:
        try:
            if isinstance(self.address, str):
                sock = socket.socket(socket.AF_UNIX)
                sock.settimeout(self.connect_timeout_s)
                sock.connect(self.address)
            else:
                sock = socket.create_connection(
                    tuple(self.address), timeout=self.connect_timeout_s
                )
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError as e:
            raise NodeUnavailable(f"connect to {self.address}: {e}") from e
        with self._lock:
            self.rpc_stats.connects += 1
        return sock

    def _conn(self) -> MuxConnection:
        with self._lock:
            if self._closed:
                raise NodeUnavailable(f"client for {self.address} is closed")
            if self._mux is not None and self._mux.alive:
                return self._mux
        sock = self._dial()
        conn = MuxConnection(sock, self._loop, self.max_frame_bytes, self.timeout_s)
        with self._lock:
            if self._closed or (self._mux is not None and self._mux.alive):
                # lost the dial race (or closed meanwhile): keep the winner
                winner = self._mux
                conn.close()
                if self._closed or winner is None:
                    raise NodeUnavailable(f"client for {self.address} is closed")
                return winner
            self._mux = conn
            return conn

    def _transport_call(self, op: int, args: tuple) -> bytes:
        """One attempt: send a tagged request, wait for its RESPONSE.
        Raises only transport errors (or the caller's own bugs)."""
        request = P.encode_request(op, *args)
        if len(request) + 4 + P.MUX_HDR_BYTES > self.max_frame_bytes:
            raise ValueError(
                f"request of {len(request)} bytes exceeds frame cap "
                f"{self.max_frame_bytes}; split the batch"
            )
        conn = self._conn()
        waiter = _UnaryWaiter()
        rid = conn.attach(waiter)
        tr = current_trace()
        try:
            sent = conn.send_request(rid, request,
                                     trace=tr.id_bytes() if tr else None)
            payload = waiter.wait(self.timeout_s)
        finally:
            conn.detach(rid)  # never leak a waiter, success or not
        with self._lock:
            self.rpc_stats.rpcs += 1
            self.rpc_stats.bytes_sent += sent
            self.rpc_stats.bytes_received += len(payload) + 4 + P.MUX_HDR_BYTES
        return payload

    def _rpc(self, op: int, *args):
        last: Optional[Exception] = None
        for attempt in range(self.retries + 1):
            if attempt:
                with self._lock:
                    self.rpc_stats.retries += 1
            try:
                payload = self._transport_call(op, args)
            except NodeUnavailable as e:
                last = e
                continue
            except TRANSPORT_ERRORS as e:
                last = e
                continue
            # Decode outside the retry net: RemoteError (node reported a
            # failure) and ProtocolError (malformed body) are application
            # errors — raising them here, not retrying, is the contract.
            return P.decode_response(op, payload)
        with self._lock:
            self.rpc_stats.failures += 1
        raise NodeUnavailable(f"node {self.address} unreachable: {last}") from last

    def ping(self) -> bool:
        """One round trip; ``False`` if the node is unreachable."""
        try:
            self._rpc(P.OP_PING)
            return True
        except NodeUnavailable:
            return False

    # ------------------------------------------------------------ streaming
    def _stream_events(self, op: int, *args) -> Iterator:
        """Generator of decoded stream events: ``("chunk", (seq_index,
        start_block, blocks))`` then ``("end", counts)``.  Transport
        failures are retried only while nothing has arrived; after the
        first chunk they raise ``NodeUnavailable`` (the caller fails
        over rather than re-pulling the prefix)."""
        request = P.encode_request(op, *args)
        last: Optional[Exception] = None
        for attempt in range(self.retries + 1):
            if attempt:
                with self._lock:
                    self.rpc_stats.retries += 1
            got_any = False
            try:
                conn = self._conn()
                waiter = _StreamWaiter()
                rid = conn.attach(waiter)
                tr = current_trace()
                try:
                    sent = conn.send_request(rid, request,
                                             trace=tr.id_bytes() if tr else None)
                    with self._lock:
                        self.rpc_stats.streams += 1
                        self.rpc_stats.bytes_sent += sent
                    while True:
                        kind, payload = waiter.next_event(self.timeout_s)
                        if kind == "err":
                            raise payload
                        with self._lock:
                            self.rpc_stats.bytes_received += len(payload) + 4 + P.MUX_HDR_BYTES
                        if kind == "chunk":
                            got_any = True
                            seq, start, blocks = P.decode_stream_chunk(payload)
                            with self._lock:
                                self.rpc_stats.stream_chunks += 1
                                self.rpc_stats.stream_blocks += len(blocks)
                            yield ("chunk", (seq, start, blocks))
                        else:  # end
                            yield ("end", P.decode_stream_end(payload))
                            return
                finally:
                    conn.detach(rid)
            except NodeUnavailable as e:
                last = e
            except TRANSPORT_ERRORS as e:
                last = e
            if got_any:
                # mid-stream loss: the caller has a partial prefix; do not
                # silently restart — surface it for replica failover
                with self._lock:
                    self.rpc_stats.failures += 1
                raise NodeUnavailable(
                    f"node {self.address} died mid-stream: {last}"
                ) from last
        with self._lock:
            self.rpc_stats.failures += 1
        raise NodeUnavailable(f"node {self.address} unreachable: {last}") from last

    def get_batch_stream(
        self, tokens: Sequence[int], n_tokens: int, chunk_blocks: Optional[int] = None
    ) -> BlockStream:
        """Stream the cached blocks covering ``tokens[:n_tokens]`` as
        they arrive.  Lazy: the request is sent on first iteration, and
        ``first_block_s`` measures from construction — construct and
        consume promptly."""
        cb = self.chunk_blocks if chunk_blocks is None else max(1, int(chunk_blocks))
        events = self._stream_events(
            P.OP_GET_STREAM, list(tokens), int(n_tokens), cb
        )
        return BlockStream(events)

    # ------------------------------------------------------------- contract
    def put_batch(
        self,
        tokens: Sequence[int],
        blocks: Sequence[np.ndarray],
        start_block: int = 0,
        skip_existing: bool = True,
    ) -> int:
        return int(
            self._rpc(P.OP_PUT, list(tokens), list(blocks), start_block, skip_existing)
        )

    def probe(self, tokens: Sequence[int]) -> int:
        return int(self._rpc(P.OP_PROBE, list(tokens)))

    def get_batch(self, tokens: Sequence[int], n_tokens: int) -> List[np.ndarray]:
        return list(self.get_batch_stream(tokens, n_tokens))

    def probe_many(self, seqs: Sequence[Sequence[int]]) -> List[int]:
        if not seqs:
            return []
        return [int(v) for v in self._rpc(P.OP_PROBE_MANY, [list(s) for s in seqs])]

    def get_many(
        self, items: Sequence[Tuple[Sequence[int], int]]
    ) -> List[List[np.ndarray]]:
        if not items:
            return []
        out: List[List[np.ndarray]] = [[] for _ in items]
        events = self._stream_events(
            P.OP_GET_MANY_STREAM,
            [(list(t), int(n)) for t, n in items],
            self.chunk_blocks,
        )
        for kind, data in events:
            if kind == "chunk":
                si, start, blocks = data
                if si >= len(out) or start != len(out[si]):
                    raise P.ProtocolError(
                        f"stream chunk for seq {si} starts at {start}, "
                        f"expected {len(out[si]) if si < len(out) else '<bad seq>'}"
                    )
                out[si].extend(blocks)
            else:
                counts = data
                if counts != [len(o) for o in out]:
                    raise P.ProtocolError(
                        f"stream end counts {counts} != received {[len(o) for o in out]}"
                    )
        return out

    def put_many(
        self, items: Sequence[Tuple[Sequence[int], Sequence[np.ndarray], int]]
    ) -> List[int]:
        if not items:
            return []
        # chunk by payload bytes so one giant batch can't trip the frame cap
        out: List[int] = []
        chunk: list = []
        chunk_bytes = 0
        for tokens, blocks, start in items:
            nbytes = sum(np.asarray(b).nbytes for b in blocks)
            if chunk and chunk_bytes + nbytes > self.put_chunk_bytes:
                out.extend(int(v) for v in self._rpc(P.OP_PUT_MANY, chunk))
                chunk, chunk_bytes = [], 0
            chunk.append((list(tokens), list(blocks), int(start)))
            chunk_bytes += nbytes
        if chunk:
            out.extend(int(v) for v in self._rpc(P.OP_PUT_MANY, chunk))
        return out

    # -------------------------------------------------- elasticity (migration)
    # All three are idempotent (scan/pull are reads; push dedups on the
    # receiving node), so the generic transport retry applies unchanged.

    def scan_keys(
        self,
        cursor: Optional[bytes] = None,
        limit: int = 1024,
        ranges: Sequence[Tuple[int, int]] = (),
    ) -> Tuple[List[bytes], Optional[bytes]]:
        """One page of the node's live keys (``(keys, next_cursor)``),
        optionally filtered to the given half-open wrapping ring arcs.
        ``limit`` bounds keys *examined* node-side, so a filtered page may
        come back short — or empty with a non-None cursor; loop until the
        cursor is None."""
        keys, next_cursor = self._rpc(P.OP_SCAN, cursor, int(limit), list(ranges))
        return keys, next_cursor

    def export_encoded(self, keys: Sequence[bytes]) -> List[Optional[Tuple[int, bytes]]]:
        """Stored records for ``keys`` as ``(tier_flags, payload)`` pairs in
        their stored encoding (``None`` where absent), aligned with ``keys``."""
        if not keys:
            return []
        return self._rpc(P.OP_PULL, [bytes(k) for k in keys])

    def import_encoded(self, records, skip_existing: bool = True) -> int:
        """Push ``(key, flags, payload)`` records to the node verbatim;
        returns blocks actually written (duplicates skipped).  Batches are
        split by payload bytes so one migration page cannot trip the
        frame cap."""
        total = 0
        chunk: list = []
        chunk_bytes = 0
        for key, flags, payload in records:
            if chunk and chunk_bytes + len(payload) > self.put_chunk_bytes:
                total += int(self._rpc(P.OP_PUSH, chunk, skip_existing))
                chunk, chunk_bytes = [], 0
            chunk.append((bytes(key), int(flags), bytes(payload)))
            chunk_bytes += len(payload)
        if chunk:
            total += int(self._rpc(P.OP_PUSH, chunk, skip_existing))
        return total

    def maintenance(self, compact_steps: int = 8) -> dict:
        return self._rpc(P.OP_MAINTENANCE, int(compact_steps))

    def flush(self) -> None:
        self._rpc(P.OP_FLUSH)

    def close(self) -> None:
        """Close the client's connection (the node itself stays up — its
        lifecycle belongs to whoever spawned it)."""
        with self._lock:
            self._closed = True
            conn, self._mux = self._mux, None
        if conn is not None:
            conn.close()
        if self._owns_loop:
            self._loop.close()

    # ---------------------------------------------------------------- stats
    def node_report(self) -> dict:
        """Raw node-side report: store stats + server transport counters,
        plus this client's own transport-level view."""
        report = self._rpc(P.OP_STATS)
        report["client"] = self.rpc_stats.as_dict()
        return report

    def metrics(self) -> dict:
        """The node's full metrics-registry snapshot (``OP_METRICS``):
        counters, gauges, latency histograms with p50/p95/p99, and the
        recent trace ids the node closed out."""
        return self._rpc(P.OP_METRICS)

    @property
    def stats(self) -> StoreStats:
        remote = self.node_report()["stats"]
        out = StoreStats()
        for k, v in remote.items():
            if hasattr(out, k):
                setattr(out, k, v)
        return out

    @property
    def disk_bytes(self) -> int:
        return int(self.node_report()["disk_bytes"])

    @property
    def file_count(self) -> int:
        return int(self.node_report()["file_count"])
