"""Consistent-hash ring for cache-node placement.

Each node owns ``vnodes`` pseudo-random points on a 64-bit ring; a key
hashes to a point and its *preference list* is the distinct nodes met
walking clockwise from there.  Replica sets are prefixes of the
preference list, which gives the two properties the cluster layer needs:

* **Minimal movement** — adding or removing one node only remaps the
  ring arcs that node's points owned (~1/N of the keyspace), so a node
  rejoin is a local rebalance, not a full reshuffle (contrast modulo
  hashing, where N → N±1 remaps almost every key).
* **Stable failover order** — the preference list with node *k* filtered
  out is exactly the preference list of the ring without *k*: readers
  that skip a dead node land on the same replica that writes re-routed
  to, with no coordination.

Keys are the same first-block routing hash ``ShardedKVBlockStore`` uses
(``key_hash``), so a whole prefix tree lands on one node and probes stay
node-local — the cross-process analogue of in-process sharding.
"""

from __future__ import annotations

import bisect
import hashlib
from typing import List, Sequence

from ..core.keycodec import encode_tokens


def key_hash(tokens: Sequence[int], block_size: int) -> int:
    """64-bit ring position of a token sequence: hash of the first block
    (stable across processes — blake2b, never ``hash()``)."""
    head = encode_tokens(tokens[: min(block_size, len(tokens))])
    return int.from_bytes(hashlib.blake2b(head, digest_size=8).digest(), "little")


def _point(node_id: str, vnode: int) -> int:
    h = hashlib.blake2b(f"{node_id}#{vnode}".encode(), digest_size=8).digest()
    return int.from_bytes(h, "little")


class HashRing:
    """Static ring over ``node_ids`` (index-addressed); membership changes
    are the *caller's* concern (the cluster store keeps a down-set and
    filters, so the ring itself never rehashes at runtime)."""

    def __init__(self, node_ids: Sequence[str], vnodes: int = 64):
        if not node_ids:
            raise ValueError("ring needs at least one node")
        if len(set(node_ids)) != len(node_ids):
            raise ValueError(f"duplicate node ids: {list(node_ids)}")
        self.node_ids = list(node_ids)
        self.vnodes = vnodes
        pts = [
            (_point(nid, v), idx)
            for idx, nid in enumerate(self.node_ids)
            for v in range(vnodes)
        ]
        pts.sort()
        self._points = [p for p, _ in pts]
        self._owners = [i for _, i in pts]

    def __len__(self) -> int:
        return len(self.node_ids)

    def preference(self, khash: int) -> List[int]:
        """All node indices in clockwise order from ``khash`` (each node
        once, first occurrence wins).  ``preference(k)[:r]`` is the
        r-replica set; survivors keep their relative order when a node is
        filtered out."""
        start = bisect.bisect_left(self._points, khash) % len(self._points)
        seen: List[int] = []
        mask = set()
        for i in range(len(self._points)):
            owner = self._owners[(start + i) % len(self._points)]
            if owner not in mask:
                mask.add(owner)
                seen.append(owner)
                if len(seen) == len(self.node_ids):
                    break
        return seen

    def primary(self, khash: int) -> int:
        return self.preference(khash)[0]
