"""Consistent-hash ring for cache-node placement.

Each node owns ``vnodes`` pseudo-random points on a 64-bit ring; a key
hashes to a point and its *preference list* is the distinct nodes met
walking clockwise from there.  Replica sets are prefixes of the
preference list, which gives the two properties the cluster layer needs:

* **Minimal movement** — adding or removing one node only remaps the
  ring arcs that node's points owned (~1/N of the keyspace), so a node
  rejoin is a local rebalance, not a full reshuffle (contrast modulo
  hashing, where N → N±1 remaps almost every key).
* **Stable failover order** — the preference list with node *k* filtered
  out is exactly the preference list of the ring without *k*: readers
  that skip a dead node land on the same replica that writes re-routed
  to, with no coordination.

Keys are the same first-block routing hash ``ShardedKVBlockStore`` uses
(``key_hash``), so a whole prefix tree lands on one node and probes stay
node-local — the cross-process analogue of in-process sharding.
"""

from __future__ import annotations

import bisect
import hashlib
from typing import List, Sequence, Tuple

from ..core.keycodec import TOKEN_WIDTH, encode_tokens


def key_hash(tokens: Sequence[int], block_size: int) -> int:
    """64-bit ring position of a token sequence: hash of the first block
    (stable across processes — blake2b, never ``hash()``)."""
    head = encode_tokens(tokens[: min(block_size, len(tokens))])
    return int.from_bytes(hashlib.blake2b(head, digest_size=8).digest(), "little")


def raw_key_hash(key: bytes, block_size: int) -> int:
    """Ring position of an already-encoded index key.  The key is the
    big-endian token encoding, so its first ``TOKEN_WIDTH * block_size``
    bytes are exactly ``encode_tokens(tokens[:block_size])`` — a node can
    place any stored key on the ring without decoding tokens."""
    head = bytes(key[: TOKEN_WIDTH * block_size])
    return int.from_bytes(hashlib.blake2b(head, digest_size=8).digest(), "little")


def _point(node_id: str, vnode: int) -> int:
    h = hashlib.blake2b(f"{node_id}#{vnode}".encode(), digest_size=8).digest()
    return int.from_bytes(h, "little")


class HashRing:
    """Immutable ring over ``node_ids`` (index-addressed).  One ring never
    rehashes — runtime *failures* are handled by the caller filtering its
    down-set out of preference lists.  Membership *changes* are a new
    ring: the cluster store holds the old and new rings side by side as a
    ``TransitionView`` while ``cluster.migration`` copies the moved arcs,
    then drops the old ring."""

    def __init__(self, node_ids: Sequence[str], vnodes: int = 64):
        if not node_ids:
            raise ValueError("ring needs at least one node")
        if len(set(node_ids)) != len(node_ids):
            raise ValueError(f"duplicate node ids: {list(node_ids)}")
        self.node_ids = list(node_ids)
        self.vnodes = vnodes
        pts = [
            (_point(nid, v), idx)
            for idx, nid in enumerate(self.node_ids)
            for v in range(vnodes)
        ]
        pts.sort()
        self._points = [p for p, _ in pts]
        self._owners = [i for _, i in pts]

    def __len__(self) -> int:
        return len(self.node_ids)

    def preference(self, khash: int) -> List[int]:
        """All node indices in clockwise order from ``khash`` (each node
        once, first occurrence wins).  ``preference(k)[:r]`` is the
        r-replica set; survivors keep their relative order when a node is
        filtered out."""
        start = bisect.bisect_left(self._points, khash) % len(self._points)
        seen: List[int] = []
        mask = set()
        for i in range(len(self._points)):
            owner = self._owners[(start + i) % len(self._points)]
            if owner not in mask:
                mask.add(owner)
                seen.append(owner)
                if len(seen) == len(self.node_ids):
                    break
        return seen

    def primary(self, khash: int) -> int:
        return self.preference(khash)[0]

    def preference_ids(self, khash: int) -> List[str]:
        """``preference`` mapped to node ids — the stable vocabulary for
        comparing placement across two rings (indices are ring-local)."""
        return [self.node_ids[i] for i in self.preference(khash)]


_RING_BITS = 64
_RING_SIZE = 1 << _RING_BITS


def in_arc(lo: int, hi: int, khash: int) -> bool:
    """True iff ``khash`` lies in the half-open wrapping arc ``(lo, hi]``.

    Arcs are half-open on the *low* side because ``preference`` uses
    ``bisect_left``: a key hashing exactly onto a ring point is owned by
    that point, so the arc owned by point ``p`` with predecessor ``q`` is
    ``(q, p]``.  ``lo == hi`` denotes the full ring.
    """
    if lo == hi:
        return True
    if lo < hi:
        return lo < khash <= hi
    return khash > lo or khash <= hi


def _merge_arcs(arcs: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """Coalesce adjacent elementary arcs (``a.hi == b.lo``), including the
    pair that meets across the 0 wrap."""
    if not arcs:
        return []
    merged: List[Tuple[int, int]] = [arcs[0]]
    for lo, hi in arcs[1:]:
        if merged[-1][1] == lo:
            merged[-1] = (merged[-1][0], hi)
        else:
            merged.append((lo, hi))
    if len(merged) > 1 and merged[-1][1] == merged[0][0]:
        merged[0] = (merged[-1][0], merged[0][1])
        merged.pop()
    return merged


def moved_arcs(old: "HashRing", new: "HashRing", replicas: int) -> List[Tuple[int, int]]:
    """Arcs of the keyspace whose r-replica owner set *gained a node* going
    from ``old`` to ``new``.

    Walks the elementary arcs induced by the union of both rings' points
    (within one such arc, both preference lists are constant) and keeps
    the arcs where some new owner is not an old owner — exactly the keys
    a migration has to copy.  Keys whose owner set only *shrank* need no
    copying: the surviving owners already hold them.  Returned arcs are
    half-open ``(lo, hi]`` (see ``in_arc``), merged where adjacent;
    ``[(h, h)]`` — the full ring — may be returned for single-point
    degenerate cases.
    """
    r = max(1, replicas)
    bounds = sorted(set(old._points) | set(new._points))
    if not bounds:
        return []
    moved: List[Tuple[int, int]] = []
    for i, hi in enumerate(bounds):
        lo = bounds[i - 1] if i else bounds[-1]
        # representative: the arc's inclusive upper bound
        old_ids = set(old.preference_ids(hi)[:r])
        new_ids = set(new.preference_ids(hi)[:r])
        if not new_ids <= old_ids:
            moved.append((lo, hi))
    if len(moved) == len(bounds):
        h = bounds[0]
        return [(h, h)]  # whole ring moved
    return _merge_arcs(moved)


class TransitionView:
    """Two-ring routing during a membership change.

    Writes target the **new** ring only (new data should land where it
    will live).  Reads consult the new owners first, then the old owners,
    so a key is reachable *wherever it currently lives* while
    ``cluster.migration`` copies the ``moved`` arcs in the background.
    Once the migrator drains, the cluster store drops the view and the
    new ring stands alone.
    """

    def __init__(self, old: HashRing, new: HashRing, replicas: int):
        self.old = old
        self.new = new
        self.replicas = max(1, replicas)
        self.moved = moved_arcs(old, new, self.replicas)

    def key_moved(self, khash: int) -> bool:
        return any(in_arc(lo, hi, khash) for lo, hi in self.moved)

    def write_ids(self, khash: int) -> List[str]:
        return self.new.preference_ids(khash)

    def read_ids(self, khash: int) -> List[str]:
        """New-ring r-owners, then old-ring r-owners, deduplicated in
        order.  Every pre-transition replica location appears, so no key
        is lost between old and new ownership mid-migration."""
        r = self.replicas
        out = list(self.new.preference_ids(khash)[:r])
        seen = set(out)
        for nid in self.old.preference_ids(khash)[:r]:
            if nid not in seen:
                seen.add(nid)
                out.append(nid)
        return out


def affected_arcs(ring: HashRing, node_ids: Sequence[str], replicas: int) -> List[Tuple[int, int]]:
    """Arcs whose r-replica owner set intersects ``node_ids`` — the key
    ranges that lost a replica when those nodes died, i.e. the ranges a
    replica repair has to re-copy onto the surviving owners."""
    r = max(1, replicas)
    targets = set(node_ids)
    bounds = ring._points
    if not bounds:
        return []
    hit: List[Tuple[int, int]] = []
    for i, hi in enumerate(bounds):
        lo = bounds[i - 1] if i else bounds[-1]
        if targets & set(ring.preference_ids(hi)[:r]):
            hit.append((lo, hi))
    if len(hit) == len(bounds):
        h = bounds[0]
        return [(h, h)]
    return _merge_arcs(hit)
