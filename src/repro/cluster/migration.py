"""Background block migration and replica repair for the cache cluster.

``BlockMigrator`` is the data-movement half of elastic membership: when
the cluster store swaps rings (``add_node`` / ``remove_node``) or a death
leaves key ranges at R-1 surviving copies, the migrator copies exactly
the affected ring arcs onto their new/surviving owners.  It runs on the
**maintenance cadence** — every ``ClusterKVBlockStore.maintenance`` cycle
drives one ``step`` — so movement is deterministic, caller-scheduled
work, never a background thread (the same scheduling contract as every
other maintenance job in the repo).

One step walks each live source node's keyspace in pages through the
arc-filtered ``OP_SCAN`` RPC, pulls the matching records **in their
stored encoding** (``OP_PULL`` — an int8+zlib cold block crosses the
wire compressed), and pushes them to the key's current owners
(``OP_PUSH``).  Safety comes from idempotence, not coordination:

* every record push dedups on the receiving node (``skip_existing``), so
  retries, overlapping repair rounds, and replica sources re-offering
  the same block never double-count — ``blocks_copied`` counts blocks
  actually written;
* sources are never deleted from: the cluster is a cache, and the
  source's copy ages out through its own budget eviction.  A migration
  interrupted anywhere (including SIGKILL of either end) therefore
  loses nothing that was committed — the transition view keeps reads
  consulting old owners until the copy provably drained;
* a node death mid-step just marks the node down and moves on; the
  surviving sources' scans still cover every key that has a surviving
  copy (replicas hold the same arcs).

Completion: when every live source has exhausted its arc scan, a
rebalance task promotes the new ring (the store drops its transition
view) and a repair task records the down-set as repaired.  Per-task wall
times land in ``MigrationStats`` (``rebalance_s`` — time-to-rebalance —
and ``repair_lag_s``, measured from when the dead node was first marked
down), bridged into the cluster registry as ``repro_migration_*``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .client import NodeUnavailable
from .ring import TransitionView, raw_key_hash


@dataclass
class MigrationStats:
    """Counters for cluster data movement (``repro_migration_*`` gauges).

    ``blocks_copied`` is exact (import-side dedup); ``bytes_moved``
    counts stored-encoding payload bytes offered over the wire, i.e. the
    network cost of the movement.  The ``*_s`` fields hold the most
    recent completed task's wall times.
    """

    migrations_started: int = 0
    migrations_completed: int = 0
    repairs_started: int = 0
    repairs_completed: int = 0
    rounds: int = 0  # migrator steps that had an active task
    keys_scanned: int = 0  # arc-matching keys returned by source scans
    blocks_pulled: int = 0  # records exported from sources
    blocks_copied: int = 0  # records actually written at destinations
    repair_blocks: int = 0  # subset of blocks_copied written by repair tasks
    bytes_moved: int = 0  # stored-encoding payload bytes shipped
    rebalance_s: float = 0.0  # wall time of the last completed rebalance
    repair_s: float = 0.0  # wall time of the last completed repair
    repair_lag_s: float = 0.0  # last repair: death detection -> full R copies

    def as_dict(self) -> dict:
        return dict(self.__dict__)


@dataclass
class _Task:
    kind: str  # "rebalance" | "repair"
    arcs: List[Tuple[int, int]]
    t0: float
    cursors: Dict[int, bytes] = field(default_factory=dict)
    exhausted: Set[int] = field(default_factory=set)
    down_t0: Optional[float] = None  # earliest mark-down of the repaired set
    target_down: FrozenSet[int] = frozenset()


class BlockMigrator:
    """Drives arc copies for one ``ClusterKVBlockStore``.

    At most one task is active at a time; a membership change during a
    repair supersedes it (the repair re-triggers afterwards — the store
    only records a down-set as repaired when its task completes).
    """

    def __init__(self, store, page_keys: int = 512):
        self.store = store
        self.page_keys = max(1, int(page_keys))
        self.stats = MigrationStats()
        self._task: Optional[_Task] = None

    @property
    def active(self) -> bool:
        return self._task is not None

    @property
    def task_kind(self) -> Optional[str]:
        return self._task.kind if self._task is not None else None

    # ------------------------------------------------------------- lifecycle
    def begin_rebalance(self, view: TransitionView) -> None:
        """Start (or restart, folding in a further membership change)
        copying the transition view's moved arcs.  Every live node is a
        source — replicas and previously-added nodes may hold moved keys
        too, and an empty node's scan costs one RPC."""
        self._task = _Task(kind="rebalance", arcs=list(view.moved), t0=time.monotonic())
        with self.store._lock:
            self.stats.migrations_started += 1

    def begin_repair(
        self,
        down: FrozenSet[int],
        arcs: List[Tuple[int, int]],
        down_t0: Optional[float],
    ) -> None:
        """Re-replicate ``arcs`` (the ranges whose R-replica set includes a
        node in ``down``) from the surviving copies onto the keys' live
        owners, restoring R copies."""
        self._task = _Task(
            kind="repair", arcs=list(arcs), t0=time.monotonic(),
            down_t0=down_t0, target_down=frozenset(down),
        )
        with self.store._lock:
            self.stats.repairs_started += 1

    # ------------------------------------------------------------------ step
    def step(self, max_pages: Optional[int] = None) -> dict:
        """Advance the active task.  By default a step drains the task to
        completion (bounded by a generous page cap), so the acceptance
        cadence — rebalance finishes within one maintenance cycle —
        holds; pass a small ``max_pages`` to move incrementally (the
        fault-injection tests do, to kill nodes mid-migration)."""
        task = self._task
        if task is None:
            return {"active": False}
        st = self.store
        budget = 100_000 if max_pages is None else max(1, int(max_pages))
        pages = copied = 0
        with st._lock:
            self.stats.rounds += 1
        if task.arcs:
            for src in list(st.live_nodes):
                if src in task.exhausted or pages >= budget:
                    continue
                while pages < budget:
                    try:
                        keys, nxt = st.nodes[src].scan_keys(
                            task.cursors.get(src), self.page_keys, ranges=task.arcs
                        )
                    except NodeUnavailable:
                        st.mark_down(src)
                        break
                    pages += 1
                    with st._lock:
                        self.stats.keys_scanned += len(keys)
                    if keys:
                        copied += self._copy(src, keys, task)
                    if nxt is None:
                        task.exhausted.add(src)
                        task.cursors.pop(src, None)
                        break
                    task.cursors[src] = nxt
        else:
            task.exhausted.update(st.live_nodes)
        done = all(i in task.exhausted for i in st.live_nodes)
        if done:
            self._finish(task)
        return {
            "active": self._task is not None,
            "kind": task.kind,
            "pages": pages,
            "copied": copied,
            "done": done,
        }

    # ------------------------------------------------------------ internals
    def _dests(self, khash: int, exclude: int) -> List[int]:
        """The key's first R live owners under the *target* ring, minus
        the source (which already holds the block)."""
        st = self.store
        pref = st._pref_indices(khash)
        with st._lock:
            dead = st._down | st._retired
        live = [i for i in pref if i not in dead]
        return [i for i in live[: st.replication] if i != exclude]

    def _copy(self, src: int, keys: List[bytes], task: _Task) -> int:
        st = self.store
        try:
            recs = st.nodes[src].export_encoded(keys)
        except NodeUnavailable:
            st.mark_down(src)
            return 0
        by_dest: Dict[int, list] = {}
        pulled = 0
        for key, rec in zip(keys, recs):
            if rec is None:
                continue  # key aged out between scan and pull — cache semantics
            pulled += 1
            flags, payload = rec
            khash = raw_key_hash(key, st.block_size)
            for dest in self._dests(khash, exclude=src):
                by_dest.setdefault(dest, []).append((key, flags, payload))
        written = 0
        offered_bytes = 0
        for dest, records in by_dest.items():
            try:
                n = st.nodes[dest].import_encoded(records, skip_existing=True)
            except NodeUnavailable:
                st.mark_down(dest)
                continue
            written += n
            offered_bytes += sum(len(p) for _, _, p in records)
        with st._lock:
            self.stats.blocks_pulled += pulled
            self.stats.blocks_copied += written
            self.stats.bytes_moved += offered_bytes
            if task.kind == "repair":
                self.stats.repair_blocks += written
        return written

    def _finish(self, task: _Task) -> None:
        now = time.monotonic()
        st = self.store
        self._task = None
        if task.kind == "rebalance":
            st._complete_transition()
            with st._lock:
                self.stats.migrations_completed += 1
                self.stats.rebalance_s = now - task.t0
        else:
            st._note_repaired(task.target_down)
            with st._lock:
                self.stats.repairs_completed += 1
                self.stats.repair_s = now - task.t0
                if task.down_t0 is not None:
                    self.stats.repair_lag_s = now - task.down_t0
