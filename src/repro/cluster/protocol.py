"""Wire protocol of the cache cluster: length-prefixed, multiplexed
binary frames.

Many RPCs share one stream socket (TCP or ``AF_UNIX``) concurrently —
every frame carries a client-chosen request id, so responses may return
in any order and a streaming response interleaves with other traffic:

    frame    :=  u32 payload_len (big-endian) | payload
    payload  :=  u32 request_id | u8 kind | [u64 trace_id] | body
    kind     :=  0 REQUEST | 1 RESPONSE | 2 CHUNK | 3 END
                 (high bit FLAG_TRACE: an 8-byte trace id precedes body)

    REQUEST  body :=  u8 opcode | args
    RESPONSE body :=  u8 status | result     status 0 = ok, 1 = error
    CHUNK    body :=  u32 seq_index | u32 start_block | block list
    END      body :=  u8 status | u32 n | u32 served_counts[n]

Unary ops complete with a single RESPONSE.  The streaming gets
(``OP_GET_STREAM`` / ``OP_GET_MANY_STREAM``) emit zero or more CHUNK
frames followed by exactly one END summarizing blocks served per
sequence — the client starts consuming block 0 while later blocks are
still on the wire.

Bodies are flat ``struct``-packed binary — token sequences ride as the
same big-endian ``u32`` words the key codec uses on disk, tensor blocks
as ``dtype | shape | raw C-order bytes``, and the observability ops
(``STATS`` / ``MAINTENANCE``) as JSON, since their payloads are small
dicts.

Block lists are *packed* when homogeneous (the overwhelmingly common
case: every KV block of a sequence has the same dtype and shape): one
header plus a single contiguous raw region, so the receiver decodes a
whole batch with one ``frombuffer`` — a bulk, GIL-releasing operation —
instead of per-block Python work.  Decoded blocks are zero-copy views
into the receive buffer; per-response that buffer stays alive exactly as
long as its blocks do.  Heterogeneous lists fall back to a per-block
layout (layout byte 0).  This matters for scalability: the client is one
GIL domain fanning out to N nodes, and per-block decode bursts would
starve the very socket reads that keep those nodes busy.

A third layout (byte 2) carries *raw tensor-log records* — the exact
``u32 crc | u32 klen | u32 plen | key | payload`` bytes sitting on the
node's disk.  When the blocks of a chunk are one contiguous log extent,
the server ``os.sendfile``s them straight from the log file into the
socket — no read into Python, no re-encode — and the client CRC-checks
and ``BatchCodec.decode``s each record (the payload is self-describing),
paying the decode CPU it would have paid anyway while the node stays out
of the copy path entirely.

A fourth layout (byte 3) carries *encoded codec payloads* — length-
prefixed ``core.codec`` blobs exactly as stored, without the log-record
framing.  This is the buffered complement of the sendfile path: when a
backend exposes ``get_batch_encoded`` (the LSM stores do), the server
ships the still-compressed bytes and the client decodes, so an
int8+zlib cold tier moves ~3-4x fewer network bytes than decoded
blocks would, on every read path.  Block lists whose items are
bytes-like rather than ndarrays encode this way automatically.

Robustness contract (property-tested in ``tests/test_cluster.py``):

* ``encode``/``decode`` round-trip every op exactly;
* a frame longer than ``max_frame_bytes`` is rejected *before* the body
  is allocated (``FrameTooLarge``) — a malicious or corrupt length word
  cannot OOM a node;
* a connection that dies mid-frame raises ``TruncatedFrame`` — callers
  see a clean, retryable error, never a hang or a partial decode (socket
  timeouts bound the wait; ``recv_frame`` never spins on a dead peer);
* an orderly peer close *between* frames returns ``None`` (EOF), which
  is the normal end of a connection, not an error.

Every decoder bounds-checks against the actual payload length, so a
truncated or corrupted body surfaces as ``ProtocolError`` rather than an
out-of-range read.
"""

from __future__ import annotations

import json
import socket
import struct
import zlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.codec import BatchCodec, CodecError

# Default cap on one frame.  A frame carries at most one batch of KV
# blocks; 256 MiB is ~64k blocks of 4 KiB — far beyond any batch the
# serving layer issues, and small enough that a corrupt length word is
# caught immediately.
MAX_FRAME_BYTES = 256 * 1024 * 1024

_U32 = struct.Struct(">I")
_U64 = struct.Struct(">Q")

# ------------------------------------------------------------------ opcodes
OP_PING = 1
OP_PROBE = 2
OP_PROBE_MANY = 3
OP_GET = 4
OP_GET_MANY = 5
OP_PUT = 6
OP_PUT_MANY = 7
OP_STATS = 8
OP_MAINTENANCE = 9
OP_FLUSH = 10
OP_GET_STREAM = 11
OP_GET_MANY_STREAM = 12
OP_METRICS = 13
# elasticity trio (cluster.migration): enumerate a node's keyspace in
# pages, pull stored records, push them to a new owner — blocks travel in
# their stored encoding (the unary cousin of LAYOUT_ENCODED), so cold
# tiers migrate compressed
OP_SCAN = 14
OP_PULL = 15
OP_PUSH = 16

OP_NAMES = {
    OP_PING: "ping",
    OP_PROBE: "probe",
    OP_PROBE_MANY: "probe_many",
    OP_GET: "get_batch",
    OP_GET_MANY: "get_many",
    OP_PUT: "put_batch",
    OP_PUT_MANY: "put_many",
    OP_STATS: "stats",
    OP_MAINTENANCE: "maintenance",
    OP_FLUSH: "flush",
    OP_GET_STREAM: "get_stream",
    OP_GET_MANY_STREAM: "get_many_stream",
    OP_METRICS: "metrics",
    OP_SCAN: "scan",
    OP_PULL: "pull",
    OP_PUSH: "push",
}

STREAM_OPS = (OP_GET_STREAM, OP_GET_MANY_STREAM)

STATUS_OK = 0
STATUS_ERROR = 1

# ------------------------------------------------------------- mux frames
KIND_REQUEST = 0
KIND_RESPONSE = 1
KIND_CHUNK = 2
KIND_END = 3

_MUX = struct.Struct(">IB")
MUX_HDR_BYTES = _MUX.size  # 5: u32 request_id | u8 kind

# Optional trace field: when the high bit of the kind byte is set, an
# 8-byte trace id follows the mux header before the body.  Old peers
# never set the flag, so the base frame layout is unchanged; REQUEST
# frames carry it client->server, the server never echoes it back.
FLAG_TRACE = 0x80
TRACE_ID_BYTES = 8


class ProtocolError(Exception):
    """Malformed frame or body — the connection is no longer trustworthy."""


class FrameTooLarge(ProtocolError):
    """Frame length exceeds the negotiated cap (rejected before allocation)."""


class TruncatedFrame(ProtocolError):
    """Peer died mid-frame (distinct from a clean between-frames EOF)."""


class RemoteError(Exception):
    """The node executed the request and reported a failure."""


# ----------------------------------------------------------------- framing
def pack_mux(request_id: int, kind: int, trace: Optional[bytes] = None) -> bytes:
    """Mux header; ``trace`` (exactly ``TRACE_ID_BYTES``) appends the
    optional trace-id field and sets ``FLAG_TRACE`` on the kind byte."""
    if trace is None:
        return _MUX.pack(request_id & 0xFFFFFFFF, kind)
    if len(trace) != TRACE_ID_BYTES:
        raise ProtocolError(f"trace id must be {TRACE_ID_BYTES} bytes, got {len(trace)}")
    return _MUX.pack(request_id & 0xFFFFFFFF, kind | FLAG_TRACE) + bytes(trace)


def split_mux_ex(payload) -> Tuple[int, int, Optional[bytes], memoryview]:
    """``(request_id, kind, trace_id_or_None, body)`` — body is a
    zero-copy view past the header and optional trace field."""
    if len(payload) < MUX_HDR_BYTES:
        raise ProtocolError(f"mux frame of {len(payload)} bytes has no header")
    rid, kind_raw = _MUX.unpack_from(payload)
    kind = kind_raw & ~FLAG_TRACE & 0xFF
    if kind > KIND_END:
        raise ProtocolError(f"unknown frame kind {kind}")
    off = MUX_HDR_BYTES
    trace = None
    if kind_raw & FLAG_TRACE:
        if len(payload) < off + TRACE_ID_BYTES:
            raise ProtocolError("frame flags a trace id but is too short to hold one")
        trace = bytes(memoryview(payload)[off : off + TRACE_ID_BYTES])
        off += TRACE_ID_BYTES
    return rid, kind, trace, memoryview(payload)[off:]


def split_mux(payload) -> Tuple[int, int, memoryview]:
    """``(request_id, kind, body)`` — body is a zero-copy view.  Any
    trace field is parsed and dropped; use :func:`split_mux_ex` to see it."""
    rid, kind, _trace, body = split_mux_ex(payload)
    return rid, kind, body


def send_frame(sock: socket.socket, payload: bytes) -> None:
    if len(payload) >= 1 << 16:
        # two sends spare a multi-MiB concat copy; small frames stay one
        sock.sendall(_U32.pack(len(payload)))
        sock.sendall(payload)
    else:
        sock.sendall(_U32.pack(len(payload)) + payload)


def send_frame_parts(sock: socket.socket, parts: Sequence) -> int:
    """Scatter-gather send of one frame built from ``parts`` (bytes or
    memoryview): the u32 length prefix is prepended and the whole vector
    handed to ``sendmsg``, so a multi-part frame (mux header + chunk
    header + tensor payload) goes out in one syscall with no concat
    copy.  Loops on partial sends.  Returns total bytes sent."""
    views = [memoryview(p).cast("B") for p in parts]
    total = sum(len(v) for v in views)
    views.insert(0, memoryview(_U32.pack(total)))
    sent_total = total + 4
    if not hasattr(sock, "sendmsg"):  # pragma: no cover — all POSIX targets have it
        sock.sendall(b"".join(views))
        return sent_total
    while views:
        sent = sock.sendmsg(views)
        while views and sent >= len(views[0]):
            sent -= len(views[0])
            views.pop(0)
        if sent and views:
            views[0] = views[0][sent:]
    return sent_total


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytearray]:
    """Read exactly ``n`` bytes into one preallocated buffer (no
    reassembly copy); ``None`` on immediate EOF, raises
    ``TruncatedFrame`` on EOF after a partial read."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], min(n - got, 1 << 20))
        if r == 0:
            if got == 0:
                return None
            raise TruncatedFrame(f"peer closed after {got}/{n} bytes")
        got += r
    return buf


def recv_frame(
    sock: socket.socket, max_frame_bytes: int = MAX_FRAME_BYTES
) -> Optional[bytes]:
    """Read one frame; ``None`` on clean EOF between frames."""
    header = _recv_exact(sock, 4)
    if header is None:
        return None
    (length,) = _U32.unpack(header)
    if length > max_frame_bytes:
        raise FrameTooLarge(f"frame of {length} bytes exceeds cap {max_frame_bytes}")
    if length == 0:
        return b""
    body = _recv_exact(sock, length)
    if body is None:
        raise TruncatedFrame("peer closed between frame header and body")
    return body


# ------------------------------------------------------------- primitives
class _Reader:
    """Bounds-checked cursor over a payload.  ``take`` returns zero-copy
    ``memoryview`` slices, so decoding a tensor batch never duplicates
    the receive buffer."""

    __slots__ = ("buf", "pos")

    def __init__(self, buf):
        self.buf = memoryview(buf)
        self.pos = 0

    def take(self, n: int) -> memoryview:
        if self.pos + n > len(self.buf):
            raise ProtocolError(
                f"body truncated: wanted {n} bytes at offset {self.pos}, "
                f"payload is {len(self.buf)}"
            )
        out = self.buf[self.pos : self.pos + n]
        self.pos += n
        return out

    def u8(self) -> int:
        return self.take(1)[0]

    def u32(self) -> int:
        return _U32.unpack(self.take(4))[0]

    def u64(self) -> int:
        return _U64.unpack(self.take(8))[0]

    def done(self) -> None:
        if self.pos != len(self.buf):
            raise ProtocolError(f"{len(self.buf) - self.pos} trailing bytes after body")


def _enc_tokens(tokens: Sequence[int]) -> bytes:
    arr = np.asarray(tokens, dtype=">u4")
    if arr.ndim != 1:
        raise ProtocolError("token sequence must be one-dimensional")
    return _U32.pack(arr.size) + arr.tobytes()


def _dec_tokens(r: _Reader) -> List[int]:
    n = r.u32()
    return np.frombuffer(r.take(4 * n), dtype=">u4").astype(np.int64).tolist()


def _dtype_head(arr: np.ndarray) -> bytes:
    dt = arr.dtype.str.encode("ascii")  # e.g. b'<f2', endian-explicit
    head = struct.pack(">BB", len(dt), arr.ndim) + dt
    return head + b"".join(_U32.pack(d) for d in arr.shape)


def _dec_dtype_head(r: _Reader) -> Tuple[np.dtype, tuple]:
    dt_len, ndim = struct.unpack(">BB", r.take(2))
    try:
        dtype = np.dtype(bytes(r.take(dt_len)).decode("ascii"))
    except (TypeError, UnicodeDecodeError) as e:
        raise ProtocolError(f"bad block dtype: {e}") from e
    return dtype, tuple(r.u32() for _ in range(ndim))


def _block_nbytes(dtype: np.dtype, shape: tuple) -> int:
    return dtype.itemsize * int(np.prod(shape, dtype=np.int64)) if shape else dtype.itemsize


def _enc_block(block: np.ndarray) -> bytes:
    arr = np.ascontiguousarray(block)
    return _dtype_head(arr) + _U64.pack(arr.nbytes) + arr.tobytes()


def _dec_block(r: _Reader) -> np.ndarray:
    dtype, shape = _dec_dtype_head(r)
    nbytes = r.u64()
    expect = _block_nbytes(dtype, shape)
    if nbytes != expect:
        raise ProtocolError(f"block byte count {nbytes} != dtype/shape product {expect}")
    raw = r.take(nbytes)
    return np.frombuffer(raw, dtype=dtype).reshape(shape)


def _enc_blocks(blocks: Sequence) -> List:
    """Encode a block list as parts for one final join.  Bytes-like items
    (still-encoded codec payloads from ``get_batch_encoded``) ride as
    layout 3 — the compressed bytes go over the wire verbatim.
    Homogeneous ndarray lists (layout 1, the common case for decoded
    blocks) pack every block into a single contiguous raw region; mixed
    lists (layout 0) ride per-block."""
    if blocks and all(isinstance(b, (bytes, bytearray, memoryview)) for b in blocks):
        parts: List = [_U32.pack(len(blocks)), b"\x03"]
        for p in blocks:
            parts.append(_U32.pack(len(p)))
            parts.append(p)
        return parts
    arrs = [np.ascontiguousarray(b) for b in blocks]
    if arrs and all(
        a.dtype == arrs[0].dtype and a.shape == arrs[0].shape for a in arrs[1:]
    ):
        packed = np.stack(arrs) if len(arrs) > 1 else arrs[0][None]
        return [
            _U32.pack(len(arrs)), b"\x01", _dtype_head(arrs[0]),
            _U64.pack(packed.nbytes), packed.data,
        ]
    return [_U32.pack(len(arrs)), b"\x00"] + [_enc_block(a) for a in arrs]


def _dec_encoded_blocks(r: _Reader, n: int) -> List[np.ndarray]:
    """Layout 3: length-prefixed self-describing codec payloads."""
    blocks: List[np.ndarray] = []
    for i in range(n):
        payload = r.take(r.u32())
        try:
            blocks.append(BatchCodec.decode(payload))
        except CodecError as e:
            raise ProtocolError(f"bad encoded block payload at block {i}: {e}") from e
    return blocks


def _dec_blocks(r: _Reader) -> List[np.ndarray]:
    n = r.u32()
    layout = r.u8()
    if layout == 0:
        return [_dec_block(r) for _ in range(n)]
    if layout == LAYOUT_ENCODED:
        return _dec_encoded_blocks(r, n)
    if layout != 1:
        raise ProtocolError(f"unknown block layout {layout}")
    dtype, shape = _dec_dtype_head(r)
    nbytes = r.u64()
    if nbytes != n * _block_nbytes(dtype, shape):
        raise ProtocolError(
            f"packed byte count {nbytes} != {n} x dtype/shape product"
        )
    raw = r.take(nbytes)
    arr = np.frombuffer(raw, dtype=dtype).reshape((n,) + shape)
    return list(arr)  # n zero-copy views over the receive buffer


# ------------------------------------------------------------- requests
def encode_request(op: int, *args) -> bytes:
    """Serialize one request.  Argument shapes per op:

    PING ()                           PROBE (tokens,)
    PROBE_MANY (seqs,)                GET (tokens, n_tokens)
    GET_MANY (items,)                 items = [(tokens, n_tokens), ...]
    PUT (tokens, blocks, start_block, skip_existing)
    PUT_MANY (items,)                 items = [(tokens, blocks, start), ...]
    STATS () / METRICS () / MAINTENANCE (compact_steps,) / FLUSH ()
    GET_STREAM (tokens, n_tokens, chunk_blocks)
    GET_MANY_STREAM (items, chunk_blocks)
    SCAN (cursor, limit, ranges)      cursor = bytes|None (opaque),
                                      ranges = [(lo, hi), ...] half-open
                                      wrapping ring arcs (u64) filtering
                                      by key hash; empty = whole keyspace
    PULL (keys,)                      keys = [bytes, ...]
    PUSH (records, skip_existing)     records = [(key, flags, payload), ...]
    """
    parts: List = [struct.pack(">B", op)]
    if op in (OP_PING, OP_STATS, OP_METRICS, OP_FLUSH):
        pass
    elif op == OP_PROBE:
        parts.append(_enc_tokens(args[0]))
    elif op == OP_PROBE_MANY:
        parts.append(_U32.pack(len(args[0])))
        parts.extend(_enc_tokens(t) for t in args[0])
    elif op == OP_GET:
        parts.append(_enc_tokens(args[0]) + _U64.pack(args[1]))
    elif op == OP_GET_MANY:
        parts.append(_U32.pack(len(args[0])))
        parts.extend(_enc_tokens(t) + _U64.pack(n) for t, n in args[0])
    elif op == OP_PUT:
        tokens, blocks, start_block, skip_existing = args
        parts.append(
            _enc_tokens(tokens)
            + _U32.pack(start_block)
            + struct.pack(">B", 1 if skip_existing else 0)
        )
        parts.extend(_enc_blocks(blocks))
    elif op == OP_PUT_MANY:
        parts.append(_U32.pack(len(args[0])))
        for t, bs, s in args[0]:
            parts.append(_enc_tokens(t) + _U32.pack(s))
            parts.extend(_enc_blocks(bs))
    elif op == OP_MAINTENANCE:
        parts.append(_U32.pack(args[0]))
    elif op == OP_GET_STREAM:
        parts.append(_enc_tokens(args[0]) + _U64.pack(args[1]) + _U32.pack(args[2]))
    elif op == OP_GET_MANY_STREAM:
        parts.append(_U32.pack(len(args[0])))
        parts.extend(_enc_tokens(t) + _U64.pack(n) for t, n in args[0])
        parts.append(_U32.pack(args[1]))
    elif op == OP_SCAN:
        cursor, limit, ranges = args
        if cursor is None:
            parts.append(b"\x00")
        else:
            parts.append(b"\x01" + _U32.pack(len(cursor)))
            parts.append(bytes(cursor))
        parts.append(_U32.pack(limit) + _U32.pack(len(ranges)))
        parts.extend(_U64.pack(lo) + _U64.pack(hi) for lo, hi in ranges)
    elif op == OP_PULL:
        parts.append(_U32.pack(len(args[0])))
        for k in args[0]:
            parts.append(_U32.pack(len(k)))
            parts.append(bytes(k))
    elif op == OP_PUSH:
        records, skip_existing = args
        parts.append(struct.pack(">B", 1 if skip_existing else 0))
        parts.append(_U32.pack(len(records)))
        for key, flags, payload in records:
            parts.append(_U32.pack(len(key)))
            parts.append(bytes(key))
            parts.append(struct.pack(">B", flags & 0xFF) + _U32.pack(len(payload)))
            parts.append(payload)
    else:
        raise ProtocolError(f"unknown opcode {op}")
    return b"".join(parts)


def decode_request(payload: bytes) -> Tuple[int, tuple]:
    """Inverse of :func:`encode_request`: ``(op, args)``."""
    if not payload:
        raise ProtocolError("empty request payload")
    r = _Reader(payload)
    op = r.u8()
    if op in (OP_PING, OP_STATS, OP_METRICS, OP_FLUSH):
        args: tuple = ()
    elif op == OP_PROBE:
        args = (_dec_tokens(r),)
    elif op == OP_PROBE_MANY:
        args = ([_dec_tokens(r) for _ in range(r.u32())],)
    elif op == OP_GET:
        args = (_dec_tokens(r), r.u64())
    elif op == OP_GET_MANY:
        args = ([(_dec_tokens(r), r.u64()) for _ in range(r.u32())],)
    elif op == OP_PUT:
        tokens = _dec_tokens(r)
        start_block = r.u32()
        skip_existing = bool(r.u8())
        args = (tokens, _dec_blocks(r), start_block, skip_existing)
    elif op == OP_PUT_MANY:
        n = r.u32()
        items = []
        for _ in range(n):
            tokens = _dec_tokens(r)
            start = r.u32()
            items.append((tokens, _dec_blocks(r), start))
        args = (items,)
    elif op == OP_MAINTENANCE:
        args = (r.u32(),)
    elif op == OP_GET_STREAM:
        args = (_dec_tokens(r), r.u64(), r.u32())
    elif op == OP_GET_MANY_STREAM:
        items = [(_dec_tokens(r), r.u64()) for _ in range(r.u32())]
        args = (items, r.u32())
    elif op == OP_SCAN:
        cursor = bytes(r.take(r.u32())) if r.u8() else None
        limit = r.u32()
        ranges = [(r.u64(), r.u64()) for _ in range(r.u32())]
        args = (cursor, limit, ranges)
    elif op == OP_PULL:
        args = ([bytes(r.take(r.u32())) for _ in range(r.u32())],)
    elif op == OP_PUSH:
        skip_existing = bool(r.u8())
        records = []
        for _ in range(r.u32()):
            key = bytes(r.take(r.u32()))
            flags = r.u8()
            records.append((key, flags, bytes(r.take(r.u32()))))
        args = (records, skip_existing)
    else:
        raise ProtocolError(f"unknown opcode {op}")
    r.done()
    return op, args


# ------------------------------------------------------------- responses
def encode_ok(op: int, result) -> bytes:
    """Serialize a success response for ``op``."""
    parts: List = [struct.pack(">B", STATUS_OK)]
    if op in (OP_PING, OP_FLUSH):
        pass
    elif op in (OP_PROBE, OP_PUT):
        parts.append(_U64.pack(int(result)))
    elif op in (OP_PROBE_MANY, OP_PUT_MANY):
        parts.append(_U32.pack(len(result)))
        parts.extend(_U64.pack(int(v)) for v in result)
    elif op == OP_GET:
        parts.extend(_enc_blocks(result))
    elif op == OP_GET_MANY:
        parts.append(_U32.pack(len(result)))
        for bs in result:
            parts.extend(_enc_blocks(bs))
    elif op in (OP_STATS, OP_METRICS, OP_MAINTENANCE):
        parts.append(json.dumps(result).encode("utf-8"))
    elif op == OP_SCAN:
        keys, next_cursor = result
        if next_cursor is None:
            parts.append(b"\x00")
        else:
            parts.append(b"\x01" + _U32.pack(len(next_cursor)))
            parts.append(bytes(next_cursor))
        parts.append(_U32.pack(len(keys)))
        for k in keys:
            parts.append(_U32.pack(len(k)))
            parts.append(bytes(k))
    elif op == OP_PULL:
        parts.append(_U32.pack(len(result)))
        for rec in result:
            if rec is None:
                parts.append(b"\x00")
            else:
                flags, payload = rec
                parts.append(b"\x01" + struct.pack(">B", flags & 0xFF) + _U32.pack(len(payload)))
                parts.append(payload)
    elif op == OP_PUSH:
        parts.append(_U64.pack(int(result)))
    else:
        raise ProtocolError(f"unknown opcode {op}")
    return b"".join(parts)


def encode_error(message: str) -> bytes:
    return struct.pack(">B", STATUS_ERROR) + message.encode("utf-8", "replace")


def decode_response(op: int, payload: bytes):
    """Decode a response to a request of type ``op``; raises
    ``RemoteError`` if the node reported a failure."""
    if not payload:
        raise ProtocolError("empty response payload")
    r = _Reader(payload)
    status = r.u8()
    if status == STATUS_ERROR:
        raise RemoteError(bytes(r.buf[r.pos :]).decode("utf-8", "replace"))
    if status != STATUS_OK:
        raise ProtocolError(f"unknown response status {status}")
    if op in (OP_PING, OP_FLUSH):
        result = None
    elif op in (OP_PROBE, OP_PUT):
        result = r.u64()
    elif op in (OP_PROBE_MANY, OP_PUT_MANY):
        result = [r.u64() for _ in range(r.u32())]
    elif op == OP_GET:
        result = _dec_blocks(r)
    elif op == OP_GET_MANY:
        result = [_dec_blocks(r) for _ in range(r.u32())]
    elif op in (OP_STATS, OP_METRICS, OP_MAINTENANCE):
        try:
            return json.loads(bytes(r.buf[r.pos :]).decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise ProtocolError(f"bad JSON response body: {e}") from e
    elif op == OP_SCAN:
        next_cursor = bytes(r.take(r.u32())) if r.u8() else None
        keys = [bytes(r.take(r.u32())) for _ in range(r.u32())]
        result = (keys, next_cursor)
    elif op == OP_PULL:
        recs: List[Optional[Tuple[int, bytes]]] = []
        for _ in range(r.u32()):
            if r.u8():
                flags = r.u8()
                recs.append((flags, bytes(r.take(r.u32()))))
            else:
                recs.append(None)
        result = recs
    elif op == OP_PUSH:
        result = r.u64()
    else:
        raise ProtocolError(f"unknown opcode {op}")
    r.done()
    return result


# ------------------------------------------------------------ stream chunks
# chunk body := u32 seq_index | u32 start_block | u32 n | u8 layout | ...
# layouts 0/1 are the block-list layouts above; layout 2 is raw tensor-log
# records (server sendfile path, client-side CRC + BatchCodec decode);
# layout 3 is length-prefixed encoded codec payloads (buffered compressed
# path, client-side BatchCodec decode).
LAYOUT_VLOG = 2
LAYOUT_ENCODED = 3
_VLOG_HDR = struct.Struct("<III")  # crc | klen | plen — the on-disk record header


def encode_stream_chunk(seq_index: int, start_block: int, blocks: Sequence[np.ndarray]) -> List:
    """Encode one decoded-blocks chunk as parts for ``send_frame_parts``."""
    return [_U32.pack(seq_index), _U32.pack(start_block)] + _enc_blocks(blocks)


def encode_vlog_chunk_header(seq_index: int, start_block: int, n_records: int, nbytes: int) -> bytes:
    """Header of a layout-2 chunk; the ``nbytes`` of raw log records that
    follow are shipped by ``os.sendfile`` straight from the log file."""
    return (
        _U32.pack(seq_index) + _U32.pack(start_block)
        + _U32.pack(n_records) + b"\x02" + _U64.pack(nbytes)
    )


def _dec_vlog_records(r: _Reader, n: int) -> List[np.ndarray]:
    nbytes = r.u64()
    raw = r.take(nbytes)
    blocks: List[np.ndarray] = []
    pos = 0
    for _ in range(n):
        if pos + _VLOG_HDR.size > nbytes:
            raise ProtocolError(f"vlog chunk truncated at record {len(blocks)}")
        crc, klen, plen = _VLOG_HDR.unpack_from(raw, pos)
        body = raw[pos + _VLOG_HDR.size : pos + _VLOG_HDR.size + klen + plen]
        if len(body) != klen + plen:
            raise ProtocolError(f"vlog chunk truncated at record {len(blocks)}")
        if zlib.crc32(body) & 0xFFFFFFFF != crc:
            raise ProtocolError(f"vlog record CRC mismatch at record {len(blocks)}")
        try:
            blocks.append(BatchCodec.decode(body[klen:]))
        except (struct.error, KeyError, ValueError, zlib.error) as e:
            raise ProtocolError(f"bad vlog record payload: {e}") from e
        pos += _VLOG_HDR.size + klen + plen
    if pos != nbytes:
        raise ProtocolError(f"{nbytes - pos} trailing bytes after vlog records")
    return blocks


def decode_stream_chunk(body) -> Tuple[int, int, List[np.ndarray]]:
    """``(seq_index, start_block, blocks)`` from one CHUNK body."""
    r = _Reader(body)
    seq_index = r.u32()
    start_block = r.u32()
    n = r.u32()
    layout = r.u8()
    if layout == LAYOUT_VLOG:
        blocks = _dec_vlog_records(r, n)
    elif layout == LAYOUT_ENCODED:
        blocks = _dec_encoded_blocks(r, n)
    elif layout == 0:
        blocks = [_dec_block(r) for _ in range(n)]
    elif layout == 1:
        dtype, shape = _dec_dtype_head(r)
        nbytes = r.u64()
        if nbytes != n * _block_nbytes(dtype, shape):
            raise ProtocolError(f"packed byte count {nbytes} != {n} x dtype/shape product")
        raw = r.take(nbytes)
        blocks = list(np.frombuffer(raw, dtype=dtype).reshape((n,) + shape))
    else:
        raise ProtocolError(f"unknown block layout {layout}")
    r.done()
    return seq_index, start_block, blocks


def encode_stream_end(counts: Sequence[int]) -> bytes:
    """END frame body: per-sequence blocks-served totals (the client
    verifies its assembled streams against these)."""
    return (
        struct.pack(">B", STATUS_OK)
        + _U32.pack(len(counts))
        + b"".join(_U32.pack(int(c)) for c in counts)
    )


def decode_stream_end(body) -> List[int]:
    """Served-count list from an END body; raises ``RemoteError`` if the
    node aborted the stream with an application failure."""
    r = _Reader(body)
    status = r.u8()
    if status == STATUS_ERROR:
        raise RemoteError(bytes(r.buf[r.pos :]).decode("utf-8", "replace"))
    if status != STATUS_OK:
        raise ProtocolError(f"unknown stream end status {status}")
    counts = [r.u32() for _ in range(r.u32())]
    r.done()
    return counts
