from .staged import PAPER_STAGES, Request, StagedWorkload  # noqa: F401
