from .staged import (  # noqa: F401
    PAPER_STAGES,
    MultiTenantWorkload,
    Request,
    StagedWorkload,
)
