"""Request generators for every serving benchmark: the paper's staged
hit-rate workload (§4.1) plus the multi-tenant variant.

``StagedWorkload`` progresses through stages with expected hit rates
[0.2 0.3 0.5 0.7 0.5 0.3 0.1 0.3 0.5 0.7]; each stage issues
``requests_per_stage`` requests of ``prompt_len`` tokens.  The expected hit
rate is the ratio of shared prompt tokens to total prompt tokens: a request
reuses the first ``hit_rate * prompt_len`` tokens of a previously issued
prompt (drawn from a warm corpus) and fills the tail with fresh tokens.

A warmup phase (paper: 100M tokens of KV cache, write-through) populates
both the memory tiers and the disk backend before measurement; the corpus
of warmup prefixes is what later stages share against.

``MultiTenantWorkload`` runs M independent staged corpora, each prompt
tagged with a tenant-id block so tenants never share prefixes — the
workload that exercises shard/node placement (distinct corpora spread
across shards of ``ShardedKVBlockStore`` or nodes of a cache cluster,
while each tenant's extensions stay local to its shard/node).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

PAPER_STAGES = (0.2, 0.3, 0.5, 0.7, 0.5, 0.3, 0.1, 0.3, 0.5, 0.7)


@dataclass
class Request:
    rid: int
    stage: int
    tokens: List[int]
    expected_hit: float


@dataclass
class StagedWorkload:
    prompt_len: int = 4096
    requests_per_stage: int = 1000
    stages: Sequence[float] = PAPER_STAGES
    vocab: int = 50_000
    block_size: int = 16
    corpus_size: int = 512  # distinct shared-prefix roots
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        # corpus roots: long random token runs requests share prefixes of
        self.corpus = [
            self.rng.integers(0, self.vocab, size=self.prompt_len).tolist()
            for _ in range(self.corpus_size)
        ]
        self._rid = 0

    # ------------------------------------------------------------- warmup
    def warmup_prompts(self, total_tokens: int) -> Iterator[List[int]]:
        """Prompts covering the corpus until ~total_tokens have been issued
        (the paper's 100M-token write-through warmup, scaled by callers)."""
        issued = 0
        i = 0
        while issued < total_tokens:
            p = self.corpus[i % len(self.corpus)]
            yield list(p)
            issued += len(p)
            i += 1

    # ------------------------------------------------------------ requests
    def _make_request(self, stage_idx: int, hit: float) -> Request:
        shared = int(round(hit * self.prompt_len))
        # share a block-aligned prefix so cache-block granularity can hit it
        shared = (shared // self.block_size) * self.block_size
        root = self.corpus[int(self.rng.integers(0, len(self.corpus)))]
        fresh = self.rng.integers(0, self.vocab, size=self.prompt_len - shared)
        toks = list(root[:shared]) + fresh.tolist()
        self._rid += 1
        return Request(self._rid, stage_idx, toks, hit)

    def requests(self) -> Iterator[Request]:
        for si, hit in enumerate(self.stages):
            for _ in range(self.requests_per_stage):
                yield self._make_request(si, hit)

    def stage_requests(self, stage_idx: int) -> List[Request]:
        return [self._make_request(stage_idx, self.stages[stage_idx]) for _ in range(self.requests_per_stage)]


@dataclass
class MultiTenantWorkload:
    """M independent tenants, each with its own prefix corpus, interleaved
    round-robin — the traffic shape storage sharding exists for: M disjoint
    prefix keyspaces that a monolithic store serializes behind one memtable
    and WAL, but a ``ShardedKVBlockStore`` spreads across shards.

    Every prompt of tenant ``t`` starts with a tenant-tag block
    (``block_size`` copies of a token unique to ``t``, drawn from above the
    vocab range), so tenants never share a first block: hash routing keeps
    each tenant's whole prefix tree shard-local while distributing tenants
    across shards.  ``prompt_len`` includes the tag block."""

    n_tenants: int = 4
    prompt_len: int = 4096
    requests_per_stage: int = 1000  # total per stage, round-robin over tenants
    stages: Sequence[float] = PAPER_STAGES
    vocab: int = 50_000
    block_size: int = 16
    corpus_size: int = 128  # distinct shared-prefix roots per tenant
    seed: int = 0

    def __post_init__(self):
        body = self.prompt_len - self.block_size
        if body <= 0:
            raise ValueError("prompt_len must exceed block_size (tag block)")
        self.tenants = [
            StagedWorkload(
                prompt_len=body,
                requests_per_stage=self.requests_per_stage,
                stages=self.stages,
                vocab=self.vocab,
                block_size=self.block_size,
                corpus_size=self.corpus_size,
                seed=self.seed + 7919 * (t + 1),
            )
            for t in range(self.n_tenants)
        ]
        self._rid = 0

    def tag_block(self, tenant: int) -> List[int]:
        return [self.vocab + tenant] * self.block_size

    def _wrap(self, tenant: int, req: Request) -> Request:
        self._rid += 1
        toks = self.tag_block(tenant) + req.tokens
        # the tag block always hits after warmup; fold it into the expectation
        hit = (self.block_size + req.expected_hit * (self.prompt_len - self.block_size)) / self.prompt_len
        return Request(self._rid, req.stage, toks, hit)

    # ------------------------------------------------------------- warmup
    def warmup_prompts(self, total_tokens: int) -> Iterator[List[int]]:
        """Tagged prompts covering every tenant's corpus round-robin until
        ~``total_tokens`` have been issued."""
        issued = 0
        i = 0
        while issued < total_tokens:
            t = i % self.n_tenants
            corpus = self.tenants[t].corpus
            p = self.tag_block(t) + list(corpus[(i // self.n_tenants) % len(corpus)])
            yield p
            issued += len(p)
            i += 1

    # ------------------------------------------------------------ requests
    def stage_requests(self, stage_idx: int) -> List[Request]:
        hit = self.stages[stage_idx]
        return [
            self._wrap(i % self.n_tenants, self.tenants[i % self.n_tenants]._make_request(stage_idx, hit))
            for i in range(self.requests_per_stage)
        ]

    def requests(self) -> Iterator[Request]:
        for si in range(len(self.stages)):
            yield from self.stage_requests(si)
