"""Paged KV pool: the device-side staging area between the LSM store and
the paged decode-attention kernel (DESIGN.md §3, "decode hot path").

Disk-resident KV blocks promoted by the cache hierarchy land in a paged
HBM pool; sequences reference pages through block tables consumed directly
by ``repro.kernels.decode_attention`` (scalar-prefetch indirection).  The
pool is a classic free-list allocator with per-sequence tables:

    alloc(seq_id, n_pages) / extend(seq_id) / free(seq_id)
    stage(seq_id, page_idx, k_block, v_block)      host -> pool page
    block_tables(batch_of_seq_ids) -> (B, NB) int32 (padded)

Pages are (page_size, KVH, Dh) per layer; the pool stores all layers of a
page contiguously (L, page, KVH, Dh) so one promotion stages one object
from the store.  Eviction is the hierarchy's concern — the pool refuses
allocation when full (caller demotes and retries), keeping the allocator
deterministic and thread-free like the rest of the runtime.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np


class PoolFullError(RuntimeError):
    pass


@dataclass
class PagedKVPool:
    n_pages: int
    page_size: int  # tokens per page
    n_layers: int
    n_kv_heads: int
    d_head: int
    dtype: np.dtype = np.dtype("float16")

    def __post_init__(self):
        shape = (self.n_pages, self.n_layers, self.page_size, self.n_kv_heads, self.d_head)
        self.k_pages = np.zeros(shape, self.dtype)
        self.v_pages = np.zeros(shape, self.dtype)
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}
        self._lens: Dict[int, int] = {}

    # ------------------------------------------------------------ allocator
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, seq_id: int, n_pages: int) -> List[int]:
        if n_pages > len(self._free):
            raise PoolFullError(f"need {n_pages}, free {len(self._free)}")
        if seq_id in self._tables:
            raise ValueError(f"seq {seq_id} already allocated")
        pages = [self._free.pop() for _ in range(n_pages)]
        self._tables[seq_id] = pages
        self._lens[seq_id] = 0
        return pages

    def extend(self, seq_id: int) -> int:
        if not self._free:
            raise PoolFullError("pool exhausted")
        p = self._free.pop()
        self._tables[seq_id].append(p)
        return p

    def free(self, seq_id: int) -> None:
        for p in self._tables.pop(seq_id):
            self._free.append(p)
        self._lens.pop(seq_id, None)

    # -------------------------------------------------------------- staging
    def stage_block(self, seq_id: int, token_offset: int, k: np.ndarray, v: np.ndarray) -> None:
        """Write a (L, n_tok, KVH, Dh) block at ``token_offset`` within the
        sequence (n_tok <= page_size; blocks never straddle pages when
        block_size == page_size, the default wiring)."""
        page_idx = token_offset // self.page_size
        within = token_offset % self.page_size
        n_tok = k.shape[1]
        assert within + n_tok <= self.page_size, "block straddles a page"
        page = self._tables[seq_id][page_idx]
        self.k_pages[page, :, within : within + n_tok] = k
        self.v_pages[page, :, within : within + n_tok] = v
        self._lens[seq_id] = max(self._lens[seq_id], token_offset + n_tok)

    def append_token(self, seq_id: int, k: np.ndarray, v: np.ndarray) -> None:
        """Decode step: append one token's (L, KVH, Dh) KV, extending the
        table when the tail page is full."""
        pos = self._lens[seq_id]
        if pos // self.page_size >= len(self._tables[seq_id]):
            self.extend(seq_id)
        self.stage_block(seq_id, pos, k[:, None], v[:, None])

    # ---------------------------------------------------------- kernel view
    def seq_len(self, seq_id: int) -> int:
        return self._lens[seq_id]

    def block_tables(self, seq_ids: Sequence[int]) -> np.ndarray:
        """(B, NB) int32 page-id table padded with page 0 (masked by kv_len
        in the kernel)."""
        nb = max(len(self._tables[s]) for s in seq_ids)
        out = np.zeros((len(seq_ids), nb), np.int32)
        for i, s in enumerate(seq_ids):
            t = self._tables[s]
            out[i, : len(t)] = t
        return out

    def kv_lens(self, seq_ids: Sequence[int]) -> np.ndarray:
        return np.asarray([self._lens[s] for s in seq_ids], np.int32)

    def layer_view(self, layer: int):
        """(P, page, KVH, Dh) views for one layer — the kernel's operands."""
        return self.k_pages[:, layer], self.v_pages[:, layer]
