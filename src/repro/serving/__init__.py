from .compute_model import ComputeModel, calibrate_host_flops, prefill_flops  # noqa: F401
from .engine import EngineStats, RequestRecord, ServingEngine  # noqa: F401
