"""Calibrated TTFT compute model.

This container is CPU-only and single-core, so full-size prefill compute
cannot be *measured*; the paper's TTFT has two components we account
separately (DESIGN.md §7):

  * I/O — measured for real against the actual disk backends.
  * compute — modeled: we time a real prefill of the reduced (smoke) model
    once on this host, derive its achieved FLOP/s, and scale by the analytic
    FLOP ratio to the full model on the paper's GPU (A30, 165 TFLOP/s bf16
    dense, ~60 % MFU assumed for prefill) or any target device.

The model covers segmented prefill: sequences longer than ``segment``
tokens prefill in chunks with per-segment scheduling overhead, matching the
paper's observation that long prompts pay extra scheduling/memory-management
cost under GPU memory pressure.

``ServingEngine`` consumes this model two ways: ``prefill_s`` terms are
added to each request's TTFT accounting, and with
``simulate_compute_wall=True`` the modeled duration is also *slept*
(GIL released) so the pipelined engine has a real compute window to
overlap promotion I/O under — the honest way to measure overlap on a
host with no accelerator.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import numpy as np


def prefill_flops(cfg, n_tokens: int, context: int = 0) -> float:
    """Analytic forward FLOPs to prefill ``n_tokens`` given ``context``
    already-cached tokens."""
    n = cfg.active_param_count()
    base = 2.0 * n * n_tokens
    if cfg.attention != "none" and cfg.family != "rwkv6":
        sites = cfg.n_layers if cfg.attn_every == 0 else cfg.n_layers // cfg.attn_every
        # causal attention over (context + position) keys
        total_kv = n_tokens * context + n_tokens * (n_tokens + 1) / 2
        base += 4.0 * sites * cfg.n_heads * cfg.d_head * total_kv
    return base


@dataclass
class ComputeModel:
    """TTFT compute estimator for one (model, device) pair."""

    cfg: object  # full ModelConfig
    device_flops: float = 165e12 * 0.6  # A30 bf16 at 60% prefill MFU
    segment: int = 2048  # segmented-prefill chunk (GPU memory pressure)
    segment_overhead_s: float = 0.008  # scheduler + memory mgmt per segment
    decode_tok_s: float = 0.02  # per output token (not in TTFT)

    def prefill_s(self, n_tokens: int, context: int = 0) -> float:
        if n_tokens <= 0:
            return 0.0
        segs = max(1, -(-n_tokens // self.segment))
        fl = prefill_flops(self.cfg, n_tokens, context)
        return fl / self.device_flops + segs * self.segment_overhead_s

    def ttft(self, prompt_len: int, reused: int, io_s: float) -> float:
        """TTFT = promotion I/O + compute for the non-reused suffix."""
        return io_s + self.prefill_s(prompt_len - reused, context=reused)


def calibrate_host_flops(smoke_cfg, n_tokens: int = 256, iters: int = 2) -> float:
    """Measure this host's achieved FLOP/s on a real smoke-model prefill —
    grounds the compute model in a real measurement (used by examples that
    serve the tiny model for real)."""
    import jax
    import jax.numpy as jnp

    from ..models import api

    params = api.init_params(smoke_cfg, jax.random.key(0))
    pfn = api.prefill_fn(smoke_cfg)
    cache = api.init_cache(smoke_cfg, 1, n_tokens)
    toks = jnp.zeros((1, n_tokens), jnp.int32)
    inputs = {"tokens": toks}
    if smoke_cfg.family == "encdec":
        inputs["frames"] = jnp.zeros((1, smoke_cfg.enc_frames, smoke_cfg.d_model), jnp.bfloat16)
    step = jax.jit(lambda p, i, c: pfn(p, i, c, 0)[0])
    step(params, inputs, cache).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        step(params, inputs, cache).block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return prefill_flops(smoke_cfg, n_tokens) / max(dt, 1e-9)
