"""Serving engine: continuous batching over the cache hierarchy.

Request lifecycle (paper Fig. 6):
  submit -> (batch formation) -> acquire (radix match + disk probe/get)
         -> prefill the non-reused suffix -> commit (write-through put)
         -> first token (TTFT recorded) -> release -> maintenance

Production concerns implemented here:
  * continuous batching with a token budget per engine step,
  * TTFT accounting split into measured I/O + (modeled or real) compute,
  * straggler mitigation: hedged disk reads — if a block promotion exceeds
    ``hedge_factor`` x the EWMA read latency, the read is re-issued and the
    faster attempt wins (both measured; duplicate I/O is accounted),
  * scheduled maintenance (LSM compaction / file merging) between batches,
    mirroring the paper's "scheduled compaction cycles".
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

from ..cache.hierarchy import CacheHierarchy
from .compute_model import ComputeModel


@dataclass
class RequestRecord:
    rid: int
    prompt_len: int
    reused_tokens: int = 0
    io_s: float = 0.0
    compute_s: float = 0.0
    ttft_s: float = 0.0
    hedged: bool = False
    stage: int = -1


@dataclass
class EngineStats:
    completed: int = 0
    hedged_reads: int = 0
    redispatches: int = 0
    maintenance_runs: int = 0
    # aggregated from backend maintenance reports; a sharded backend sums
    # these across the shards each cycle touched
    maintenance_compactions: int = 0
    evicted_files: int = 0

    ttfts: List[float] = field(default_factory=list)
    hits: List[float] = field(default_factory=list)

    @property
    def mean_ttft(self) -> float:
        return float(np.mean(self.ttfts)) if self.ttfts else 0.0

    @property
    def mean_hit(self) -> float:
        return float(np.mean(self.hits)) if self.hits else 0.0


class ServingEngine:
    def __init__(
        self,
        hierarchy: CacheHierarchy,
        compute: ComputeModel,
        kv_bytes_per_token: int,
        max_batch_tokens: int = 16_384,
        hedge_factor: float = 4.0,
        maintenance_every: int = 8,
        real_prefill: Optional[Callable] = None,
    ):
        self.h = hierarchy
        self.compute = compute
        self.kv_bytes_per_token = kv_bytes_per_token
        self.max_batch_tokens = max_batch_tokens
        self.hedge_factor = hedge_factor
        self.maintenance_every = maintenance_every
        self.real_prefill = real_prefill  # (tokens, reused) -> (blocks, seconds)
        self.stats = EngineStats()
        self._queue: Deque = deque()  # popleft is O(1); list.pop(0) was O(n)
        self._batches = 0
        self._ewma_read_s: float = 0.0
        self._block_template: Optional[np.ndarray] = None

    # ------------------------------------------------------------ lifecycle
    def submit(self, request) -> None:
        self._queue.append(request)

    def run(self) -> List[RequestRecord]:
        out = []
        while self._queue:
            out.extend(self.step())
        return out

    def step(self) -> List[RequestRecord]:
        """One continuous-batching iteration: take requests up to the token
        budget, serve each (acquire -> prefill -> commit), run maintenance."""
        batch, tokens = [], 0
        while self._queue and tokens + len(self._queue[0].tokens) <= self.max_batch_tokens:
            r = self._queue.popleft()
            batch.append(r)
            tokens += len(r.tokens)
        if not batch and self._queue:  # oversized single request
            batch.append(self._queue.popleft())
        records = [self._serve_one(r) for r in batch]
        self._batches += 1
        if self._batches % self.maintenance_every == 0:
            rep = self.h.maintenance()
            self.stats.maintenance_runs += 1
            self.stats.maintenance_compactions += int(rep.get("compactions", 0) or 0)
            self.stats.evicted_files += int(rep.get("evicted_files", 0) or 0)
        return records

    # ------------------------------------------------------------- serving
    def _acquire_hedged(self, tokens):
        """Hedged promotion: re-issue the disk read when it exceeds
        hedge_factor x EWMA latency (straggler mitigation)."""
        t0 = time.perf_counter()
        acq = self.h.acquire(tokens)
        dt = time.perf_counter() - t0
        hedged = False
        if (
            self._ewma_read_s > 0
            and dt > self.hedge_factor * self._ewma_read_s
            and acq.disk_tokens > 0
        ):
            # straggler: retry the promotion path; fastest attempt wins
            self.h.release(acq)
            t1 = time.perf_counter()
            acq2 = self.h.acquire(tokens)
            dt2 = time.perf_counter() - t1
            self.stats.hedged_reads += 1
            hedged = True
            if dt2 < dt:
                acq, dt = acq2, dt2
            else:
                self.h.release(acq2)
        self._ewma_read_s = 0.9 * self._ewma_read_s + 0.1 * dt if self._ewma_read_s else dt
        return acq, dt, hedged

    def _serve_one(self, req) -> RequestRecord:
        tokens = req.tokens
        B = self.h.block_size
        acq, io_s, hedged = self._acquire_hedged(tokens)
        reused = acq.reuse_tokens
        n_new = len(tokens) - reused

        if self.real_prefill is not None:
            new_blocks, compute_s = self.real_prefill(tokens, reused)
        else:
            compute_s = self.compute.prefill_s(n_new, context=reused)
            n_blocks = (len(tokens) // B) - (reused // B)
            # realistic payload entropy (zeros would compress to nothing and
            # fake the storage pressure the paper's claims rest on)
            if self._block_template is None:
                shape = (B, max(1, self.kv_bytes_per_token // 2))
                self._block_template = np.random.default_rng(0).standard_normal(shape).astype(np.float16)
            new_blocks = [self._block_template] * n_blocks
        self.h.commit(tokens, new_blocks, acq)
        self.h.release(acq)

        rec = RequestRecord(
            rid=getattr(req, "rid", -1),
            prompt_len=len(tokens),
            reused_tokens=reused,
            io_s=io_s,
            compute_s=compute_s,
            ttft_s=io_s + compute_s,
            hedged=hedged,
            stage=getattr(req, "stage", -1),
        )
        self.stats.completed += 1
        self.stats.ttfts.append(rec.ttft_s)
        self.stats.hits.append(reused / max(1, len(tokens)))
        return rec
