"""Serving engine: continuous batching over the cache hierarchy.

Request lifecycle (paper Fig. 6):
  submit -> (batch formation) -> acquire (radix match + disk probe/get)
         -> prefill the non-reused suffix -> commit (write-through put)
         -> first token (TTFT recorded) -> release -> maintenance

Production concerns implemented here:
  * continuous batching with a token budget per engine step,
  * TTFT accounting split into measured I/O + (modeled or real) compute,
  * a two-stage pipeline (``runtime=RuntimeServices(...)``): while batch k
    is being served, batch k+1's disk fetches (probe + batched get) are
    already running on the I/O executor — ``hierarchy.plan`` on the engine
    thread, ``hierarchy.fetch`` on the pool, ``hierarchy.fulfill`` back on
    the engine thread.  TTFT then pays only the *non-overlapped* remainder
    of the I/O (``io_wait``), not the full promotion,
  * write-behind commits: the disk write-through rides the runtime's
    ``CommitQueue`` drain thread instead of the request,
  * straggler mitigation: hedged disk reads — when a fetch future exceeds
    ``hedge_factor`` x the EWMA fetch latency, a second fetch is issued on
    the executor and the faster attempt wins (duplicate I/O is accounted).
    Without a runtime the legacy inline re-issue path is used,
  * scheduled maintenance (LSM compaction / file merging) between batches —
    run through ``MaintenanceService`` off the request path when a runtime
    is attached, inline otherwise.

Concurrency contract for the stats: ``EngineStats`` is only ever mutated
on the engine thread.  Worker-side counters live in the runtime services'
own locked stats objects and are folded in via ``harvest()`` /
``runtime_report()`` on the engine thread, so totals stay consistent
without putting a lock on the request path.
"""

from __future__ import annotations

import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED
from concurrent.futures import TimeoutError as FutureTimeoutError
from concurrent.futures import wait as futures_wait
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..cache.hierarchy import AcquirePlan, CacheHierarchy, DiskFetch
from ..obs import MetricsRegistry, TraceContext, activate, dataclass_gauges
from ..obs.tracing import maybe_span
from ..runtime import RuntimeServices
from .compute_model import ComputeModel


@dataclass
class RequestRecord:
    rid: int
    prompt_len: int
    reused_tokens: int = 0
    io_s: float = 0.0
    io_wait_s: float = 0.0  # non-overlapped wait on the prefetch future
    first_block_s: Optional[float] = None  # streamed fetch: time-to-first-block
    compute_s: float = 0.0
    ttft_s: float = 0.0
    hedged: bool = False
    prefetched: bool = False
    stage: int = -1


@dataclass
class EngineStats:
    completed: int = 0
    hedged_reads: int = 0
    redispatches: int = 0
    maintenance_runs: int = 0
    # aggregated from backend maintenance reports; a sharded backend sums
    # these across the shards each cycle touched
    maintenance_compactions: int = 0
    evicted_files: int = 0

    # pipeline accounting (engine-thread-only writers; see module docstring)
    prefetched_requests: int = 0
    prefetch_ready: int = 0  # future already resolved when the engine needed it
    io_wait_s: float = 0.0  # I/O the pipeline could NOT hide (charged to TTFT)
    overlap_io_s: float = 0.0  # I/O executed under the previous batch's service

    ttfts: List[float] = field(default_factory=list)
    ttfbs: List[float] = field(default_factory=list)  # streamed fetches only
    hits: List[float] = field(default_factory=list)

    @property
    def mean_ttft(self) -> float:
        return float(np.mean(self.ttfts)) if self.ttfts else 0.0

    @property
    def mean_ttfb(self) -> float:
        """Mean time-to-first-block across fetches that streamed — the
        latency at which the pipeline starts installing disk state."""
        return float(np.mean(self.ttfbs)) if self.ttfbs else 0.0

    @property
    def mean_hit(self) -> float:
        return float(np.mean(self.hits)) if self.hits else 0.0


@dataclass
class _Staged:
    """A request whose acquire phases 1(+2) already ran (``plan`` is None
    in the no-runtime path, where acquire plans internally)."""

    req: object
    plan: Optional[AcquirePlan]
    future: Optional[object] = None  # Future[DiskFetch] when prefetched
    trace: Optional[TraceContext] = None  # per-request trace (tracing=True)


class ServingEngine:
    def __init__(
        self,
        hierarchy: CacheHierarchy,
        compute: ComputeModel,
        kv_bytes_per_token: int,
        max_batch_tokens: int = 16_384,
        hedge_factor: float = 4.0,
        maintenance_every: int = 8,
        real_prefill: Optional[Callable] = None,
        runtime: Optional[RuntimeServices] = None,
        pipeline: Optional[bool] = None,
        simulate_compute_wall: bool = False,
        registry: Optional[MetricsRegistry] = None,
        tracing: bool = False,
    ):
        """``simulate_compute_wall``: when compute is *modeled* (no
        ``real_prefill``), additionally occupy real wall-clock time for the
        modeled duration (a GIL-releasing sleep — the engine thread is
        "waiting on the accelerator").  This is what makes overlap
        measurable end to end on a CPU-only container: the I/O executor
        prefetches into exactly the window a GPU deployment would expose.
        Off by default (tests and hit-rate benchmarks don't want the wall
        time)."""
        self.h = hierarchy
        self.compute = compute
        self.kv_bytes_per_token = kv_bytes_per_token
        self.max_batch_tokens = max_batch_tokens
        self.hedge_factor = hedge_factor
        self.maintenance_every = maintenance_every
        self.real_prefill = real_prefill  # (tokens, reused) -> (blocks, seconds)
        self.simulate_compute_wall = simulate_compute_wall
        self.runtime = runtime
        # pipeline defaults to on exactly when an async runtime is attached
        self.pipeline = bool(runtime and runtime.async_mode) if pipeline is None else bool(pipeline)
        if runtime is not None:
            # wire the write-behind queue into the hierarchy (unless the
            # caller attached their own) and bind off-path maintenance
            if self.h.commit_queue is None and runtime.commits is not None:
                self.h.commit_queue = runtime.commits
            self._maintenance = runtime.bind_maintenance(self.h.maintenance)
        else:
            self._maintenance = None
        self.stats = EngineStats()
        self._queue: Deque = deque()  # popleft is O(1); list.pop(0) was O(n)
        self._staged: Optional[List[_Staged]] = None  # batch k+1, prefetching
        self._batches = 0
        self._ewma_read_s: float = 0.0
        self._block_template: Optional[np.ndarray] = None
        self.tracing = bool(tracing)
        self.registry = registry if registry is not None else MetricsRegistry()
        self._register_metrics()

    def _register_metrics(self) -> None:
        """Wire the engine, cache, and runtime stats into the registry so
        one snapshot covers the whole serving stack.  Collectors read the
        live dataclasses at snapshot time — no double bookkeeping."""
        reg = self.registry
        self._h_ttft = reg.histogram("repro_engine_ttft_seconds")
        self._h_io_wait = reg.histogram("repro_engine_io_wait_seconds")
        reg.register_collector(dataclass_gauges(
            "repro_engine", self.stats,
            extra=lambda: {
                "repro_engine_mean_ttft_s": self.stats.mean_ttft,
                "repro_engine_mean_ttfb_s": self.stats.mean_ttfb,
                "repro_engine_mean_hit": self.stats.mean_hit,
                "repro_engine_streamed_fetches": float(len(self.stats.ttfbs)),
            }))
        reg.register_collector(dataclass_gauges("repro_cache", self.h.stats))
        if self.runtime is not None:
            reg.register_collector(dataclass_gauges(
                "repro_executor", self.runtime.executor.stats,
                lock=self.runtime.executor._lock))
            if self.runtime.commits is not None:
                reg.register_collector(dataclass_gauges(
                    "repro_commit_queue", self.runtime.commits.stats))
        if self._maintenance is not None:
            reg.register_collector(dataclass_gauges(
                "repro_maintenance", self._maintenance.stats))

    # ------------------------------------------------------------ lifecycle
    def submit(self, request) -> None:
        self._queue.append(request)

    def run(self) -> List[RequestRecord]:
        out = []
        while self._queue or self._staged:
            out.extend(self.step())
        return out

    def drain(self) -> None:
        """Quiesce the runtime (flush write-behind, finish maintenance) and
        fold its counters into the engine stats."""
        if self.runtime is not None:
            self.runtime.drain()
            self._harvest_maintenance()

    def close(self) -> None:
        if self.runtime is not None:
            self.drain()
            self.runtime.close()

    # ------------------------------------------------------- batch formation
    def _form_batch(self) -> List:
        batch, tokens = [], 0
        while self._queue and tokens + len(self._queue[0].tokens) <= self.max_batch_tokens:
            r = self._queue.popleft()
            batch.append(r)
            tokens += len(r.tokens)
        if not batch and self._queue:  # oversized single request
            batch.append(self._queue.popleft())
        return batch

    def _stage(self, batch: List, prefetch: bool) -> List[_Staged]:
        """Phase 1 for every request (engine thread); optionally launch
        phase 2 on the executor (prefetch-ahead)."""
        staged = []
        ex = self.runtime.executor if self.runtime is not None else None
        for r in batch:
            trace = TraceContext() if self.tracing else None
            if ex is None:
                # no runtime: the legacy acquire path re-plans internally,
                # so planning here would walk the radix tree twice
                staged.append(_Staged(req=r, plan=None, trace=trace))
                continue
            with activate(trace) if trace is not None else nullcontext():
                plan = self.h.plan(r.tokens)
                fut = None
                # never stall the engine thread on the admission gate: if
                # the pool is saturated, try_submit declines and the fetch
                # runs at serve time in _resolve_fetch, when slots have
                # freed.  (The old in_flight < max_pending check raced
                # other submitters into exactly the stall it was written
                # to avoid.)  try_submit captures the active trace, so the
                # prefetch worker's spans land on this request.
                if prefetch and plan.need_disk:
                    fut = ex.try_submit(self.h.fetch, plan)
                    if fut is not None:
                        self.stats.prefetched_requests += 1
            staged.append(_Staged(req=r, plan=plan, future=fut, trace=trace))
        return staged

    def step(self) -> List[RequestRecord]:
        """One continuous-batching iteration.  Serial mode: take a batch,
        serve it, run maintenance.  Pipelined mode: serve the batch whose
        fetches were launched last step, while this step launches the
        fetches of the next one."""
        can_prefetch = self.pipeline and self.runtime is not None and self.runtime.async_mode
        if self._staged is not None:
            current = self._staged
            self._staged = None
        else:
            # first batch of a burst: no earlier step staged it, but its
            # fetches still fan out on the executor (intra-batch overlap) —
            # and they must be submitted BEFORE the next batch's prefetch
            # so the FIFO pool serves the batch we are about to block on
            current = self._stage(self._form_batch(), prefetch=can_prefetch)
        if can_prefetch:
            nxt = self._stage(self._form_batch(), prefetch=True)
            self._staged = nxt or None
        records = [self._serve_one(s) for s in current]
        self._batches += 1
        if self._batches % self.maintenance_every == 0:
            if self._maintenance is not None and self.runtime.async_mode:
                self._maintenance.maybe_schedule()
                self.stats.maintenance_runs += 1
            else:
                rep = self._maintenance.run_inline() if self._maintenance else self.h.maintenance()
                self.stats.maintenance_runs += 1
                if self._maintenance is None:
                    self.stats.maintenance_compactions += int(rep.get("compactions", 0) or 0)
                    self.stats.evicted_files += int(rep.get("evicted_files", 0) or 0)
        self._harvest_maintenance()
        return records

    def _harvest_maintenance(self) -> None:
        if self._maintenance is None:
            return
        got = self._maintenance.harvest()
        self.stats.maintenance_compactions += got.compactions
        self.stats.evicted_files += got.evicted_files

    # ------------------------------------------------------------- serving
    def _acquire_hedged(self, tokens):
        """Legacy inline hedging (no runtime attached): re-issue the whole
        promotion when it exceeds hedge_factor x EWMA latency."""
        t0 = time.perf_counter()
        acq = self.h.acquire(tokens)
        dt = time.perf_counter() - t0
        hedged = False
        if (
            self._ewma_read_s > 0
            and dt > self.hedge_factor * self._ewma_read_s
            and acq.disk_tokens > 0
        ):
            # straggler: retry the promotion path; fastest attempt wins
            self.h.release(acq)
            t1 = time.perf_counter()
            acq2 = self.h.acquire(tokens)
            dt2 = time.perf_counter() - t1
            self.stats.hedged_reads += 1
            hedged = True
            if dt2 < dt:
                acq, dt = acq2, dt2
            else:
                self.h.release(acq2)
        self._ewma_read_s = 0.9 * self._ewma_read_s + 0.1 * dt if self._ewma_read_s else dt
        return acq, dt, hedged

    def _resolve_fetch(self, st: _Staged) -> Tuple[DiskFetch, float, bool]:
        """Obtain the DiskFetch for a staged request: wait on the prefetch
        future (hedging stragglers on the executor) or, if none was
        launched, run the fetch through the executor now.  Returns
        (fetch, wait_seconds, hedged)."""
        ex = self.runtime.executor
        fut = st.future
        if fut is None:
            if not st.plan.need_disk:
                return DiskFetch(), 0.0, False
            fut = ex.submit(self.h.fetch, st.plan)
        elif fut.done():
            self.stats.prefetch_ready += 1
        t0 = time.perf_counter()
        hedged = False
        timeout = self.hedge_factor * self._ewma_read_s if self._ewma_read_s > 0 else None
        try:
            fetched = fut.result(timeout=timeout)
        except FutureTimeoutError:
            # straggler: hedge on the executor; first finished attempt wins
            hedge = ex.submit(self.h.fetch, st.plan)
            self.stats.hedged_reads += 1
            self.stats.redispatches += 1
            hedged = True
            pending = {fut, hedge}
            done = set()
            while not done:
                done, pending = futures_wait(pending, timeout=1.0, return_when=FIRST_COMPLETED)
            fetched = next(iter(done)).result()
        wait_s = time.perf_counter() - t0
        if fetched.io_s > 0:
            self._ewma_read_s = (
                0.9 * self._ewma_read_s + 0.1 * fetched.io_s if self._ewma_read_s else fetched.io_s
            )
        return fetched, wait_s, hedged

    def _serve_one(self, st: _Staged) -> RequestRecord:
        with activate(st.trace) if st.trace is not None else nullcontext():
            rec = self._serve(st)
        self._h_ttft.observe(rec.ttft_s)
        self._h_io_wait.observe(rec.io_wait_s)
        if st.trace is not None:
            # one histogram per span name: the engine-side closure of the
            # trace, matching the node-side close-out in the server
            for name, total in st.trace.span_totals().items():
                self.registry.histogram(
                    f"repro_engine_span_seconds_{name}").observe(total)
        return rec

    def _serve(self, st: _Staged) -> RequestRecord:
        req = st.req
        tokens = req.tokens
        B = self.h.block_size
        prefetched = st.future is not None
        first_block_s: Optional[float] = None
        if self.runtime is not None:
            fetched, wait_s, hedged = self._resolve_fetch(st)
            first_block_s = fetched.first_block_s
            t1 = time.perf_counter()
            acq = self.h.fulfill(st.plan, fetched)
            install_s = time.perf_counter() - t1
            # TTFT charges only the I/O the pipeline failed to hide: the
            # blocking wait plus the on-thread install.  Whatever the fetch
            # did while the previous batch was being served is overlap.
            io_s = wait_s + install_s
            self.stats.io_wait_s += wait_s
            if prefetched:
                self.stats.overlap_io_s += max(0.0, fetched.io_s - wait_s)
        else:
            acq, io_s, hedged = self._acquire_hedged(tokens)
            wait_s = io_s
        reused = acq.reuse_tokens
        n_new = len(tokens) - reused

        with maybe_span("compute"):
            if self.real_prefill is not None:
                new_blocks, compute_s = self.real_prefill(tokens, reused)
            else:
                compute_s = self.compute.prefill_s(n_new, context=reused)
                n_blocks = (len(tokens) // B) - (reused // B)
                # realistic payload entropy (zeros would compress to nothing
                # and fake the storage pressure the paper's claims rest on)
                if self._block_template is None:
                    shape = (B, max(1, self.kv_bytes_per_token // 2))
                    self._block_template = np.random.default_rng(0).standard_normal(shape).astype(np.float16)
                new_blocks = [self._block_template] * n_blocks
                if self.simulate_compute_wall and compute_s > 0:
                    time.sleep(compute_s)  # GIL released: prefetch runs under this
        with maybe_span("commit"):
            self.h.commit(tokens, new_blocks, acq)
            self.h.release(acq)

        rec = RequestRecord(
            rid=getattr(req, "rid", -1),
            prompt_len=len(tokens),
            reused_tokens=reused,
            io_s=io_s,
            io_wait_s=wait_s,
            first_block_s=first_block_s,
            compute_s=compute_s,
            ttft_s=io_s + compute_s,
            hedged=hedged,
            prefetched=prefetched,
            stage=getattr(req, "stage", -1),
        )
        self.stats.completed += 1
        self.stats.ttfts.append(rec.ttft_s)
        if first_block_s is not None:
            self.stats.ttfbs.append(first_block_s)
        self.stats.hits.append(reused / max(1, len(tokens)))
        return rec

    # ---------------------------------------------------------------- report
    def metrics_snapshot(self) -> Dict:
        """Full registry snapshot (counters / gauges / histograms) — the
        engine-side twin of the node server's ``OP_METRICS`` reply."""
        return self.registry.snapshot()

    def runtime_report(self) -> Dict:
        """Engine + runtime counters in one machine-readable dict (the
        benchmark artifact format).  Scalar fields are read back out of
        the metrics registry — the same snapshot the scrape endpoint
        exports — so the report and the exposition can never disagree."""
        snap = self.registry.snapshot()
        g = snap["gauges"]
        ttft = snap["histograms"]["repro_engine_ttft_seconds"]
        out: Dict = {
            "completed": int(g.get("repro_engine_completed", 0)),
            "mean_ttft_s": g.get("repro_engine_mean_ttft_s", 0.0),
            "mean_time_to_first_block_s": g.get("repro_engine_mean_ttfb_s", 0.0),
            "streamed_fetches": int(g.get("repro_engine_streamed_fetches", 0)),
            "mean_hit": g.get("repro_engine_mean_hit", 0.0),
            "hedged_reads": int(g.get("repro_engine_hedged_reads", 0)),
            "prefetched_requests": int(g.get("repro_engine_prefetched_requests", 0)),
            "prefetch_ready": int(g.get("repro_engine_prefetch_ready", 0)),
            "io_wait_s": g.get("repro_engine_io_wait_s", 0.0),
            "overlap_io_s": g.get("repro_engine_overlap_io_s", 0.0),
            "maintenance_runs": int(g.get("repro_engine_maintenance_runs", 0)),
            "maintenance_compactions": int(g.get("repro_engine_maintenance_compactions", 0)),
            "evicted_files": int(g.get("repro_engine_evicted_files", 0)),
            "plan_stale": int(g.get("repro_cache_plan_stale", 0)),
            "writeback_blocks": int(g.get("repro_cache_writeback_blocks", 0)),
            "ttft_p50_s": ttft["p50"],
            "ttft_p95_s": ttft["p95"],
            "ttft_p99_s": ttft["p99"],
        }
        if self.runtime is not None:
            out["runtime"] = self.runtime.report()
        return out
