"""Baseline disk/memory KV-cache stores the paper evaluates against (§4.1):

* ``FilePerObjectStore`` — SGLang(file): one file per KV block, named by a
  hash of the token prefix.  Exhibits exactly the §1 pathologies: per-file
  open/write/close syscalls, no batching, filesystem block rounding (a
  2 KiB payload consumes >=4 KiB + inode), metadata pressure as file counts
  grow.  Filesystem overhead is charged for real via ``st_blocks``-style
  rounding so both backends compete under the same *physical* byte budget.

* ``MemoryOnlyStore`` — SGLang(memory): LRU dict bounded by a byte budget
  (models HBM+DRAM capacity, which forces the evictions the paper
  describes).

Both satisfy the ``repro.core.backend.StorageBackend`` protocol — including
its probe invariant (a probe reports a *contiguous* readable prefix, even
after LRU eviction punches holes mid-prefix) — so the hierarchy, serving
engine, and benchmarks are backend-agnostic.

Thread-safety: baselines take one coarse re-entrant lock around every
public operation.  That satisfies the backend contract (no lost writes, no
torn reads, consistent stats) without complicating code whose entire role
is to be the simple comparison point; the fine-grained design that keeps
readers lock-free lives in ``KVBlockStore``.
"""

from __future__ import annotations

import functools
import hashlib
import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from .batchops import BatchOpsMixin
from .codec import CODEC_RAW, BatchCodec
from .keycodec import encode_tokens
from .store import StoreStats


def _locked(fn):
    """Run the method under the instance's coarse ``_lock``."""

    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with self._lock:
            return fn(self, *args, **kwargs)

    return wrapper

FS_BLOCK = 4096  # filesystem allocation unit
INODE_OVERHEAD = 256  # metadata bytes charged per file (inode + dirent)


def fs_footprint(payload_bytes: int) -> int:
    """Physical bytes a payload costs in a file-per-object layout."""
    blocks = (payload_bytes + FS_BLOCK - 1) // FS_BLOCK
    return max(1, blocks) * FS_BLOCK + INODE_OVERHEAD


class FilePerObjectStore(BatchOpsMixin):
    """One file per KV block (state-of-practice disk backend)."""

    name = "file"

    def __init__(
        self,
        root: str,
        block_size: int = 16,
        codec: Optional[BatchCodec] = None,
        budget_bytes: Optional[int] = None,
        max_files: Optional[int] = None,
        meta_penalty_per_file_s: float = 0.0,
    ):
        """``meta_penalty_per_file_s``: optional modeled metadata latency per
        file operation per million resident files (calibrated by
        ``benchmarks/store_scalability.py`` from real measurements; default
        off so everything measured is real I/O)."""
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.block_size = block_size
        # the file backend cannot batch-compress (paper §3.4), so default raw
        self.codec = codec or BatchCodec(CODEC_RAW, use_zlib=False)
        self.budget_bytes = budget_bytes
        self.max_files = max_files
        self.meta_penalty = meta_penalty_per_file_s
        self._lru: "OrderedDict[str, int]" = OrderedDict()  # path -> fs bytes
        self.fs_bytes = 0
        self.stats = StoreStats()
        self.modeled_penalty_s = 0.0
        # holes mid-prefix only appear after an eviction or a refused write
        # (max_files wall); until then probe stays O(log n).  Persisted via
        # a marker file (as in KVBlockStore) so the probe contiguity
        # invariant survives reopen.
        self._holes_marker = os.path.join(root, "evicted.marker")
        self._may_have_holes = os.path.exists(self._holes_marker)
        self._lock = threading.RLock()
        self._recover()

    def _mark_holes(self) -> None:
        if not self._may_have_holes:
            self._may_have_holes = True
            open(self._holes_marker, "w").close()

    def _recover(self) -> None:
        for dirpath, _, files in os.walk(self.root):
            for f in files:
                if not f.endswith(".bin"):
                    continue  # bookkeeping files (evicted.marker) are not objects
                p = os.path.join(dirpath, f)
                fp = fs_footprint(os.path.getsize(p))
                self._lru[p] = fp
                self.fs_bytes += fp

    def _path(self, tokens: Sequence[int], n_tokens: int) -> str:
        h = hashlib.sha1(encode_tokens(tokens[:n_tokens])).hexdigest()
        return os.path.join(self.root, h[:2], h[2:4], h + ".bin")

    def _charge_meta(self) -> None:
        if self.meta_penalty:
            self.modeled_penalty_s += self.meta_penalty * (len(self._lru) / 1e6)

    def _touch(self, path: str) -> None:
        if path in self._lru:
            self._lru.move_to_end(path)

    @_locked
    def put_batch(self, tokens, blocks, start_block: int = 0, skip_existing: bool = True) -> int:
        B = self.block_size
        t0 = time.perf_counter()
        wrote = 0
        for i, block in enumerate(blocks):
            end = (start_block + i + 1) * B
            if end > len(tokens):
                break
            path = self._path(tokens, end)
            self._charge_meta()
            if skip_existing and path in self._lru:
                self._touch(path)
                continue
            if self.max_files is not None and len(self._lru) >= self.max_files:
                # the §4.2 wall: filesystem refuses/degrades past the file cap
                self._mark_holes()  # a later block may still land
                continue
            payload = self.codec.encode(np.asarray(block))
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(path, "wb") as f:  # one open/write/close per object
                f.write(payload)
            fp = fs_footprint(len(payload))
            self._lru[path] = fp
            self.fs_bytes += fp
            self.stats.payload_bytes_in += np.asarray(block).nbytes
            self.stats.payload_bytes_stored += len(payload)
            wrote += 1
        self.stats.put_blocks += wrote
        self.stats.put_tokens += wrote * B
        self.stats.io_write_s += time.perf_counter() - t0
        if self.budget_bytes is not None:
            self._evict_to_budget()
        return wrote

    @_locked
    def probe(self, tokens) -> int:
        B = self.block_size
        max_blocks = len(tokens) // B
        self.stats.probes += 1
        lo, hi = 0, max_blocks
        while lo < hi:
            mid = (lo + hi + 1) // 2
            self._charge_meta()
            self.stats.probe_lookups += 1
            if os.path.exists(self._path(tokens, mid * B)):  # stat() syscall
                lo = mid
            else:
                hi = mid - 1
        # LRU eviction (budget) and refused writes (max_files wall) punch
        # holes mid-prefix; confirm contiguity so probe matches what
        # get_batch can actually return.  Until a hole can exist, probe
        # keeps the pure O(log n) binary search.
        if lo and self._may_have_holes:
            k = 0
            while k < lo:
                self._charge_meta()
                self.stats.probe_lookups += 1
                if not os.path.exists(self._path(tokens, (k + 1) * B)):
                    break
                k += 1
            lo = k
        if lo == 0:
            self.stats.probe_empty += 1
        else:
            self.stats.probe_hits += 1
        return lo * B

    @_locked
    def get_batch(self, tokens, n_tokens: int) -> List[np.ndarray]:
        B = self.block_size
        t0 = time.perf_counter()
        out: List[np.ndarray] = []
        for i in range(n_tokens // B):
            path = self._path(tokens, (i + 1) * B)
            self._charge_meta()
            if not os.path.exists(path):
                break
            with open(path, "rb") as f:  # open/read/close per object
                out.append(BatchCodec.decode(f.read()))
            self._touch(path)
        self.stats.get_blocks += len(out)
        self.stats.get_tokens += len(out) * B
        self.stats.io_read_s += time.perf_counter() - t0
        return out

    def _evict_to_budget(self) -> None:
        while self.fs_bytes > self.budget_bytes and self._lru:
            self._mark_holes()
            path, fp = self._lru.popitem(last=False)
            try:
                os.remove(path)
            except OSError:
                pass
            self.fs_bytes -= fp
            self.stats.evicted_blocks += 1

    @_locked
    def maintenance(self, compact_steps: int = 0) -> dict:
        if self.budget_bytes is not None:
            self._evict_to_budget()
        return {}

    @property
    def disk_bytes(self) -> int:
        return self.fs_bytes

    @property
    def file_count(self) -> int:
        return len(self._lru)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class MemoryOnlyStore(BatchOpsMixin):
    """In-memory LRU KV cache bounded by a byte budget."""

    name = "memory"

    def __init__(self, budget_bytes: int, block_size: int = 16, **_):
        self.block_size = block_size
        self.budget_bytes = budget_bytes
        self._lru: "OrderedDict[bytes, np.ndarray]" = OrderedDict()
        self.bytes = 0
        self.stats = StoreStats()
        self._may_have_holes = False  # set on first LRU eviction
        self._lock = threading.RLock()

    def _key(self, tokens, n_tokens: int) -> bytes:
        return encode_tokens(tokens[:n_tokens])

    @_locked
    def put_batch(self, tokens, blocks, start_block: int = 0, skip_existing: bool = True) -> int:
        B = self.block_size
        wrote = 0
        for i, block in enumerate(blocks):
            end = (start_block + i + 1) * B
            if end > len(tokens):
                break
            key = self._key(tokens, end)
            if skip_existing and key in self._lru:
                self._lru.move_to_end(key)
                continue
            arr = np.asarray(block)
            self._lru[key] = arr
            self.bytes += arr.nbytes
            self.stats.payload_bytes_in += arr.nbytes
            self.stats.payload_bytes_stored += arr.nbytes
            wrote += 1
        while self.bytes > self.budget_bytes and self._lru:
            self._may_have_holes = True
            _, old = self._lru.popitem(last=False)
            self.bytes -= old.nbytes
            self.stats.evicted_blocks += 1
        self.stats.put_blocks += wrote
        self.stats.put_tokens += wrote * B
        return wrote

    @_locked
    def probe(self, tokens) -> int:
        B = self.block_size
        self.stats.probes += 1
        lo, hi = 0, len(tokens) // B
        while lo < hi:
            mid = (lo + hi + 1) // 2
            self.stats.probe_lookups += 1
            if self._key(tokens, mid * B) in self._lru:
                lo = mid
            else:
                hi = mid - 1
        # confirm contiguity once LRU eviction can have punched holes
        # (protocol invariant: probe never promises what get_batch lacks)
        if lo and self._may_have_holes:
            k = 0
            while k < lo and self._key(tokens, (k + 1) * B) in self._lru:
                k += 1
            lo = k
        if lo == 0:
            self.stats.probe_empty += 1
        else:
            self.stats.probe_hits += 1
        return lo * B

    @_locked
    def get_batch(self, tokens, n_tokens: int) -> List[np.ndarray]:
        B = self.block_size
        out: List[np.ndarray] = []
        for i in range(n_tokens // B):
            key = self._key(tokens, (i + 1) * B)
            blk = self._lru.get(key)
            if blk is None:
                break
            self._lru.move_to_end(key)
            out.append(blk)
        self.stats.get_blocks += len(out)
        self.stats.get_tokens += len(out) * B
        return out

    # ----------------------------------------------- key export (elasticity)
    # The cluster migration trio (see core.store).  Memory blocks are held
    # decoded, so export wraps them in the raw codec (flags 0 = hot tier)
    # and import decodes — the self-describing codec header keeps this
    # interoperable with LSM nodes that ship compressed tiers.

    @_locked
    def scan_keys(self, cursor: Optional[bytes] = None, limit: int = 1024):
        keys = sorted(k for k in self._lru if cursor is None or k > cursor)
        page = keys[:limit]
        next_cursor = page[-1] if len(keys) > limit else None
        return page, next_cursor

    @_locked
    def export_encoded(self, keys: Sequence[bytes]):
        codec = BatchCodec(CODEC_RAW, use_zlib=False)
        out = []
        n = 0
        for k in keys:
            blk = self._lru.get(bytes(k))
            if blk is None:
                out.append(None)
            else:
                out.append((0, codec.encode(blk)))
                n += 1
        self.stats.exported_blocks += n
        return out

    @_locked
    def import_encoded(self, records, skip_existing: bool = True) -> int:
        wrote = 0
        for key, _flags, payload in records:
            key = bytes(key)
            if skip_existing and key in self._lru:
                continue
            arr = BatchCodec.decode(bytes(payload))
            self._lru[key] = arr
            self.bytes += arr.nbytes
            self.stats.imported_blocks += 1
            self.stats.imported_bytes += len(payload)
            self.stats.payload_bytes_stored += arr.nbytes
            wrote += 1
        if wrote:
            # imported arcs need not be prefix-closed: verify contiguity
            self._may_have_holes = True
        while self.bytes > self.budget_bytes and self._lru:
            self._may_have_holes = True
            _, old = self._lru.popitem(last=False)
            self.bytes -= old.nbytes
            self.stats.evicted_blocks += 1
        return wrote

    @_locked
    def maintenance(self, compact_steps: int = 0) -> dict:
        return {}

    @property
    def disk_bytes(self) -> int:
        return self.bytes

    @property
    def file_count(self) -> int:
        return len(self._lru)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass
