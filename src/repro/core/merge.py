"""Automatic tensor-file merging + GC (paper §3.4 'Automatic Tensor File
Merging').

Activates when the tensor-log file count exceeds a threshold or a file's
garbage ratio passes a bound; live records from victim files are re-appended
to the active log (consolidating many small/stale files into few large
ones), and the corresponding ``file_id + offset`` index entries are
rewritten in the LSM-tree.  Scheduled from the store's maintenance cycle so
it rides along natural compaction windows rather than competing with
request processing.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List

from .tensorlog import LogPointer, TensorLog


@dataclass
class MergeReport:
    files_removed: int = 0
    records_moved: int = 0
    bytes_reclaimed: int = 0


class TensorFileMerger:
    def __init__(
        self,
        log: TensorLog,
        index,  # LSMTree holding key -> packed pointer (+meta) entries
        max_files: int = 64,
        garbage_threshold: float = 0.5,
        value_codec=None,  # (unpack, pack) hooks from the store: value <-> ptr
    ):
        self.log = log
        self.index = index
        self.max_files = max_files
        self.garbage_threshold = garbage_threshold
        if value_codec is None:
            value_codec = (
                lambda v: LogPointer.unpack(v),
                lambda ptr, old_v: ptr.pack() + old_v[20:],
            )
        self._unpack, self._pack = value_codec

    def _victims(self) -> List[int]:
        ids = self.log.file_ids()
        if not ids:
            return []
        active = ids[-1]
        victims = [f for f in ids if f != active and self.log.garbage_ratio(f) >= self.garbage_threshold]
        # file-count pressure: merge oldest files first until under threshold
        if self.log.file_count > self.max_files:
            extra = [f for f in ids if f != active and f not in victims]
            need = self.log.file_count - self.max_files
            victims.extend(extra[:need])
        return sorted(set(victims))

    def needed(self) -> bool:
        return bool(self._victims())

    def run(self, max_victims: int = 8) -> MergeReport:
        rep = MergeReport()
        for fid in self._victims()[:max_victims]:
            moved: List = []  # (key, old_value, payload)
            for ptr, key, payload in self.log.scan_file(fid):
                found, v = self.index.get(key)
                if not found:
                    continue  # evicted/stale: garbage
                cur = self._unpack(v)
                if (cur.file_id, cur.offset) != (ptr.file_id, ptr.offset):
                    continue  # superseded copy: garbage
                moved.append((key, v, payload))
            if moved:
                new_ptrs = self.log.append_batch([(k, p) for k, _, p in moved])
                self.index.put_batch(
                    (k, self._pack(np_, old_v)) for (k, old_v, _), np_ in zip(moved, new_ptrs)
                )
                rep.records_moved += len(moved)
            size = self.log._files.get(fid, {}).get("size", 0)
            self.log.remove_file(fid)
            rep.files_removed += 1
            rep.bytes_reclaimed += size
        return rep
