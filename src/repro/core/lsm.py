"""LSM-tree engine: memtable + WAL + leveled/tiered sorted runs with lazy
per-level (T, K) adoption (paper §3.3, App. C).

This is the *index* of the key-value-separated design: values handed to
``put`` are small pointer records (``tensorlog.LogPointer`` + metadata), so
compaction here never rewrites tensor payloads.

Structure
---------
* level i holds up to ``K_i`` runs and ``C_i = M·∏_{j<=i} T_j`` bytes.
* flush: memtable → new run at level 0.
* compaction step (``maybe_compact``): first level violating its run-count
  or byte budget merges **all** its runs; the merged run stays at the level
  if it now fits (leveling behaviour), otherwise moves to level i+1
  (tiering cascade).  K=1 ⇒ leveling, K=T−1 ⇒ tiering, anything between is
  a valid hybrid (Dostoevsky-style).
* lazy transitions: the controller sets *target* (T, K); a level adopts the
  targets only when it next participates in a compaction — never a
  wholesale restructure (App. C.2).
"""

from __future__ import annotations

import heapq
import os
import threading
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

from .memtable import MemTable
from .sst import RunMeta, SSTReader, SSTWriter
from .wal import WAL, ManifestStore


@dataclass
class _Run:
    meta: RunMeta
    reader: SSTReader


@dataclass
class _Level:
    T: int  # size ratio adopted by this level
    K: int  # max sorted runs
    runs: List[_Run] = field(default_factory=list)  # newest first

    @property
    def bytes(self) -> int:
        return sum(r.meta.data_bytes for r in self.runs)


@dataclass
class LSMStats:
    puts: int = 0
    gets: int = 0
    get_hits: int = 0
    range_scans: int = 0
    flushes: int = 0
    compactions: int = 0
    compact_bytes_in: int = 0
    compact_bytes_out: int = 0
    bloom_negative: int = 0

    @property
    def write_amplification(self) -> float:
        return self.compact_bytes_out / max(1, self.compact_bytes_in)


class LSMTree:
    def __init__(
        self,
        root: str,
        buffer_bytes: int = 1 << 20,
        size_ratio: int = 4,
        runs_per_level: int = 1,
        block_bytes: int = 4096,
        bloom_bits_per_key: float = 10.0,
        fsync: bool = False,
    ):
        self.root = root
        os.makedirs(root, exist_ok=True)
        # One re-entrant lock serializes every structural operation
        # (memtable mutation, WAL append, flush, compaction, manifest
        # install) *and* point/range reads: reads traverse the memtable and
        # the live run list, both of which flush/compaction rewrite.  The
        # expensive I/O the serving path cares about — tensor-log payload
        # reads — lives outside this tree and stays lock-free; index
        # entries are tiny pointer records, so the critical sections here
        # are short.
        self._lock = threading.RLock()
        self.buffer_bytes = buffer_bytes
        self.block_bytes = block_bytes
        self.bloom_bits_per_key = bloom_bits_per_key
        self.fsync = fsync
        self.target_T = size_ratio
        self.target_K = runs_per_level
        self.mem = MemTable()
        self.levels: List[_Level] = []
        self.stats = LSMStats()
        self._seq = 0
        self._run_no = 0
        self.manifest = ManifestStore(root)
        self._wal_path = os.path.join(root, "wal.log")
        self._recover()
        self.wal = WAL(self._wal_path)

    # ------------------------------------------------------------------ setup
    def _recover(self) -> None:
        state = self.manifest.load()
        if state:
            self._seq = state["seq"]
            self._run_no = state["run_no"]
            self.target_T = state.get("target_T", self.target_T)
            self.target_K = state.get("target_K", self.target_K)
            for lv in state["levels"]:
                level = _Level(T=lv["T"], K=lv["K"])
                for rm in lv["runs"]:
                    path = os.path.join(self.root, rm["file"])
                    if not os.path.exists(path):
                        continue  # crashed mid-compaction before install: ignore
                    meta = RunMeta(
                        path=path,
                        min_key=bytes.fromhex(rm["min"]),
                        max_key=bytes.fromhex(rm["max"]),
                        entries=rm["entries"],
                        data_bytes=rm["bytes"],
                        seq=rm["seq"],
                    )
                    level.runs.append(_Run(meta, SSTReader(path)))
                self.levels.append(level)
        # replay WAL into memtable (records newer than last flush)
        for key, value in WAL.replay(self._wal_path):
            self.mem.put(key, value)

    def _install_manifest(self) -> None:
        state = {
            "seq": self._seq,
            "run_no": self._run_no,
            "target_T": self.target_T,
            "target_K": self.target_K,
            "levels": [
                {
                    "T": lv.T,
                    "K": lv.K,
                    "runs": [
                        {
                            "file": os.path.basename(r.meta.path),
                            "min": r.meta.min_key.hex(),
                            "max": r.meta.max_key.hex(),
                            "entries": r.meta.entries,
                            "bytes": r.meta.data_bytes,
                            "seq": r.meta.seq,
                        }
                        for r in lv.runs
                    ],
                }
                for lv in self.levels
            ],
        }
        self.manifest.install(state)

    # ------------------------------------------------------------- public API
    def put(self, key: bytes, value: Optional[bytes]) -> None:
        with self._lock:
            self.wal.append(key, value)
            self.mem.put(key, value)
            self.stats.puts += 1
            if self.fsync:
                self.wal.sync()
            if self.mem.bytes >= self.buffer_bytes:
                self.flush()

    def put_batch(self, items) -> None:
        with self._lock:
            for k, v in items:
                self.wal.append(k, v)
                self.mem.put(k, v)
                self.stats.puts += 1
            if self.fsync:
                self.wal.sync()
            if self.mem.bytes >= self.buffer_bytes:
                self.flush()

    def delete(self, key: bytes) -> None:
        self.put(key, None)

    def get(self, key: bytes):
        """(found, value). Tombstones report found=False."""
        with self._lock:
            self.stats.gets += 1
            found, v = self.mem.get(key)
            if found:
                if v is None:
                    return False, None
                self.stats.get_hits += 1
                return True, v
            for lv in self.levels:
                for run in lv.runs:  # newest first
                    if key < run.meta.min_key or key > run.meta.max_key:
                        continue
                    if key not in run.reader.bloom:
                        self.stats.bloom_negative += 1
                        continue
                    found, v = run.reader.get(key)
                    if found:
                        if v is None:
                            return False, None
                        self.stats.get_hits += 1
                        return True, v
            return False, None

    def range(self, start: bytes, end: bytes) -> Iterator:
        """Merged scan over memtable + all runs, newest shadows oldest,
        tombstones suppressed.  Materialized under the tree lock — a lazy
        generator would hold references into runs a concurrent compaction
        may close; index entries are small pointer records, so the eager
        list is cheap."""
        with self._lock:
            self.stats.range_scans += 1
            sources = [(0, self.mem.range(start, end))]  # priority 0 = newest
            pri = 1
            for lv in self.levels:
                for run in lv.runs:
                    if not (run.meta.max_key < start or run.meta.min_key >= end):
                        sources.append((pri, run.reader.range(start, end)))
                    pri += 1

            heap: List = []
            for prio, it in sources:
                try:
                    k, v = next(it)
                    heap.append((k, prio, v, it))
                except StopIteration:
                    pass
            heapq.heapify(heap)
            last_key: Optional[bytes] = None
            out: List[Tuple[bytes, bytes]] = []
            while heap:
                k, prio, v, it = heapq.heappop(heap)
                if k != last_key:
                    last_key = k
                    if v is not None:
                        out.append((k, v))
                try:
                    nk, nv = next(it)
                    heapq.heappush(heap, (nk, prio, nv, it))
                except StopIteration:
                    pass
        return iter(out)

    # ----------------------------------------------------------------- tuning
    def set_targets(self, T: int, K: int) -> None:
        """Lazy transition entry point: adopted per level at its next
        compaction (App. C)."""
        with self._lock:
            self.target_T = max(2, T)
            self.target_K = max(1, min(K, self.target_T - 1))

    def level_params(self) -> List[Tuple[int, int]]:
        with self._lock:
            return [(lv.T, lv.K) for lv in self.levels]

    # ------------------------------------------------------------ flush/merge
    def _new_run_path(self) -> str:
        self._run_no += 1
        return os.path.join(self.root, f"run_{self._run_no:08d}.sst")

    def flush(self) -> None:
        with self._lock:
            self._flush_locked()

    def _flush_locked(self) -> None:
        if not len(self.mem):
            return
        w = SSTWriter(self._new_run_path(), self.block_bytes, self.bloom_bits_per_key)
        for k, v in self.mem.items():
            w.add(k, v)
        meta = w.finish()
        self._seq += 1
        meta.seq = self._seq
        if not self.levels:
            self.levels.append(_Level(T=self.target_T, K=self.target_K))
        self.levels[0].runs.insert(0, _Run(meta, SSTReader(meta.path)))
        self.mem.clear()
        self.wal.close()
        os.remove(self._wal_path)
        self.wal = WAL(self._wal_path)
        self.stats.flushes += 1
        self._install_manifest()
        self.maybe_compact()

    def _capacity(self, level_idx: int) -> int:
        cap = self.buffer_bytes
        for i in range(level_idx + 1):
            T = self.levels[i].T if i < len(self.levels) else self.target_T
            cap *= T
        return cap

    def _violation(self, i: int) -> bool:
        lv = self.levels[i]
        is_last = i == len(self.levels) - 1
        if len(lv.runs) > lv.K:
            return True
        if not is_last and lv.bytes > self._capacity(i):
            return True
        # last level: merge only on run-count overflow (it may grow in bytes)
        return False

    def maybe_compact(self, max_steps: int = 64) -> int:
        """Run up to ``max_steps`` single-level compactions; returns count."""
        with self._lock:
            steps = 0
            while steps < max_steps:
                victim = None
                for i in range(len(self.levels)):
                    if self._violation(i):
                        victim = i
                        break
                if victim is None:
                    return steps
                self._compact_level(victim)
                steps += 1
            return steps

    def _merge_runs(self, runs: List[_Run], drop_tombstones: bool) -> Optional[RunMeta]:
        w = SSTWriter(self._new_run_path(), self.block_bytes, self.bloom_bits_per_key)
        heap: List = []
        for prio, run in enumerate(runs):  # newest first
            it = run.reader.items()
            try:
                k, v = next(it)
                heap.append((k, prio, v, it))
            except StopIteration:
                pass
        heapq.heapify(heap)
        last_key = None
        wrote = 0
        while heap:
            k, prio, v, it = heapq.heappop(heap)
            if k != last_key:
                last_key = k
                if v is not None or not drop_tombstones:
                    w.add(k, v)
                    wrote += 1
            try:
                nk, nv = next(it)
                heapq.heappush(heap, (nk, prio, nv, it))
            except StopIteration:
                pass
        meta = w.finish()
        if wrote == 0:
            os.remove(meta.path)
            return None
        return meta

    def _compact_level(self, i: int) -> None:
        lv = self.levels[i]
        runs = lv.runs
        bytes_in = sum(r.meta.data_bytes for r in runs)
        is_last = i == len(self.levels) - 1
        merged = self._merge_runs(runs, drop_tombstones=is_last)
        # lazy adoption of target parameters at this level (App. C)
        lv.T, lv.K = self.target_T, self.target_K
        for r in runs:
            r.reader.close()
        old_paths = [r.meta.path for r in runs]
        lv.runs = []
        if merged is not None:
            self._seq += 1
            merged.seq = self._seq
            dest = i
            if not is_last and merged.data_bytes > self._capacity(i):
                dest = i + 1
            elif is_last and merged.data_bytes > self._capacity(i):
                dest = i + 1  # grow the tree by one level
            if dest >= len(self.levels):
                self.levels.append(_Level(T=self.target_T, K=self.target_K))
            self.levels[dest].runs.insert(0, _Run(merged, SSTReader(merged.path)))
            self.stats.compact_bytes_out += merged.data_bytes
        self.stats.compactions += 1
        self.stats.compact_bytes_in += bytes_in
        self._install_manifest()
        for p in old_paths:
            try:
                os.remove(p)
            except OSError:
                pass

    def compact_all(self) -> None:
        while self.maybe_compact(max_steps=1):
            pass

    # ------------------------------------------------------------------ misc
    @property
    def n_entries(self) -> int:
        with self._lock:
            return len(self.mem) + sum(r.meta.entries for lv in self.levels for r in lv.runs)

    @property
    def disk_bytes(self) -> int:
        with self._lock:
            return sum(r.meta.data_bytes for lv in self.levels for r in lv.runs)

    @property
    def n_runs(self) -> int:
        with self._lock:
            return sum(len(lv.runs) for lv in self.levels)

    def close(self) -> None:
        with self._lock:
            self.wal.sync()
            self.wal.close()
            for lv in self.levels:
                for r in lv.runs:
                    r.reader.close()
