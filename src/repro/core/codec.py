"""Batch codec (paper §3.4 'Batch Codec Operations'): serialize + compress
whole KV-cache tensor blocks before they enter the tensor log.

Codecs:
  raw      — numpy bytes, no compression
  zlib     — lossless deflate over the raw bytes
  int8     — per-channel symmetric int8 quantization (the 50–75 % storage
             reduction the paper cites) + optional zlib over the packed ints
The int8 path mirrors ``repro.kernels.kv_codec`` (the Pallas device-side
kernel); this module is the host-side reference used by the storage engine
and is bit-identical to the kernel's oracle.

Payload layout (self-describing: decode never needs an external tag, so a
payload can travel from disk over the wire and be decoded anywhere)::

    u8 codec | u8 zlibbed | u16 ndim | u32 dims... | u8 dtype_code |
    [int8: f32 scales over last axis] | body

Malformed payloads (unknown codec/dtype codes, truncated headers or
bodies, corrupt deflate streams) raise ``CodecError`` — a ``ValueError``
subclass so existing record-level error handling (the cluster protocol's
decode guards) keeps catching it, but typed so callers can distinguish
codec corruption from programming errors.

``transcode`` is the tier-demotion primitive (see ``core.tiering``): it
re-encodes a payload to a target codec without a decode round-trip when
only the zlib layer differs — int8 → int8+zlib is bit-stable, never
re-quantized.
"""

from __future__ import annotations

import struct
import zlib
from typing import Optional, Tuple

import numpy as np


class CodecError(ValueError):
    """A payload that cannot be decoded: unknown codec/dtype code,
    truncated header or body, or a corrupt compressed stream."""


CODEC_RAW = 0
CODEC_INT8 = 1
_CODECS = (CODEC_RAW, CODEC_INT8)

# bfloat16 is not a stock numpy dtype: ``np.dtype("bfloat16")`` only works
# once ml_dtypes (shipped with jax) has registered it.  Probe by
# construction — a plain ``hasattr(np, "bfloat16")`` is False even when the
# dtype *is* registered, so it can't tell the two worlds apart.
try:  # ml_dtypes provides bfloat16 for numpy under jax
    import ml_dtypes

    _BFLOAT16: Optional[np.dtype] = np.dtype(ml_dtypes.bfloat16)
except Exception:  # pragma: no cover — ml_dtypes ships with jax here
    try:
        _BFLOAT16 = np.dtype("bfloat16")
    except TypeError:
        _BFLOAT16 = None

HAVE_BFLOAT16 = _BFLOAT16 is not None

_DTYPES = {
    0: np.dtype("float32"),
    1: np.dtype("float16"),
    2: _BFLOAT16,  # None when unavailable: decode raises CodecError
    3: np.dtype("int8"),
}
_DTYPE_CODES = {v: k for k, v in _DTYPES.items() if v is not None}

_HDR = struct.Struct("<BBH")
_U32 = struct.Struct("<I")
# sanity bound on ndim: a corrupt u16 of 65535 would otherwise demand a
# 256 KiB dims header before any other check could fire
_MAX_NDIM = 16


def quantize_int8(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-channel (last axis) symmetric int8 quantization."""
    xf = x.astype(np.float32)
    absmax = np.max(np.abs(xf), axis=tuple(range(xf.ndim - 1)), keepdims=True)
    scale = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(xf / scale), -127, 127).astype(np.int8)
    return q, scale.reshape(-1)


def dequantize_int8(q: np.ndarray, scale: np.ndarray, dtype) -> np.ndarray:
    return (q.astype(np.float32) * scale.reshape((1,) * (q.ndim - 1) + (-1,))).astype(dtype)


def header_info(raw) -> Tuple[int, bool, Tuple[int, ...], int]:
    """Parse just the payload header: ``(codec, zlibbed, shape, dtype_code)``.
    Cheap (no body decode) — the tier recoder uses it to decide whether a
    record is already at its target encoding.  Raises ``CodecError`` on a
    malformed header."""
    mv = memoryview(raw)
    if len(mv) < _HDR.size:
        raise CodecError(f"payload truncated: {len(mv)} bytes, header needs {_HDR.size}")
    codec, zl, ndim = _HDR.unpack_from(mv)
    if codec not in _CODECS:
        raise CodecError(f"unknown codec code {codec}")
    if zl not in (0, 1):
        raise CodecError(f"bad zlib flag {zl}")
    if ndim == 0 or ndim > _MAX_NDIM:
        raise CodecError(f"bad ndim {ndim} (must be 1..{_MAX_NDIM})")
    need = _HDR.size + 4 * ndim + 1
    if len(mv) < need:
        raise CodecError(f"payload truncated: {len(mv)} bytes, dims header needs {need}")
    shape = struct.unpack_from(f"<{ndim}I", mv, _HDR.size)
    (dt_code,) = struct.unpack_from("<B", mv, _HDR.size + 4 * ndim)
    if dt_code not in _DTYPES:
        raise CodecError(f"unknown dtype code {dt_code}")
    return codec, bool(zl), shape, dt_code


def _dtype_for(dt_code: int) -> np.dtype:
    dtype = _DTYPES[dt_code]
    if dtype is None:
        raise CodecError(
            "payload encoded as bfloat16 but this host has no bfloat16 "
            "dtype (ml_dtypes is not importable)"
        )
    return dtype


def _split(raw) -> Tuple[int, bool, Tuple[int, ...], int, "memoryview"]:
    """Header fields + a view of the (possibly compressed) body."""
    codec, zl, shape, dt_code = header_info(raw)
    pos = _HDR.size + 4 * len(shape) + 1
    return codec, zl, shape, dt_code, memoryview(raw)[pos:]


class BatchCodec:
    def __init__(self, codec: int = CODEC_INT8, use_zlib: bool = True, zlib_level: int = 1):
        if codec not in _CODECS:
            raise CodecError(f"unknown codec code {codec}")
        self.codec = codec
        self.use_zlib = bool(use_zlib)
        self.zlib_level = zlib_level

    def __repr__(self) -> str:
        name = "int8" if self.codec == CODEC_INT8 else "raw"
        return f"BatchCodec({name}{'+zlib' if self.use_zlib else ''})"

    def encode(self, x: np.ndarray) -> bytes:
        x = np.ascontiguousarray(x)
        try:
            dt_code = _DTYPE_CODES[np.dtype(x.dtype)]
        except KeyError:
            raise CodecError(f"unsupported dtype {x.dtype}") from None
        if x.ndim == 0 or x.ndim > _MAX_NDIM:
            raise CodecError(f"unsupported ndim {x.ndim} (must be 1..{_MAX_NDIM})")
        hdr = _HDR.pack(self.codec, int(self.use_zlib), x.ndim)
        hdr += struct.pack(f"<{x.ndim}I", *x.shape)
        hdr += struct.pack("<B", dt_code)
        if self.codec == CODEC_INT8:
            q, scale = quantize_int8(x)
            body = scale.astype("<f4").tobytes() + q.tobytes()
        else:
            body = x.tobytes()
        if self.use_zlib:
            body = zlib.compress(body, self.zlib_level)
        return hdr + body

    @staticmethod
    def decode(raw) -> np.ndarray:
        """``raw`` may be bytes or a zero-copy memoryview (the tensor-log
        batch read path hands out views into one coalesced read).  Raises
        ``CodecError`` on any malformed payload."""
        codec, zl, shape, dt_code, body = _split(raw)
        dtype = _dtype_for(dt_code)
        if zl:
            try:
                body = zlib.decompress(body)
            except zlib.error as e:
                raise CodecError(f"corrupt zlib body: {e}") from e
        n = 1
        for d in shape:
            n *= d
        if codec == CODEC_INT8:
            c = shape[-1]
            if len(body) != 4 * c + n:
                raise CodecError(
                    f"int8 body is {len(body)} bytes, expected {4 * c + n} "
                    f"for shape {shape}"
                )
            scale = np.frombuffer(body[: 4 * c], dtype="<f4")
            q = np.frombuffer(body[4 * c:], dtype=np.int8).reshape(shape)
            return dequantize_int8(q, scale, dtype)
        if len(body) != n * dtype.itemsize:
            raise CodecError(
                f"raw body is {len(body)} bytes, expected {n * dtype.itemsize} "
                f"for shape {shape} dtype {dtype}"
            )
        return np.frombuffer(body, dtype=dtype).reshape(shape).copy()

    def compression_ratio(self, x: np.ndarray) -> float:
        return x.nbytes / max(1, len(self.encode(x)))


def transcode(raw, target: "BatchCodec") -> Optional[bytes]:
    """Re-encode a payload to ``target``'s encoding; ``None`` when the
    payload is already there.  When only the zlib layer differs the body
    is recompressed verbatim — an int8 → int8+zlib demotion is bit-stable
    (never re-quantized, so repeated demotions cannot accumulate error).
    A codec change (raw → int8) decodes and re-encodes."""
    codec, zl, shape, dt_code, body = _split(raw)
    if codec == target.codec:
        if zl == target.use_zlib:
            return None
        if zl:
            try:
                body = zlib.decompress(body)
            except zlib.error as e:
                raise CodecError(f"corrupt zlib body: {e}") from e
        else:
            body = zlib.compress(body, target.zlib_level)
        hdr = _HDR.pack(codec, int(target.use_zlib), len(shape))
        hdr += struct.pack(f"<{len(shape)}I", *shape)
        hdr += struct.pack("<B", dt_code)
        return hdr + bytes(body)
    return target.encode(BatchCodec.decode(raw))
