"""Batch codec (paper §3.4 'Batch Codec Operations'): serialize + compress
whole KV-cache tensor blocks before they enter the tensor log.

Codecs:
  raw      — numpy bytes, no compression
  zlib     — lossless deflate over the raw bytes
  int8     — per-channel symmetric int8 quantization (the 50–75 % storage
             reduction the paper cites) + optional zlib over the packed ints
The int8 path mirrors ``repro.kernels.kv_codec`` (the Pallas device-side
kernel); this module is the host-side reference used by the storage engine
and is bit-identical to the kernel's oracle.

Payload layout::

    u8 codec | u8 zlibbed | u16 ndim | u32 dims... | u8 dtype_code |
    [int8: f32 scales over last axis] | body
"""

from __future__ import annotations

import struct
import zlib
from typing import Tuple

import numpy as np

CODEC_RAW = 0
CODEC_INT8 = 1

_DTYPES = {0: np.dtype("float32"), 1: np.dtype("float16"), 2: np.dtype("bfloat16") if hasattr(np, "bfloat16") else None, 3: np.dtype("int8")}
try:  # ml_dtypes provides bfloat16 for numpy under jax
    import ml_dtypes

    _DTYPES[2] = np.dtype(ml_dtypes.bfloat16)
except Exception:  # pragma: no cover
    pass
_DTYPE_CODES = {v: k for k, v in _DTYPES.items() if v is not None}


def quantize_int8(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-channel (last axis) symmetric int8 quantization."""
    xf = x.astype(np.float32)
    absmax = np.max(np.abs(xf), axis=tuple(range(xf.ndim - 1)), keepdims=True)
    scale = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(xf / scale), -127, 127).astype(np.int8)
    return q, scale.reshape(-1)


def dequantize_int8(q: np.ndarray, scale: np.ndarray, dtype) -> np.ndarray:
    return (q.astype(np.float32) * scale.reshape((1,) * (q.ndim - 1) + (-1,))).astype(dtype)


class BatchCodec:
    def __init__(self, codec: int = CODEC_INT8, use_zlib: bool = True, zlib_level: int = 1):
        self.codec = codec
        self.use_zlib = use_zlib
        self.zlib_level = zlib_level

    def encode(self, x: np.ndarray) -> bytes:
        x = np.ascontiguousarray(x)
        dt_code = _DTYPE_CODES[np.dtype(x.dtype)]
        hdr = struct.pack("<BBH", self.codec, int(self.use_zlib), x.ndim)
        hdr += struct.pack(f"<{x.ndim}I", *x.shape)
        hdr += struct.pack("<B", dt_code)
        if self.codec == CODEC_INT8:
            q, scale = quantize_int8(x)
            body = scale.astype("<f4").tobytes() + q.tobytes()
        else:
            body = x.tobytes()
        if self.use_zlib:
            body = zlib.compress(body, self.zlib_level)
        return hdr + body

    @staticmethod
    def decode(raw) -> np.ndarray:
        """``raw`` may be bytes or a zero-copy memoryview (the tensor-log
        batch read path hands out views into one coalesced read)."""
        codec, zl, ndim = struct.unpack_from("<BBH", raw)
        pos = 4
        shape = struct.unpack_from(f"<{ndim}I", raw, pos)
        pos += 4 * ndim
        (dt_code,) = struct.unpack_from("<B", raw, pos)
        pos += 1
        dtype = _DTYPES[dt_code]
        body = memoryview(raw)[pos:]
        if zl:
            body = zlib.decompress(body)
        if codec == CODEC_INT8:
            c = shape[-1]
            scale = np.frombuffer(body[: 4 * c], dtype="<f4")
            q = np.frombuffer(body[4 * c :], dtype=np.int8).reshape(shape)
            return dequantize_int8(q, scale, dtype)
        return np.frombuffer(body, dtype=dtype).reshape(shape).copy()

    def compression_ratio(self, x: np.ndarray) -> float:
        return x.nbytes / max(1, len(self.encode(x)))
