"""Adaptive compression tiers (ROADMAP item 1; paper §3.4): codec choice
as a per-block storage *policy* rather than a store-wide constant.

Blocks are written raw (**hot** — the put path pays zero codec CPU), then
demoted by the off-path maintenance cycle as they cool: **warm** blocks are
re-encoded int8 (per-channel symmetric quantization, ~4x), **cold** blocks
int8+zlib.  Recency comes from bookkeeping the tensor log already keeps —
each log file's last-access time — so the policy costs the hot path
nothing.  Demotion rides the same mechanics as tensor-file merging: scan a
sealed victim file, transcode live records, re-append them to the active
log, repoint the index, remove the victim.  Lock-free readers that lose
the race see ``FileNotFoundError`` and re-resolve from the index, exactly
as for merge/eviction (see ``core.tensorlog``).

The tier tag lives in the index entry's flags byte (``LogPointer(20B) |
u8 flags``, bits 0–1), so per-tier accounting never touches payloads; the
payloads themselves stay self-describing (``core.codec`` header), so
decode anywhere — store, hierarchy fulfill, cluster client — needs no
side channel.

State machine::

    put ──► HOT (raw) ──idle ≥ warm_after_s──► WARM (int8)
                 │                                  │
                 └──────idle ≥ cold_after_s─────────┴──► COLD (int8+zlib)

Demotion only moves down-tier; a re-read does not promote (re-inflating a
block would cost a rewrite for no capacity gain) but it *does* refresh the
file's access time, so files holding traffic stop demoting further.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from .codec import CODEC_INT8, CODEC_RAW, BatchCodec, transcode

TIER_HOT = 0
TIER_WARM = 1
TIER_COLD = 2
TIER_MASK = 0x03  # bits 0-1 of the index-entry flags byte
TIER_NAMES = ("hot", "warm", "cold")

_TIER_CODECS = (
    BatchCodec(CODEC_RAW, use_zlib=False),
    BatchCodec(CODEC_INT8, use_zlib=False),
    BatchCodec(CODEC_INT8, use_zlib=True),
)


def tier_of_codec(codec: BatchCodec) -> int:
    """The tier a static store-wide codec corresponds to, so per-tier
    gauges stay meaningful on stores running without an adaptive policy
    (raw → hot, int8 → warm, int8+zlib → cold)."""
    if codec.codec == CODEC_INT8:
        return TIER_COLD if codec.use_zlib else TIER_WARM
    return TIER_HOT


@dataclass
class TieringPolicy:
    """When to demote: a sealed log file idle for ``warm_after_s`` becomes
    a warm victim, for ``cold_after_s`` a cold victim.  Zero thresholds
    demote at the next maintenance cycle (benchmarks and tests use this
    for deterministic demotion).  ``max_files_per_cycle`` bounds per-cycle
    re-encode work the same way merge bounds its victims."""

    warm_after_s: float = 30.0
    cold_after_s: float = 120.0
    max_files_per_cycle: int = 4
    zlib_level: int = 1

    def __post_init__(self) -> None:
        if self.cold_after_s < self.warm_after_s:
            raise ValueError(
                f"cold_after_s ({self.cold_after_s}) must be >= "
                f"warm_after_s ({self.warm_after_s})"
            )

    def codec_for(self, tier: int) -> BatchCodec:
        c = _TIER_CODECS[tier]
        if tier == TIER_COLD and self.zlib_level != 1:
            return BatchCodec(CODEC_INT8, use_zlib=True, zlib_level=self.zlib_level)
        return c

    def target_tier(self, idle_s: float) -> int:
        if idle_s >= self.cold_after_s:
            return TIER_COLD
        if idle_s >= self.warm_after_s:
            return TIER_WARM
        return TIER_HOT


@dataclass
class TierReport:
    """One recoder cycle, JSON-shaped for the maintenance report."""

    files: int = 0
    demoted_blocks: int = 0
    moved_blocks: int = 0  # live records rewritten (demoted or carried)
    bytes_before: int = 0  # pre-transcode payload bytes of demoted blocks
    bytes_after: int = 0
    transitions: Dict[str, int] = None  # "hot->warm" etc. -> block count

    def __post_init__(self) -> None:
        if self.transitions is None:
            self.transitions = {}

    def as_dict(self) -> dict:
        return {
            "files": self.files,
            "demoted_blocks": self.demoted_blocks,
            "moved_blocks": self.moved_blocks,
            "bytes_before": self.bytes_before,
            "bytes_after": self.bytes_after,
            "transitions": dict(self.transitions),
        }


class TierRecoder:
    """Off-path tier demotion over the tensor log, mirroring
    ``TensorFileMerger``: runs inside the store's maintenance cycle under
    the store mutation lock, never on the put/get path.

    ``entry_codec`` is ``(unpack(v) -> (ptr, flags), pack(ptr, flags) ->
    bytes)`` from the store — the recoder owns no entry-layout knowledge.
    """

    def __init__(
        self,
        log,  # TensorLog
        index,  # LSMTree: key -> packed (ptr | flags) entries
        policy: TieringPolicy,
        entry_codec: Tuple[Callable, Callable],
    ):
        self.log = log
        self.index = index
        self.policy = policy
        self._unpack, self._pack = entry_codec
        # Files whose surviving records are all at (or below) this tier
        # already — skip rescanning them until a colder target applies.
        # File ids are never reused, so stale entries are harmless.
        self._settled: Dict[int, int] = {}

    def _victims(self, now: float) -> List[Tuple[int, int]]:
        """Sealed files due for demotion, oldest-idle first: (fid, target)."""
        ids = self.log.file_ids()
        if len(ids) < 2:
            return []  # only the active file (or empty): nothing sealed
        active = ids[-1]
        out = []
        for fid in ids:
            if fid == active:
                continue
            idle = self.log.idle_s(fid, now)
            target = self.policy.target_tier(idle)
            if target == TIER_HOT or self._settled.get(fid, -1) >= target:
                continue
            out.append((idle, fid, target))
        out.sort(reverse=True)  # most-idle first: coldest data demotes first
        return [(fid, target) for _, fid, target in out[: self.policy.max_files_per_cycle]]

    def needed(self, now: Optional[float] = None) -> bool:
        return bool(self._victims(time.monotonic() if now is None else now))

    def run(self, now: Optional[float] = None) -> TierReport:
        now = time.monotonic() if now is None else now
        rep = TierReport()
        for fid, target in self._victims(now):
            codec = self.policy.codec_for(target)
            moved = []  # (key, payload_bytes, flags)
            demoted = 0
            for ptr, key, payload in self.log.scan_file(fid):
                found, v = self.index.get(key)
                if not found:
                    continue  # evicted/stale: garbage, dropped by the rewrite
                cur_ptr, flags = self._unpack(v)
                if (cur_ptr.file_id, cur_ptr.offset) != (ptr.file_id, ptr.offset):
                    continue  # superseded copy: garbage
                tier = flags & TIER_MASK
                if tier >= target:
                    # already at/below target (e.g. merge carried a cold
                    # record into a young file): carry unchanged
                    moved.append((key, bytes(payload), flags))
                    continue
                new_payload = transcode(payload, codec)
                if new_payload is None:  # payload already target-encoded
                    moved.append((key, bytes(payload), (flags & ~TIER_MASK) | target))
                    continue
                rep.bytes_before += len(payload)
                rep.bytes_after += len(new_payload)
                demoted += 1
                key_t = TIER_NAMES[tier] + "->" + TIER_NAMES[target]
                rep.transitions[key_t] = rep.transitions.get(key_t, 0) + 1
                moved.append((key, new_payload, (flags & ~TIER_MASK) | target))
            if demoted == 0:
                # nothing to transcode: leave the file in place (merge still
                # handles its garbage) and remember it is settled at target
                self._settled[fid] = target
                continue
            if moved:
                # same publish ordering as merge: append, repoint the index,
                # *then* remove the victim — racing lock-free readers retry
                # off the repointed index
                new_ptrs = self.log.append_batch([(k, p) for k, p, _ in moved])
                self.index.put_batch(
                    (k, self._pack(np_, fl)) for (k, _, fl), np_ in zip(moved, new_ptrs)
                )
            self.log.remove_file(fid)
            self._settled.pop(fid, None)
            rep.files += 1
            rep.demoted_blocks += demoted
            rep.moved_blocks += len(moved)
        return rep
