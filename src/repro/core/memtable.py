"""In-memory write buffer of the LSM-tree (paper Fig. 2).

Keeps keys in sorted order (bisect-maintained list) so flushes emit an
already-sorted run and range scans can merge the memtable with on-disk runs.
Tombstones are represented as ``value is None``.
"""

from __future__ import annotations

import bisect
from typing import Iterator, Optional

_MISSING = object()


class MemTable:
    def __init__(self) -> None:
        self._map: dict = {}
        self._keys: list = []  # sorted
        self.bytes = 0  # approximate payload bytes

    def __len__(self) -> int:
        return len(self._map)

    def put(self, key: bytes, value: Optional[bytes]) -> None:
        old = self._map.get(key, _MISSING)
        if old is _MISSING:
            bisect.insort(self._keys, key)
            self.bytes += len(key)
        else:
            self.bytes -= len(old) if old is not None else 0
        self._map[key] = value
        self.bytes += len(value) if value is not None else 0

    def get(self, key: bytes):
        """Returns (found, value).  value None => tombstone."""
        v = self._map.get(key, _MISSING)
        if v is _MISSING:
            return False, None
        return True, v

    def range(self, start: bytes, end: bytes) -> Iterator:
        """Yield (key, value) for start <= key < end, in order (tombstones
        included so the merge layer can shadow older runs)."""
        lo = bisect.bisect_left(self._keys, start)
        hi = bisect.bisect_left(self._keys, end)
        for i in range(lo, hi):
            k = self._keys[i]
            yield k, self._map[k]

    def items(self) -> Iterator:
        for k in self._keys:
            yield k, self._map[k]

    def clear(self) -> None:
        self._map.clear()
        self._keys.clear()
        self.bytes = 0
