"""Prefix-preserving key encoding (paper §3.2).

Token sequences are encoded as fixed-width big-endian ``uint32`` words so
that byte-lexicographic order over encoded keys coincides exactly with
token-prefix order:

  tokens_a is a prefix of tokens_b  <=>  encode(tokens_a) is a byte-prefix
                                         of encode(tokens_b)

and for any two sequences the lexicographic comparison of their encodings
equals the lexicographic comparison of the sequences themselves.  This is
the property the LSM index relies on: all cached blocks of one request sort
adjacently, so ``get_batch`` is a single range scan and compaction keeps
related prefixes physically clustered.

Keys can get long (a 32k-token prefix is 128 KiB); the SST block format
(``sst.py``) applies restart-point prefix compression, so consecutive keys
sharing a long token prefix cost only their suffix on disk.
"""

from __future__ import annotations

import struct
from typing import Sequence

import numpy as np

TOKEN_WIDTH = 4  # bytes per token word
_U32 = struct.Struct(">I")


def encode_tokens(tokens: Sequence[int]) -> bytes:
    """Encode a token-id sequence into an order-preserving byte key.

    Vectorized: key construction sits on the probe/scan hot path (a probe
    of an L-token prompt encodes O(log L) prefixes of up to L tokens), and
    the per-token ``struct.pack`` loop dominated read-side CPU profiles.
    """
    try:
        arr = np.asarray(tokens, dtype=">u4")
        # older numpy wraps out-of-range list ints silently: verify
        if arr.size and not np.array_equal(
            arr.astype(np.int64), np.asarray(tokens, dtype=np.int64)
        ):
            raise ValueError("token id out of range for key encoding")
    except (OverflowError, TypeError, ValueError) as e:
        raise ValueError(f"token id out of range for key encoding: {e}") from e
    if arr.ndim != 1:
        raise ValueError("token sequence must be one-dimensional")
    return arr.tobytes()


def decode_tokens(key: bytes) -> tuple:
    """Inverse of :func:`encode_tokens`."""
    if len(key) % TOKEN_WIDTH:
        raise ValueError(f"key length {len(key)} not a multiple of {TOKEN_WIDTH}")
    return tuple(_U32.unpack_from(key, i)[0] for i in range(0, len(key), TOKEN_WIDTH))


def key_token_len(key: bytes) -> int:
    return len(key) // TOKEN_WIDTH


def block_key(tokens: Sequence[int], block_size: int, block_idx: int) -> bytes:
    """Key for the ``block_idx``-th KV block: the whole prefix up to and
    including that block.  Using the *full* prefix (not just the block's own
    tokens) is what makes lookups content-addressed: two requests sharing a
    prefix produce identical keys regardless of what follows."""
    end = (block_idx + 1) * block_size
    if end > len(tokens):
        raise ValueError("block extends past token sequence")
    return encode_tokens(tokens[:end])


def shared_prefix_len(a: bytes, b: bytes) -> int:
    """Longest common byte prefix (for SST prefix compression)."""
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


def successor(key: bytes):
    """Smallest key strictly greater than every key having ``key`` as a
    prefix (an exclusive range-scan upper bound).  Returns ``None`` when no
    finite successor exists (empty or all-0xFF keys): callers treat that as
    an unbounded scan."""
    b = bytearray(key)
    while b:
        if b[-1] != 0xFF:
            b[-1] += 1
            return bytes(b)
        b.pop()
    return None
