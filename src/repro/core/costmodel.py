"""Analytic LSM I/O cost model (paper §2.2 / §3.3).

Costs are expressed in expected block I/Os per operation:

  update       W(T, K) = T·L / (B·K)          (amortized, out-of-place)
  point hit    R(T, K) = K·L·p + 1
  point miss   Z(T, K) = K·L·p                (Bloom-pruned empty probe)
  range scan   S(T, K) = K·L + d/B            (seek every run + stream d)

with L = ceil(log_T(N·e / M)) levels, B entries per block, p the Bloom
false-positive rate.  The adaptive controller minimizes the workload-
weighted sum  w·W + s·S + r·R + z·Z  over the (T, K) design space.
"""

from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass
class TreeShape:
    n_entries: int  # N
    entry_bytes: int  # e
    buffer_bytes: int  # M
    block_bytes: int = 4096
    bloom_fpr: float = 0.01  # p

    @property
    def entries_per_block(self) -> float:
        return max(1.0, self.block_bytes / max(1, self.entry_bytes))

    def levels(self, T: int) -> int:
        data = max(1, self.n_entries * self.entry_bytes)
        if data <= self.buffer_bytes:
            return 1
        return max(1, math.ceil(math.log(data / self.buffer_bytes, T)))


def cost_terms(shape: TreeShape, T: int, K: int, avg_range_entries: float = 8.0):
    L = shape.levels(T)
    B = shape.entries_per_block
    p = shape.bloom_fpr
    W = T * L / (B * K)
    R = K * L * p + 1.0
    Z = K * L * p
    S = K * L + avg_range_entries / B
    return {"W": W, "R": R, "Z": Z, "S": S, "L": L}


def weighted_cost(shape: TreeShape, T: int, K: int, w: float, s: float, r: float, z: float,
                  avg_range_entries: float = 8.0) -> float:
    t = cost_terms(shape, T, K, avg_range_entries)
    return w * t["W"] + s * t["S"] + r * t["R"] + z * t["Z"]


def optimize(shape: TreeShape, w: float, s: float, r: float, z: float,
             t_max: int = 16, avg_range_entries: float = 8.0):
    """Enumerate the (T, K) design space (paper §3.3: 'iterating over
    different values of the size ratio T and the runs parameter K')."""
    total = max(1e-12, w + s + r + z)
    w, s, r, z = w / total, s / total, r / total, z / total
    best = None
    for T in range(2, t_max + 1):
        for K in range(1, T):  # K=1 leveling ... K=T-1 tiering
            c = weighted_cost(shape, T, K, w, s, r, z, avg_range_entries)
            if best is None or c < best[0]:
                best = (c, T, K)
    return {"cost": best[0], "T": best[1], "K": best[2]}
