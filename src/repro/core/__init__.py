"""SGLANG-LSM core: prefix-preserving LSM storage engine for KV cache
(paper §3), plus the baseline backends it is evaluated against."""

from .backend import StorageBackend, merge_stats
from .baselines import FilePerObjectStore, MemoryOnlyStore
from .codec import CODEC_INT8, CODEC_RAW, BatchCodec
from .controller import AdaptiveController
from .costmodel import TreeShape, cost_terms, optimize, weighted_cost
from .keycodec import block_key, decode_tokens, encode_tokens
from .lsm import LSMTree
from .sharded_store import ShardedKVBlockStore, shard_of
from .store import KVBlockStore, StoreStats

__all__ = [
    "StorageBackend",
    "merge_stats",
    "StoreStats",
    "KVBlockStore",
    "ShardedKVBlockStore",
    "shard_of",
    "FilePerObjectStore",
    "MemoryOnlyStore",
    "LSMTree",
    "AdaptiveController",
    "BatchCodec",
    "CODEC_INT8",
    "CODEC_RAW",
    "TreeShape",
    "cost_terms",
    "weighted_cost",
    "optimize",
    "encode_tokens",
    "decode_tokens",
    "block_key",
]
