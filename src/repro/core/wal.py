"""Write-ahead log + versioned manifest: crash consistency for the LSM index
(paper §3.2's two-phase write protocol relies on the index insert being the
atomic commit point; the WAL makes that insert durable, and the manifest
makes structural changes — flushes, compactions, log merges — atomic).

WAL record::

    u32 crc | u32 klen | u32 vlen(or TOMBSTONE) | key | value

Manifest: JSON written to ``MANIFEST-<n>`` then atomically pointed at by a
``CURRENT`` file (write-temp + rename).  Recovery = read CURRENT, load
manifest, replay WAL into a fresh memtable.
"""

from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Iterator, List, Optional, Tuple

_HDR = struct.Struct("<III")
_TOMB = 0xFFFFFFFF


class WAL:
    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "ab")

    def append(self, key: bytes, value: Optional[bytes]) -> None:
        vlen = _TOMB if value is None else len(value)
        body = key + (value or b"")
        crc = zlib.crc32(body) & 0xFFFFFFFF
        self._f.write(_HDR.pack(crc, len(key), vlen) + body)

    def sync(self) -> None:
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        self._f.close()

    @staticmethod
    def replay(path: str) -> Iterator:
        """Yield (key, value) records; stops at first torn/corrupt record
        (crash semantics: a torn tail is discarded, not an error)."""
        if not os.path.exists(path):
            return
        with open(path, "rb") as f:
            raw = f.read()
        pos = 0
        n = len(raw)
        while pos + _HDR.size <= n:
            crc, klen, vlen = _HDR.unpack_from(raw, pos)
            pos2 = pos + _HDR.size
            vl = 0 if vlen == _TOMB else vlen
            if pos2 + klen + vl > n:
                return  # torn tail
            body = raw[pos2 : pos2 + klen + vl]
            if zlib.crc32(body) & 0xFFFFFFFF != crc:
                return  # corrupt tail
            key = body[:klen]
            value = None if vlen == _TOMB else body[klen:]
            yield key, value
            pos = pos2 + klen + vl


class ManifestStore:
    """Versioned manifest with atomic install."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._next = self._scan_next()

    def _scan_next(self) -> int:
        mx = 0
        for name in os.listdir(self.root):
            if name.startswith("MANIFEST-"):
                try:
                    mx = max(mx, int(name.split("-")[1]))
                except ValueError:
                    pass
        return mx + 1

    def load(self) -> Optional[dict]:
        cur = os.path.join(self.root, "CURRENT")
        if not os.path.exists(cur):
            return None
        with open(cur) as f:
            name = f.read().strip()
        path = os.path.join(self.root, name)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def install(self, state: dict) -> None:
        name = f"MANIFEST-{self._next}"
        self._next += 1
        path = os.path.join(self.root, name)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, path)
        cur_tmp = os.path.join(self.root, "CURRENT.tmp")
        with open(cur_tmp, "w") as f:
            f.write(name)
            f.flush()
            os.fsync(f.fileno())
        os.rename(cur_tmp, os.path.join(self.root, "CURRENT"))
        # GC old manifests (keep last 3)
        manifests = sorted(
            (n for n in os.listdir(self.root) if n.startswith("MANIFEST-") and not n.endswith(".tmp")),
            key=lambda n: int(n.split("-")[1]),
        )
        for old in manifests[:-3]:
            try:
                os.remove(os.path.join(self.root, old))
            except OSError:
                pass
