"""Tensor log: the value side of key-value separation (paper §3.2,
WiscKey-style).  Large immutable KV-cache payloads are appended to
sequential log files; the LSM index stores only ``(file_id, offset,
length)`` pointers.  Compaction of the index never touches these files,
bounding write amplification.

Record layout (self-describing so the merge service can relocate records
without consulting the index)::

    u32 crc | u32 klen | u32 plen | key | payload

Batch reads coalesce adjacent ``(file, offset)`` ranges into single
sequential reads — this is the mechanism that converts the file-per-object
random-I/O pattern into sequential I/O (paper App. B, Get Batch).

Concurrency: appends, file removal, and the size/liveness bookkeeping are
serialized by an internal lock; **reads take no lock at all**.  Log records
are immutable once their pointer is published (append flushes before the
index insert that publishes the pointer), file ids are never reused, and
readers open their own file handles — so the only read/write race is a
reader holding a pointer into a file that eviction or the merge service
just removed, which surfaces as ``FileNotFoundError`` and is handled by
the store's read-retry loop (re-resolve pointers from the index).
"""

from __future__ import annotations

import os
import struct
import threading
import time
import zlib
from dataclasses import dataclass
from typing import Dict, Iterator, List, Sequence, Tuple

_HDR = struct.Struct("<III")


@dataclass(frozen=True)
class LogExtent:
    """A run of whole records that is byte-contiguous in one log file —
    the unit the cluster server can ``os.sendfile`` straight into a
    socket.  ``record_lengths`` preserves the per-record boundaries so
    the sender can split the extent at record granularity."""

    path: str
    offset: int
    length: int
    record_lengths: Tuple[int, ...]


@dataclass(frozen=True)
class LogPointer:
    file_id: int
    offset: int
    length: int  # full record length (header + key + payload)

    def pack(self) -> bytes:
        return struct.pack("<QQI", self.file_id, self.offset, self.length)

    @classmethod
    def unpack(cls, raw: bytes) -> "LogPointer":
        f, o, l = struct.unpack_from("<QQI", raw)
        return cls(f, o, l)

PTR_BYTES = struct.calcsize("<QQI")


class TensorLog:
    def __init__(self, root: str, max_file_bytes: int = 64 * 1024 * 1024, fsync_writes: bool = False):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.max_file_bytes = max_file_bytes
        self.fsync_writes = fsync_writes
        self._lock = threading.RLock()  # guards appends + bookkeeping; reads are lock-free
        self._files: Dict[int, dict] = {}  # id -> {size, live, path, atime}
        self._active_id = -1
        self._active_f = None
        self.seq_reads = 0
        self._recover()

    # -- bookkeeping ---------------------------------------------------------
    def _path(self, file_id: int) -> str:
        return os.path.join(self.root, f"vlog_{file_id:08d}.bin")

    def _recover(self) -> None:
        ids = []
        for name in os.listdir(self.root):
            if name.startswith("vlog_") and name.endswith(".bin"):
                fid = int(name[5:-4])
                ids.append(fid)
                size = os.path.getsize(self._path(fid))
                self._files[fid] = {"size": size, "live": size,
                                    "path": self._path(fid),
                                    "atime": time.monotonic()}
        self._active_id = max(ids) if ids else -1

    def _open_active(self) -> None:
        if self._active_f is None or self._files.get(self._active_id, {}).get("size", 0) >= self.max_file_bytes:
            if self._active_f is not None:
                self._active_f.close()
            self._active_id += 1
            self._files[self._active_id] = {"size": 0, "live": 0,
                                            "path": self._path(self._active_id),
                                            "atime": time.monotonic()}
            self._active_f = open(self._path(self._active_id), "ab")

    @property
    def file_count(self) -> int:
        with self._lock:
            return len(self._files)

    @property
    def total_bytes(self) -> int:
        with self._lock:
            return sum(f["size"] for f in self._files.values())

    def garbage_ratio(self, file_id: int) -> float:
        with self._lock:
            f = self._files[file_id]
            return 1.0 - (f["live"] / f["size"]) if f["size"] else 0.0

    def file_ids(self) -> List[int]:
        with self._lock:
            return sorted(self._files)

    # -- access recency (tier policy input) ----------------------------------
    def touch(self, file_id: int) -> None:
        """Refresh a file's last-access time.  Lock-free by design: called
        from the read path, where a single dict-slot assignment is safe
        under CPython and an occasionally-lost update only ages a file a
        little early — the tier policy tolerates that."""
        f = self._files.get(file_id)
        if f is not None:
            f["atime"] = time.monotonic()

    def idle_s(self, file_id: int, now: float = None) -> float:
        """Seconds since the file was last appended to or read from — the
        access-recency signal ``core.tiering`` demotes on."""
        f = self._files.get(file_id)
        if f is None:
            return 0.0
        return (time.monotonic() if now is None else now) - f["atime"]

    # -- writes --------------------------------------------------------------
    def append(self, key: bytes, payload: bytes) -> LogPointer:
        return self.append_batch([(key, payload)])[0]

    def append_batch(self, records: Sequence[Tuple[bytes, bytes]]) -> List[LogPointer]:
        """Append records contiguously; one write syscall for the batch.
        Serialized by the log lock; the flush before return makes every
        returned pointer immediately readable by lock-free readers."""
        with self._lock:
            return self._append_batch_locked(records)

    def _append_batch_locked(self, records: Sequence[Tuple[bytes, bytes]]) -> List[LogPointer]:
        self._open_active()
        finfo = self._files[self._active_id]
        base = finfo["size"]
        buf = bytearray()
        ptrs: List[LogPointer] = []
        for key, payload in records:
            body = key + payload
            crc = zlib.crc32(body) & 0xFFFFFFFF
            rec = _HDR.pack(crc, len(key), len(payload)) + body
            ptrs.append(LogPointer(self._active_id, base + len(buf), len(rec)))
            buf += rec
        self._active_f.write(buf)
        self._active_f.flush()  # readers use separate handles
        if self.fsync_writes:
            os.fsync(self._active_f.fileno())
        finfo["size"] += len(buf)
        finfo["live"] += len(buf)
        finfo["atime"] = time.monotonic()
        return ptrs

    def mark_dead(self, ptr: LogPointer) -> None:
        with self._lock:
            f = self._files.get(ptr.file_id)
            if f is not None:
                f["live"] = max(0, f["live"] - ptr.length)

    # -- reads ---------------------------------------------------------------
    def read(self, ptr: LogPointer) -> Tuple[bytes, bytes]:
        with open(self._path(ptr.file_id), "rb") as f:
            f.seek(ptr.offset)
            raw = f.read(ptr.length)
        self.touch(ptr.file_id)
        return self._parse(raw, ptr)

    @staticmethod
    def _parse(raw, ptr: LogPointer) -> Tuple[bytes, "memoryview"]:
        """Parse one record.  ``raw`` may be bytes or a memoryview into a
        larger read; the returned payload is a zero-copy view — per-block
        GIL-held memcpys were a measurable serial fraction of batch reads.
        CRC runs over the view (crc32 releases the GIL on large buffers)."""
        crc, klen, plen = _HDR.unpack_from(raw)
        body = memoryview(raw)[_HDR.size : _HDR.size + klen + plen]
        if zlib.crc32(body) & 0xFFFFFFFF != crc:
            raise IOError(f"tensor-log CRC mismatch at {ptr}")
        return bytes(body[:klen]), body[klen:]

    def read_batch(self, ptrs: Sequence[LogPointer]) -> List[Tuple[bytes, bytes]]:
        """Coalescing batch read: pointers are grouped per file, sorted by
        offset, and adjacent/overlapping ranges are fetched with a single
        sequential read."""
        by_file: Dict[int, List[Tuple[int, LogPointer]]] = {}
        for i, p in enumerate(ptrs):
            by_file.setdefault(p.file_id, []).append((i, p))
        out: List = [None] * len(ptrs)
        seq_reads = 0
        for fid, lst in by_file.items():
            lst.sort(key=lambda ip: ip[1].offset)
            with open(self._path(fid), "rb") as f:
                j = 0
                while j < len(lst):
                    # coalesce a contiguous-ish range (gap tolerance 64 KiB)
                    start = lst[j][1].offset
                    end = lst[j][1].offset + lst[j][1].length
                    k = j + 1
                    while k < len(lst) and lst[k][1].offset <= end + 65536:
                        end = max(end, lst[k][1].offset + lst[k][1].length)
                        k += 1
                    f.seek(start)
                    chunk = memoryview(f.read(end - start))
                    seq_reads += 1
                    for idx, p in lst[j:k]:
                        raw = chunk[p.offset - start : p.offset - start + p.length]
                        out[idx] = self._parse(raw, p)
                    j = k
            self.touch(fid)
        with self._lock:
            self.seq_reads += seq_reads
        return out

    def extent_for(self, ptrs: Sequence[LogPointer]) -> "LogExtent | None":
        """The single contiguous extent covering ``ptrs`` in order, or
        ``None`` when the records span files or are not strictly
        adjacent.  Batch appends write records back-to-back, so a
        sequence stored in one ``append_batch`` call (the common case:
        one ``put_batch`` per sequence) qualifies."""
        if not ptrs:
            return None
        fid, off = ptrs[0].file_id, ptrs[0].offset
        end = off
        for p in ptrs:
            if p.file_id != fid or p.offset != end:
                return None
            end += p.length
        return LogExtent(self._path(fid), off, end - off, tuple(p.length for p in ptrs))

    def scan_file(self, file_id: int) -> Iterator:
        """Yield (ptr, key, payload) for every record in a file (merge/GC)."""
        path = self._path(file_id)
        size = os.path.getsize(path)
        with open(path, "rb") as f:
            off = 0
            while off + _HDR.size <= size:
                hdr = f.read(_HDR.size)
                crc, klen, plen = _HDR.unpack_from(hdr)
                body = f.read(klen + plen)
                if len(body) < klen + plen:
                    return
                ptr = LogPointer(file_id, off, _HDR.size + klen + plen)
                if zlib.crc32(body) & 0xFFFFFFFF == crc:
                    yield ptr, body[:klen], body[klen:]
                off += ptr.length

    def remove_file(self, file_id: int) -> None:
        with self._lock:
            if self._active_id == file_id and self._active_f is not None:
                self._active_f.close()
                self._active_f = None
            try:
                os.remove(self._path(file_id))
            except OSError:
                pass
            self._files.pop(file_id, None)

    def sync(self) -> None:
        with self._lock:
            if self._active_f is not None:
                self._active_f.flush()
                os.fsync(self._active_f.fileno())

    def close(self) -> None:
        with self._lock:
            if self._active_f is not None:
                self._active_f.close()
                self._active_f = None
