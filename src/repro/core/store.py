"""``KVBlockStore`` — the public contract of SGLANG-LSM (paper §3.2, Fig. 6):

    put_batch(tokens, blocks)   store sequential KV-cache blocks
    probe(tokens) -> n_tokens   longest cached prefix (binary search +
                                Bloom-pruned LSM point lookups)
    get_batch(tokens, n)        one LSM range scan + coalesced tensor-log
                                batch read + batch decode

Two-phase write protocol: tensor payloads are committed to the tensor log
first; the atomic commit point is the WAL-backed index insert (a crash in
between leaves unreferenced log records, which the merge service garbage
collects).

Index entry value layout: ``LogPointer(20B) | u8 flags`` — compact metadata
only, per key-value separation.

Concurrency (see ``backend.py`` for the cross-backend contract): mutators
(``put_batch``, ``maintenance``, eviction, flush) serialize on a store
mutation lock; ``probe``/``get_batch`` run concurrently with them — index
point/range lookups are protected inside ``LSMTree``, tensor-log payload
reads are lock-free against immutable log files, and a read that loses a
race with file eviction/merging re-resolves its pointers from the index
and retries.  Stats and the adaptive controller share a dedicated lock so
counters sum correctly under concurrent load.

Durability ordering (two-phase write): with ``fsync_writes`` enabled the
tensor-log append is fsynced **before** the WAL-backed index insert, so a
crash can only ever leave *unreferenced* log records (garbage the merge
service collects) — never an index entry pointing at bytes that were lost.
"""

from __future__ import annotations

import os
import struct
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from .batchops import BatchOpsMixin
from .codec import CODEC_INT8, BatchCodec
from .controller import OP_EMPTY, OP_RANGE, OP_READ, OP_WRITE, AdaptiveController
from .keycodec import encode_tokens
from .lsm import LSMTree
from .merge import TensorFileMerger
from .tensorlog import PTR_BYTES, LogPointer, TensorLog
from .tiering import (
    TIER_HOT,
    TIER_MASK,
    TIER_NAMES,
    TierRecoder,
    TieringPolicy,
    tier_of_codec,
)

ENTRY_BYTES = PTR_BYTES + 1


@dataclass
class StoreStats:
    put_blocks: int = 0
    put_tokens: int = 0
    get_blocks: int = 0
    get_tokens: int = 0
    probes: int = 0
    probe_hits: int = 0
    probe_empty: int = 0
    probe_lookups: int = 0
    payload_bytes_in: int = 0
    payload_bytes_stored: int = 0
    evicted_blocks: int = 0
    io_read_s: float = 0.0
    io_write_s: float = 0.0
    raw_gets: int = 0  # get_batch_raw calls that found a sendfile-able extent
    raw_get_blocks: int = 0
    # compression-tier accounting (see core.tiering).  The tier counts are
    # resident blocks per tier — kept exact under put/demote/evict, drift
    # only on overwrites (skip_existing=False superseding an indexed key).
    tier_hot_blocks: int = 0
    tier_warm_blocks: int = 0
    tier_cold_blocks: int = 0
    demoted_blocks: int = 0  # blocks re-encoded down-tier by maintenance
    demote_bytes_before: int = 0  # payload bytes of demoted blocks, pre/post
    demote_bytes_after: int = 0
    demote_s: float = 0.0  # off-path wall time spent transcoding
    # elasticity accounting: blocks shipped out of / into this store in
    # stored encoding (cluster migration + replica repair traffic)
    exported_blocks: int = 0
    imported_blocks: int = 0
    imported_bytes: int = 0  # stored payload bytes accepted by import

    @property
    def compression_ratio(self) -> float:
        return self.payload_bytes_in / max(1, self.payload_bytes_stored)

    @property
    def demote_bytes_saved(self) -> int:
        return self.demote_bytes_before - self.demote_bytes_after


@dataclass
class RawBatch:
    """A contiguous run of encoded blocks, as an *open file* plus an
    extent — the zero-copy handoff behind the cluster server's
    ``os.sendfile`` path.  The open handle pins the inode, so the bytes
    stay readable even if eviction unlinks the file mid-send.  The
    records are the on-disk ``crc | klen | plen | key | payload`` format;
    ``record_lengths[i]`` is the full length of block ``i``'s record, in
    ascending block order.  The caller owns ``file`` and must close it."""

    file: object
    offset: int
    length: int
    record_lengths: List[int]

    @property
    def n_blocks(self) -> int:
        return len(self.record_lengths)

    def close(self) -> None:
        try:
            self.file.close()
        except OSError:
            pass


class KVBlockStore(BatchOpsMixin):
    """Disk-resident KV-cache store over an LSM index + tensor log."""

    name = "lsm"

    def __init__(
        self,
        root: str,
        block_size: int = 16,
        codec: Optional[BatchCodec] = None,
        buffer_bytes: int = 1 << 20,
        size_ratio: int = 4,
        runs_per_level: int = 1,
        bloom_bits_per_key: float = 10.0,
        vlog_file_bytes: int = 32 * 1024 * 1024,
        max_log_files: int = 64,
        garbage_threshold: float = 0.5,
        budget_bytes: Optional[int] = None,
        adaptive: bool = True,
        controller_window: int = 4096,
        fsync: bool = False,
        fsync_writes: Optional[bool] = None,
        tiering: Optional[TieringPolicy] = None,
    ):
        # ``fsync_writes`` is the documented knob; ``fsync`` is kept as a
        # backward-compatible alias (either turns durability on).
        self.fsync_writes = bool(fsync) if fsync_writes is None else bool(fsync_writes)
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.block_size = block_size
        # With an adaptive tiering policy the put path always writes the
        # hot tier's codec (raw — zero codec CPU on the hot path); the
        # policy demotes blocks to int8 / int8+zlib off-path during
        # maintenance.  Without a policy the static ``codec`` applies and
        # every block is tagged with that codec's equivalent tier so the
        # per-tier gauges stay meaningful.
        self.tiering = tiering
        if tiering is not None:
            self.codec = tiering.codec_for(TIER_HOT)
            self._put_tier = TIER_HOT
        else:
            self.codec = codec or BatchCodec(CODEC_INT8, use_zlib=True)
            self._put_tier = tier_of_codec(self.codec)
        self.budget_bytes = budget_bytes
        self._lock = threading.RLock()  # serializes mutators (put/maintenance/evict)
        self._stats_lock = threading.Lock()  # stats counters + adaptive controller
        self.index = LSMTree(
            os.path.join(root, "index"),
            buffer_bytes=buffer_bytes,
            size_ratio=size_ratio,
            runs_per_level=runs_per_level,
            bloom_bits_per_key=bloom_bits_per_key,
            fsync=self.fsync_writes,
        )
        self.log = TensorLog(
            os.path.join(root, "log"),
            max_file_bytes=vlog_file_bytes,
            fsync_writes=self.fsync_writes,
        )
        self.merger = TensorFileMerger(
            self.log, self.index, max_files=max_log_files, garbage_threshold=garbage_threshold
        )
        self.controller = AdaptiveController(
            self.index, window=controller_window, entry_bytes=ENTRY_BYTES, enabled=adaptive
        )
        self.recoder = (
            TierRecoder(self.log, self.index, tiering,
                        entry_codec=(self._unpack_entry, self._pack_value))
            if tiering is not None else None
        )
        self.stats = StoreStats()
        # File eviction is the only operation that breaks prefix-closure
        # (holes mid-prefix); the marker persists that fact across reopens
        # so probe only pays contiguity verification on stores where holes
        # can actually exist.
        self._holes_marker = os.path.join(root, "evicted.marker")
        self._may_have_holes = os.path.exists(self._holes_marker)

    # ------------------------------------------------------------------ keys
    def _key(self, tokens: Sequence[int], n_tokens: int) -> bytes:
        return encode_tokens(tokens[:n_tokens])

    @staticmethod
    def _pack_value(ptr: LogPointer, flags: int = 0) -> bytes:
        return ptr.pack() + struct.pack("<B", flags)

    @staticmethod
    def _unpack_value(v: bytes) -> LogPointer:
        return LogPointer.unpack(v)

    @staticmethod
    def _unpack_entry(v: bytes):
        """Full entry: ``(LogPointer, flags)`` — bits 0-1 of flags are the
        compression tier (``core.tiering``)."""
        return LogPointer.unpack(v), (v[PTR_BYTES] if len(v) > PTR_BYTES else 0)

    def _bump_tier(self, tier: int, n: int) -> None:
        """Adjust one resident-per-tier gauge; caller holds ``_stats_lock``."""
        name = f"tier_{TIER_NAMES[tier]}_blocks"
        setattr(self.stats, name, getattr(self.stats, name) + n)

    # ------------------------------------------------------------------- put
    def put_batch(
        self,
        tokens: Sequence[int],
        blocks: Sequence[np.ndarray],
        start_block: int = 0,
        skip_existing: bool = True,
    ) -> int:
        """Store ``blocks[i]`` as the KV cache of tokens
        ``[(start_block+i)·B : (start_block+i+1)·B)``.  Returns #blocks
        written (duplicates skipped)."""
        B = self.block_size
        t0 = time.perf_counter()
        records = []  # (key, payload)
        bytes_in = bytes_stored = 0
        # encode outside the mutation lock: codec CPU (quantize + zlib) is
        # the expensive part and must not serialize concurrent writers
        # across shards sharing a thread pool.  The dedup check may race a
        # concurrent writer of the same key; the loser's record becomes
        # garbage the merge service collects — never a lost write.
        for i, block in enumerate(blocks):
            bi = start_block + i
            end = (bi + 1) * B
            if end > len(tokens):
                break
            key = self._key(tokens, end)
            if skip_existing:
                found, _ = self.index.get(key)
                if found:
                    continue
            payload = self.codec.encode(np.asarray(block))
            bytes_in += np.asarray(block).nbytes
            bytes_stored += len(payload)
            records.append((key, payload))
        if not records:
            return 0
        with self._lock:
            # phase 1: tensor log append.  Durability ordering: with
            # fsync_writes the log was constructed with fsync-per-append,
            # so the payload bytes are on disk *before* phase 2's WAL-backed
            # index insert can commit a pointer to them (the same internal
            # fsync also covers the merge service's relocation appends).
            ptrs = self.log.append_batch(records)
            # phase 2: atomic index insert (WAL-backed commit point).  The
            # flags byte carries the block's compression tier.
            self.index.put_batch(
                (k, self._pack_value(p, self._put_tier)) for (k, _), p in zip(records, ptrs)
            )
        with self._stats_lock:
            self.controller.record(OP_WRITE, len(records))
            self.stats.payload_bytes_in += bytes_in
            self.stats.payload_bytes_stored += bytes_stored
            self.stats.put_blocks += len(records)
            self.stats.put_tokens += len(records) * B
            self.stats.io_write_s += time.perf_counter() - t0
            self._bump_tier(self._put_tier, len(records))
        return len(records)

    # ----------------------------------------------------------------- probe
    def probe(self, tokens: Sequence[int]) -> int:
        """Longest cached prefix length in tokens (multiple of block_size).
        Binary search over block counts; each step is an LSM point lookup
        (paper App. B: Bloom filters prune the misses)."""
        B = self.block_size
        max_blocks = len(tokens) // B
        with self._stats_lock:
            self.stats.probes += 1
        if max_blocks == 0:
            with self._stats_lock:
                self.stats.probe_empty += 1
                self.controller.record(OP_EMPTY, 1)
            return 0
        lo, hi = 0, max_blocks  # invariant: block count `lo` exists (0 = root)
        while lo < hi:
            mid = (lo + hi + 1) // 2
            found, _ = self.index.get(self._key(tokens, mid * B))
            with self._stats_lock:
                self.stats.probe_lookups += 1
                self.controller.record(OP_READ if found else OP_EMPTY, 1)
            if found:
                lo = mid
            else:
                hi = mid - 1
        if lo and self._may_have_holes:
            # Binary search assumes prefix-closure (block k present => blocks
            # 1..k-1 present), but FIFO file eviction tombstones whole files
            # regardless of prefix position, punching holes mid-prefix.  One
            # index range scan confirms the contiguous prefix so probe never
            # promises tokens get_batch would then truncate.  Skipped until
            # the first eviction: hole-free stores keep the pure O(log n)
            # Bloom-pruned probe.
            lo = self._contiguous_blocks(tokens, lo)
        with self._stats_lock:
            if lo == 0:
                self.stats.probe_empty += 1
            else:
                self.stats.probe_hits += 1
        return lo * B

    def _scan_block_ptrs(self, tokens: Sequence[int], n_blocks: int) -> List[Optional[LogPointer]]:
        """One index range scan over blocks 1..n_blocks; ``ptrs[i]`` is None
        when block ``i+1`` is absent.  Shared by ``get_batch`` and probe's
        contiguity verification so the two can never disagree on presence."""
        B = self.block_size
        start = self._key(tokens, B)
        end = self._key(tokens, n_blocks * B) + b"\x00"
        wanted: Dict[bytes, int] = {self._key(tokens, (i + 1) * B): i for i in range(n_blocks)}
        ptrs: List[Optional[LogPointer]] = [None] * n_blocks
        for k, v in self.index.range(start, end):
            idx = wanted.get(k)
            if idx is not None:
                ptrs[idx] = self._unpack_value(v)
        with self._stats_lock:
            self.controller.record(OP_RANGE, 1)
        return ptrs

    def _contiguous_blocks(self, tokens: Sequence[int], n_blocks: int) -> int:
        """Largest k <= n_blocks such that blocks 1..k are all indexed."""
        ptrs = self._scan_block_ptrs(tokens, n_blocks)
        k = 0
        while k < n_blocks and ptrs[k] is not None:
            k += 1
        return k

    # ------------------------------------------------------------------- get
    def get_batch(self, tokens: Sequence[int], n_tokens: int) -> List[np.ndarray]:
        """Load the cached blocks covering ``tokens[:n_tokens]``: one index
        range scan, then a coalesced batch read from the tensor log."""
        B = self.block_size
        n_blocks = n_tokens // B
        if n_blocks == 0:
            return []
        t0 = time.perf_counter()
        blocks: List[Optional[np.ndarray]] = [None] * n_blocks
        # Optimistic lock-free read: resolve pointers, read payloads with no
        # lock held.  If FIFO eviction or the merge service removed a log
        # file between the scan and the read (FileNotFoundError), re-resolve
        # and retry — the index was updated (tombstoned or repointed)
        # *before* the file was unlinked, so a fresh scan converges.  Any
        # other I/O error (notably a CRC mismatch: records are immutable
        # once their pointer is published, so a bad checksum is real
        # corruption, never a race) propagates to the caller.  Bounded
        # attempts: a reader can lose the eviction race at most once per
        # maintenance cycle in practice.
        for _attempt in range(3):
            ptrs = self._scan_block_ptrs(tokens, n_blocks)
            present = [(i, p) for i, p in enumerate(ptrs) if p is not None]
            blocks = [None] * n_blocks
            if not present:
                break
            try:
                recs = self.log.read_batch([p for _, p in present])
            except FileNotFoundError:
                continue  # lost the race with eviction/merge: retry
            for (i, _), (_, payload) in zip(present, recs):
                blocks[i] = BatchCodec.decode(payload)
            break
        # only the contiguous prefix is usable as KV cache
        out: List[np.ndarray] = []
        for b in blocks:
            if b is None:
                break
            out.append(b)
        with self._stats_lock:
            self.stats.get_blocks += len(out)
            self.stats.get_tokens += len(out) * B
            self.stats.io_read_s += time.perf_counter() - t0
        return out

    def get_batch_encoded(self, tokens: Sequence[int], n_tokens: int) -> List[bytes]:
        """The contiguous cached prefix as *encoded* codec payloads —
        no decode.  The cluster server ships these verbatim, so the wire
        carries the same compressed bytes the disk stores (the buffered
        complement of the sendfile path, which already ships raw log
        records).  Payloads are self-describing (``core.codec`` header);
        the receiver decodes with ``BatchCodec.decode``."""
        B = self.block_size
        n_blocks = n_tokens // B
        if n_blocks == 0:
            return []
        t0 = time.perf_counter()
        payloads: List[Optional[bytes]] = [None] * n_blocks
        for _attempt in range(3):  # same retry contract as get_batch
            ptrs = self._scan_block_ptrs(tokens, n_blocks)
            present = [(i, p) for i, p in enumerate(ptrs) if p is not None]
            payloads = [None] * n_blocks
            if not present:
                break
            try:
                recs = self.log.read_batch([p for _, p in present])
            except FileNotFoundError:
                continue  # lost the race with eviction/merge/demotion: retry
            for (i, _), (_, payload) in zip(present, recs):
                payloads[i] = bytes(payload)
            break
        out: List[bytes] = []
        for p in payloads:
            if p is None:
                break
            out.append(p)
        with self._stats_lock:
            self.stats.get_blocks += len(out)
            self.stats.get_tokens += len(out) * B
            self.stats.io_read_s += time.perf_counter() - t0
        return out

    def get_batch_raw(self, tokens: Sequence[int], n_tokens: int) -> Optional[RawBatch]:
        """Zero-copy variant of ``get_batch``: when the contiguous cached
        prefix sits as one adjacent run of records in a single tensor-log
        file (the common case — a sequence is appended in one batch),
        return it as an open-file extent instead of reading and decoding.
        Returns ``None`` when no such extent exists (blocks span files,
        interleave with other writes, or the store is empty) — callers
        fall back to ``get_batch``."""
        B = self.block_size
        n_blocks = n_tokens // B
        if n_blocks == 0:
            return None
        ptrs = self._scan_block_ptrs(tokens, n_blocks)
        run = []
        for p in ptrs:
            if p is None:
                break
            run.append(p)
        if not run:
            return None
        ext = self.log.extent_for(run)
        if ext is None:
            return None
        try:
            f = open(ext.path, "rb")
        except FileNotFoundError:
            return None  # lost the race with eviction/merge; caller retries decoded
        self.log.touch(run[0].file_id)  # sendfile reads count as access too
        with self._stats_lock:
            self.stats.raw_gets += 1
            self.stats.raw_get_blocks += len(run)
        return RawBatch(file=f, offset=ext.offset, length=ext.length,
                        record_lengths=list(ext.record_lengths))

    # ----------------------------------------------- key export (elasticity)
    # The enumeration/export/import trio is the storage-level substrate of
    # cluster migration (``cluster.migration``): scan the live keyspace in
    # pages, ship blocks *in their stored encoding* (cold tiers migrate
    # compressed — no transcode on either side), and accept foreign records
    # verbatim.  All three are optional backend methods (duck-typed by the
    # cluster server, like ``get_batch_encoded``).

    _SCAN_END = b"\xff" * 2048  # past any real key (keys are 4B/token)

    def scan_keys(
        self, cursor: Optional[bytes] = None, limit: int = 1024
    ) -> "tuple[List[bytes], Optional[bytes]]":
        """One page of live index keys in key order, starting strictly
        after ``cursor`` (None = from the beginning).  Returns
        ``(keys, next_cursor)``; ``next_cursor`` is None once the keyspace
        is exhausted.  Key order sorts every prefix before its extensions,
        so a prefix tree streams out in prefix-closed order — a migration
        destination that imports pages in order never holds a child block
        without its ancestors.  A page may be shorter than ``limit`` (or
        the final ``next_cursor`` may point at an empty page); callers
        loop until ``next_cursor`` is None."""
        start = bytes(cursor) + b"\x00" if cursor else b""
        out: List[bytes] = []
        for k, _ in self.index.range(start, self._SCAN_END):
            out.append(k)
            if len(out) >= limit:
                break
        next_cursor = out[-1] if len(out) >= limit else None
        return out, next_cursor

    def export_encoded(self, keys: Sequence[bytes]):
        """Stored records for ``keys`` as ``(tier_flags, payload)`` pairs
        (still encoded — the wire ships what the disk stores), aligned
        with ``keys``; ``None`` where a key is not (or no longer) indexed.
        Same optimistic retry contract as ``get_batch``: losing a race
        with eviction/merge re-resolves from the index."""
        out: List[Optional[tuple]] = [None] * len(keys)
        n = 0
        for _attempt in range(3):
            present = []
            for i, key in enumerate(keys):
                found, v = self.index.get(bytes(key))
                if found:
                    present.append((i, *self._unpack_entry(v)))
            out = [None] * len(keys)
            if not present:
                break
            try:
                recs = self.log.read_batch([ptr for _, ptr, _ in present])
            except FileNotFoundError:
                continue  # lost the race with eviction/merge/demotion: retry
            for (i, _, flags), (_, payload) in zip(present, recs):
                out[i] = (flags, bytes(payload))
            n = len(present)
            break
        with self._stats_lock:
            self.stats.exported_blocks += n
        return out

    def import_encoded(self, records, skip_existing: bool = True) -> int:
        """Accept foreign ``(key, tier_flags, payload)`` records verbatim:
        the payload is appended to the tensor log unchanged and indexed
        with its original tier flags, so a block that left its source as
        int8+zlib lands here as int8+zlib.  Idempotent under
        ``skip_existing`` (already-indexed keys are skipped and not
        counted), which is what makes migration retries and multi-source
        repair copies safe.  Returns the number of blocks written."""
        fresh = []  # (key, payload)
        flags_list: List[int] = []
        for key, flags, payload in records:
            key = bytes(key)
            if skip_existing:
                found, _ = self.index.get(key)
                if found:
                    continue
            fresh.append((key, bytes(payload)))
            flags_list.append(int(flags) & 0xFF)
        if not fresh:
            return 0
        with self._lock:
            # Imported arcs are subsets of the source keyspace, so this
            # store can now hold blocks without their prefix ancestors —
            # same probe-safety situation as file eviction.  Persist the
            # marker *before* the records commit so probe verifies
            # contiguity from the first imported block onward.
            if not self._may_have_holes:
                self._may_have_holes = True
                open(self._holes_marker, "w").close()
            # two-phase write, same ordering as put_batch
            ptrs = self.log.append_batch(fresh)
            self.index.put_batch(
                (k, self._pack_value(p, fl))
                for (k, _), p, fl in zip(fresh, ptrs, flags_list)
            )
        nbytes = sum(len(p) for _, p in fresh)
        with self._stats_lock:
            self.controller.record(OP_WRITE, len(fresh))
            self.stats.imported_blocks += len(fresh)
            self.stats.imported_bytes += nbytes
            self.stats.payload_bytes_stored += nbytes
            for fl in flags_list:
                self._bump_tier(fl & TIER_MASK, 1)
        return len(fresh)

    # ------------------------------------------------------------ lifecycle
    def maintenance(self, compact_steps: int = 8) -> dict:
        """One maintenance cycle: index compaction, tensor-file merging, and
        budget eviction.  Deterministic (no background thread) so tests and
        benchmarks control scheduling; ``serving.engine`` calls it between
        batches, mirroring the paper's 'scheduled compaction cycles'."""
        with self._lock:
            rep: dict = {}
            rep["compactions"] = self.index.maybe_compact(compact_steps)
            if self.merger.needed():
                m = self.merger.run()
                rep["merge"] = {"files": m.files_removed, "moved": m.records_moved, "reclaimed": m.bytes_reclaimed}
            # tier demotion runs before budget eviction so the budget is
            # enforced against the *compressed* footprint — this ordering
            # is what lets a fixed budget hold 3-4x more cold blocks
            if self.recoder is not None and self.recoder.needed():
                t0 = time.perf_counter()
                trep = self.recoder.run()
                self._apply_tier_report(trep, time.perf_counter() - t0)
                if trep.files:
                    rep["tiering"] = trep.as_dict()
            if self.budget_bytes is not None:
                rep["evicted_files"] = self._evict_to_budget()
            return rep

    def _apply_tier_report(self, trep, dt: float) -> None:
        with self._stats_lock:
            self.stats.demoted_blocks += trep.demoted_blocks
            self.stats.demote_bytes_before += trep.bytes_before
            self.stats.demote_bytes_after += trep.bytes_after
            self.stats.demote_s += dt
            for name, n in trep.transitions.items():
                src, _, dst = name.partition("->")
                self._bump_tier(TIER_NAMES.index(src), -n)
                self._bump_tier(TIER_NAMES.index(dst), n)

    def evict_oldest_file(self) -> bool:
        """Drop the oldest tensor-log file and tombstone its index entries
        (the unit of FIFO eviction; ``ShardedKVBlockStore`` drives this
        directly to enforce a global budget across shards).  Returns False
        when only the active file remains.  Index entries are tombstoned
        *before* the file is unlinked so concurrent lock-free readers that
        lose the race re-resolve to a consistent (evicted) view."""
        with self._lock:
            if self.log.file_count <= 1:
                return False
            if not self._may_have_holes:
                self._may_have_holes = True
                open(self._holes_marker, "w").close()
            fid = self.log.file_ids()[0]
            keys = [key for _, key, _ in self.log.scan_file(fid)]
            # one batched tombstone insert (single WAL sync under
            # fsync_writes) instead of a per-key delete loop
            dead = []
            tiers = [0, 0, 0]  # evicted blocks per compression tier
            for key in keys:
                found, v = self.index.get(key)
                if found and self._unpack_value(v).file_id == fid:
                    dead.append(key)
                    tiers[self._unpack_entry(v)[1] & TIER_MASK] += 1
            self.index.put_batch((k, None) for k in dead)
            evicted = len(dead)
            self.log.remove_file(fid)
        with self._stats_lock:
            self.stats.evicted_blocks += evicted
            for tier, n in enumerate(tiers):
                if n:
                    self._bump_tier(tier, -n)
        return True

    def _evict_to_budget(self) -> int:
        """FIFO file eviction: oldest tensor-log files are dropped (their
        index entries tombstoned) until under budget.  Hot data survives
        because the merge service continuously rewrites live records into
        young files (WiscKey-style age segregation)."""
        evicted = 0
        while self.disk_bytes > self.budget_bytes and self.evict_oldest_file():
            evicted += 1
        return evicted

    # ----------------------------------------------------------------- stats
    @property
    def disk_bytes(self) -> int:
        return self.log.total_bytes + self.index.disk_bytes

    @property
    def file_count(self) -> int:
        return self.log.file_count + self.index.n_runs

    @property
    def write_amplification(self) -> float:
        return self.index.stats.write_amplification

    def flush(self) -> None:
        with self._lock:
            self.index.flush()
            self.log.sync()

    def sync_wal(self) -> None:
        """Durability point without a memtable flush: tensor log first, then
        the WAL (same ordering as the two-phase write), so recovery replays
        an index whose pointers all resolve."""
        with self._lock:
            self.log.sync()
            self.index.wal.sync()

    def close(self) -> None:
        with self._lock:
            self.index.close()
            self.log.close()
