"""Sorted-run (SSTable) file format with fence pointers, restart-point
prefix compression, and a per-run Bloom filter.

Layout::

    [data block 0][data block 1]...[index][bloom][footer(40B)]

Data block entry (LevelDB-style):
    varint shared_len | varint unshared_len | varint value_len |
    key_suffix bytes | value bytes
Tombstones are encoded with value_len == VLEN_TOMBSTONE sentinel.

Prefix compression matters here more than in a general-purpose store: keys
are full token prefixes (``keycodec``), so consecutive keys within a run
share very long prefixes — a 32k-token key typically costs ~4 bytes of
suffix after compression.

The index (fence pointers) and Bloom filter are loaded into memory when the
run is opened; data blocks are read on demand (one seek + one read per
block), matching the I/O cost model of §2.2.
"""

from __future__ import annotations

import os
import struct
import zlib
from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

from .bloom import BloomFilter
from .keycodec import shared_prefix_len

MAGIC = 0x4C534D31  # "LSM1"
_FOOTER = struct.Struct("<QQQQI")  # index_off, index_len, bloom_off, bloom_len, magic
VLEN_TOMBSTONE = (1 << 32) - 1


def _put_varint(buf: bytearray, n: int) -> None:
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            buf.append(b | 0x80)
        else:
            buf.append(b)
            return


def _get_varint(buf: bytes, pos: int) -> Tuple[int, int]:
    shift = 0
    out = 0
    while True:
        b = buf[pos]
        pos += 1
        out |= (b & 0x7F) << shift
        if not b & 0x80:
            return out, pos
        shift += 7


@dataclass
class RunMeta:
    path: str
    min_key: bytes
    max_key: bytes
    entries: int
    data_bytes: int  # total file size
    seq: int  # creation sequence number; larger == newer


class SSTWriter:
    """Builds one sorted run from an already-sorted (key, value) stream."""

    def __init__(self, path: str, block_bytes: int = 4096, bloom_bits_per_key: float = 10.0):
        self.path = path
        self.block_bytes = block_bytes
        self._bloom_bits = bloom_bits_per_key
        self._buf = bytearray()
        self._last_key: Optional[bytes] = None
        self._block_first_key: Optional[bytes] = None
        self._index: List[Tuple[bytes, int, int]] = []  # (first_key, off, len)
        self._keys: List[bytes] = []
        self._f = open(path, "wb")
        self._off = 0
        self.entries = 0
        self.min_key: Optional[bytes] = None
        self.max_key: Optional[bytes] = None

    def add(self, key: bytes, value: Optional[bytes]) -> None:
        if self._last_key is not None and key <= self._last_key:
            raise ValueError("keys must be added in strictly increasing order")
        if self._block_first_key is None:
            self._block_first_key = key
            shared = 0  # restart point at block start
        else:
            shared = shared_prefix_len(self._last_key, key)
        _put_varint(self._buf, shared)
        _put_varint(self._buf, len(key) - shared)
        _put_varint(self._buf, VLEN_TOMBSTONE if value is None else len(value))
        self._buf += key[shared:]
        if value is not None:
            self._buf += value
        self._last_key = key
        self._keys.append(key)
        self.entries += 1
        if self.min_key is None:
            self.min_key = key
        self.max_key = key
        if len(self._buf) >= self.block_bytes:
            self._flush_block()

    def _flush_block(self) -> None:
        if not self._buf:
            return
        raw = bytes(self._buf)
        self._f.write(raw)
        self._index.append((self._block_first_key, self._off, len(raw)))
        self._off += len(raw)
        self._buf.clear()
        self._block_first_key = None
        self._last_key = None  # restart prefix compression at block boundary

    def finish(self) -> RunMeta:
        self._flush_block()
        # index block: count | per entry: varint klen, key, u64 off, u32 len
        ib = bytearray()
        _put_varint(ib, len(self._index))
        for fk, off, ln in self._index:
            _put_varint(ib, len(fk))
            ib += fk
            ib += struct.pack("<QI", off, ln)
        index_raw = zlib.compress(bytes(ib), 1)
        bloom = BloomFilter.for_entries(len(self._keys), self._bloom_bits)
        for k in self._keys:
            bloom.add(k)
        bloom_raw = bloom.to_bytes()
        index_off = self._off
        self._f.write(index_raw)
        bloom_off = index_off + len(index_raw)
        self._f.write(bloom_raw)
        self._f.write(_FOOTER.pack(index_off, len(index_raw), bloom_off, len(bloom_raw), MAGIC))
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        size = os.path.getsize(self.path)
        return RunMeta(
            path=self.path,
            min_key=self.min_key or b"",
            max_key=self.max_key or b"",
            entries=self.entries,
            data_bytes=size,
            seq=0,
        )


def _decode_block(raw: bytes) -> Iterator:
    pos = 0
    prev = b""
    n = len(raw)
    while pos < n:
        shared, pos = _get_varint(raw, pos)
        unshared, pos = _get_varint(raw, pos)
        vlen, pos = _get_varint(raw, pos)
        key = prev[:shared] + raw[pos : pos + unshared]
        pos += unshared
        if vlen == VLEN_TOMBSTONE:
            value = None
        else:
            value = raw[pos : pos + vlen]
            pos += vlen
        yield key, value
        prev = key


class SSTReader:
    """Open run: fence pointers + bloom in memory, blocks read on demand."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "rb")
        self._f.seek(0, os.SEEK_END)
        fsize = self._f.tell()
        self._f.seek(fsize - _FOOTER.size)
        index_off, index_len, bloom_off, bloom_len, magic = _FOOTER.unpack(self._f.read(_FOOTER.size))
        if magic != MAGIC:
            raise IOError(f"bad SST magic in {path}")
        self._f.seek(index_off)
        ib = zlib.decompress(self._f.read(index_len))
        pos = 0
        cnt, pos = _get_varint(ib, pos)
        self.index: List[Tuple[bytes, int, int]] = []
        for _ in range(cnt):
            klen, pos = _get_varint(ib, pos)
            fk = ib[pos : pos + klen]
            pos += klen
            off, ln = struct.unpack_from("<QI", ib, pos)
            pos += 12
            self.index.append((fk, off, ln))
        self._f.seek(bloom_off)
        self.bloom = BloomFilter.from_bytes(self._f.read(bloom_len))
        self.block_reads = 0  # observability for cost-model validation

    def close(self) -> None:
        self._f.close()

    def _read_block(self, i: int) -> bytes:
        _, off, ln = self.index[i]
        self._f.seek(off)
        self.block_reads += 1
        return self._f.read(ln)

    def _find_block(self, key: bytes) -> int:
        """Rightmost block whose first_key <= key (fence-pointer search)."""
        lo, hi = 0, len(self.index) - 1
        ans = -1
        while lo <= hi:
            mid = (lo + hi) // 2
            if self.index[mid][0] <= key:
                ans = mid
                lo = mid + 1
            else:
                hi = mid - 1
        return ans

    def get(self, key: bytes):
        """(found, value) — bloom-pruned point lookup."""
        if key not in self.bloom:
            return False, None
        bi = self._find_block(key)
        if bi < 0:
            return False, None
        for k, v in _decode_block(self._read_block(bi)):
            if k == key:
                return True, v
            if k > key:
                break
        return False, None

    def range(self, start: bytes, end: bytes) -> Iterator:
        """Yield (key, value) for start <= key < end (tombstones included)."""
        if not self.index:
            return
        bi = max(0, self._find_block(start))
        for i in range(bi, len(self.index)):
            if self.index[i][0] >= end:
                break
            for k, v in _decode_block(self._read_block(i)):
                if k < start:
                    continue
                if k >= end:
                    return
                yield k, v

    def items(self) -> Iterator:
        for i in range(len(self.index)):
            yield from _decode_block(self._read_block(i))
