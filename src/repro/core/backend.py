"""``StorageBackend`` — the storage contract of the reproduction.

Every disk/memory backend (``KVBlockStore``, ``ShardedKVBlockStore``,
``FilePerObjectStore``, ``MemoryOnlyStore``) satisfies this protocol, and
the layers above storage — ``cache.hierarchy.CacheHierarchy``,
``serving.engine.ServingEngine``, the workload drivers and benchmarks —
depend only on it.  Swapping the engine's disk tier is a constructor
argument, never a code change.

The contract (paper §3.2, Fig. 6):

    put_batch(tokens, blocks, start_block, skip_existing) -> n_written
    probe(tokens) -> n_tokens        longest *contiguous* cached prefix
    get_batch(tokens, n_tokens)      blocks covering tokens[:n_tokens]
    maintenance(compact_steps)       one scheduled maintenance cycle
    flush() / close()                durability / lifecycle
    stats / disk_bytes / file_count  observability

Invariants every backend must keep:
  * ``probe`` never promises tokens ``get_batch`` would truncate — it
    reports a contiguous, immediately readable prefix;
  * ``put_batch`` keys block ``i`` by the whole token prefix through block
    ``i`` (content addressing), so identical prefixes dedup across requests;
  * ``maintenance`` is deterministic and caller-scheduled (no background
    threads), so tests and benchmarks control when compaction work happens.
"""

from __future__ import annotations

from dataclasses import fields
from typing import Iterable, List, Protocol, Sequence, runtime_checkable

import numpy as np

from .store import StoreStats


@runtime_checkable
class StorageBackend(Protocol):
    """Structural protocol for KV-cache storage backends.

    ``runtime_checkable`` supports ``isinstance`` conformance checks in
    tests; static checkers verify the full signatures.
    """

    name: str
    block_size: int

    def put_batch(
        self,
        tokens: Sequence[int],
        blocks: Sequence[np.ndarray],
        start_block: int = 0,
        skip_existing: bool = True,
    ) -> int: ...

    def probe(self, tokens: Sequence[int]) -> int: ...

    def get_batch(self, tokens: Sequence[int], n_tokens: int) -> List[np.ndarray]: ...

    def maintenance(self, compact_steps: int = 8) -> dict: ...

    def flush(self) -> None: ...

    def close(self) -> None: ...

    @property
    def stats(self) -> StoreStats: ...

    @property
    def disk_bytes(self) -> int: ...

    @property
    def file_count(self) -> int: ...


def merge_stats(parts: Iterable[StoreStats]) -> StoreStats:
    """Aggregate per-shard ``StoreStats`` into one view (all fields are
    additive counters/timers)."""
    out = StoreStats()
    for s in parts:
        for f in fields(StoreStats):
            setattr(out, f.name, getattr(out, f.name) + getattr(s, f.name))
    return out
