"""``StorageBackend`` — the storage contract of the reproduction.

Every disk/memory backend (``KVBlockStore``, ``ShardedKVBlockStore``,
``FilePerObjectStore``, ``MemoryOnlyStore``) satisfies this protocol, and
the layers above storage — ``cache.hierarchy.CacheHierarchy``,
``serving.engine.ServingEngine``, the workload drivers and benchmarks —
depend only on it.  Swapping the engine's disk tier is a constructor
argument, never a code change.

The contract (paper §3.2, Fig. 6):

    put_batch(tokens, blocks, start_block, skip_existing) -> n_written
    probe(tokens) -> n_tokens        longest *contiguous* cached prefix
    get_batch(tokens, n_tokens)      blocks covering tokens[:n_tokens]
    probe_many / get_many / put_many multi-sequence forms (a sharded
                                     backend fans these out in parallel)
    maintenance(compact_steps)       one scheduled maintenance cycle
    flush() / close()                durability / lifecycle
    stats / disk_bytes / file_count  observability

Optional fast-path methods (duck-typed; the cluster server probes with
``getattr`` and falls back to ``get_batch``):

    get_batch_raw(tokens, n)      the prefix as one contiguous tensor-log
                                  extent (``RawBatch``) for ``os.sendfile``
    get_batch_encoded(tokens, n)  the prefix as still-encoded codec
                                  payloads (bytes), so compressed tiers
                                  ship compressed over the wire

Optional elasticity methods (duck-typed the same way; ``cluster.migration``
uses them to move blocks between nodes during membership changes):

    scan_keys(cursor, limit)      one page of live index keys in a stable
                                  total order -> (keys, next_cursor)
    export_encoded(keys)          stored records as (tier_flags, payload)
                                  pairs, still encoded (None if absent)
    import_encoded(records,       accept foreign (key, flags, payload)
                    skip_existing) records verbatim; idempotent when
                                  skip_existing — returns #blocks written

The LSM backends also accept a ``tiering=TieringPolicy`` constructor
argument (``core.tiering``): puts then write the raw hot tier and the
maintenance cycle demotes idle blocks to int8 / int8+zlib off-path.

Invariants every backend must keep:
  * ``probe`` never promises tokens ``get_batch`` would truncate — it
    reports a contiguous, immediately readable prefix;
  * ``put_batch`` keys block ``i`` by the whole token prefix through block
    ``i`` (content addressing), so identical prefixes dedup across requests;
  * ``maintenance`` is deterministic and caller-scheduled — no backend
    spawns its own threads.  The ``repro.runtime`` layer supplies threads
    (``MaintenanceService`` and the I/O executor) when the deployment
    wants work off the request path.

Thread-safety contract (the concurrent runtime layer depends on this):
  * Every method above is safe to call from multiple threads concurrently,
    including ``maintenance`` racing reads and writes.  Implementations
    use internal fine-grained locks: the LSM index and tensor-log
    *bookkeeping* are lock-protected, while bulk payload reads from
    immutable log files / SSTs take no lock at all (readers re-resolve
    pointers and retry if eviction or a merge removed a file mid-read).
  * Writes are never lost and reads are never torn: a reader sees either
    a block's committed bytes (CRC-verified in the tensor log) or no block
    — never a partial or mixed record.
  * ``stats`` counters are updated under a lock so they sum correctly
    across threads (``merge_stats`` relies on additivity).
  * ``close`` is not required to be safe against in-flight operations;
    callers quiesce (drain executors/queues) first.
"""

from __future__ import annotations

from dataclasses import fields
from typing import Iterable, List, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from .batchops import BatchOpsMixin
from .store import StoreStats

__all__ = ["StorageBackend", "BatchOpsMixin", "merge_stats"]


@runtime_checkable
class StorageBackend(Protocol):
    """Structural protocol for KV-cache storage backends.

    ``runtime_checkable`` supports ``isinstance`` conformance checks in
    tests; static checkers verify the full signatures.
    """

    name: str
    block_size: int

    def put_batch(
        self,
        tokens: Sequence[int],
        blocks: Sequence[np.ndarray],
        start_block: int = 0,
        skip_existing: bool = True,
    ) -> int: ...

    def probe(self, tokens: Sequence[int]) -> int: ...

    def get_batch(self, tokens: Sequence[int], n_tokens: int) -> List[np.ndarray]: ...

    def probe_many(self, seqs: Sequence[Sequence[int]]) -> List[int]: ...

    def get_many(
        self, items: Sequence[Tuple[Sequence[int], int]]
    ) -> List[List[np.ndarray]]: ...

    def put_many(
        self, items: Sequence[Tuple[Sequence[int], Sequence[np.ndarray], int]]
    ) -> List[int]: ...

    def maintenance(self, compact_steps: int = 8) -> dict: ...

    def flush(self) -> None: ...

    def close(self) -> None: ...

    @property
    def stats(self) -> StoreStats: ...

    @property
    def disk_bytes(self) -> int: ...

    @property
    def file_count(self) -> int: ...


def merge_stats(parts: Iterable[StoreStats]) -> StoreStats:
    """Aggregate per-shard ``StoreStats`` into one view (all fields are
    additive counters/timers)."""
    out = StoreStats()
    for s in parts:
        for f in fields(StoreStats):
            setattr(out, f.name, getattr(out, f.name) + getattr(s, f.name))
    return out
