"""Bloom filter (Bloom 1970) used per sorted run to prune absent keys during
``probe`` point lookups (paper §2.2, App. B)."""

from __future__ import annotations

import hashlib
import math
import struct


def _hash2(key: bytes) -> tuple:
    d = hashlib.blake2b(key, digest_size=16).digest()
    return struct.unpack("<QQ", d)


class BloomFilter:
    __slots__ = ("nbits", "k", "bits")

    def __init__(self, nbits: int, k: int, bits: bytearray | None = None):
        self.nbits = max(8, nbits)
        self.k = max(1, k)
        self.bits = bits if bits is not None else bytearray((self.nbits + 7) // 8)

    @classmethod
    def for_entries(cls, n: int, bits_per_key: float = 10.0) -> "BloomFilter":
        n = max(1, n)
        nbits = int(n * bits_per_key)
        k = max(1, round(bits_per_key * math.log(2)))
        return cls(nbits, k)

    def add(self, key: bytes) -> None:
        h1, h2 = _hash2(key)
        for i in range(self.k):
            bit = (h1 + i * h2) % self.nbits
            self.bits[bit >> 3] |= 1 << (bit & 7)

    def __contains__(self, key: bytes) -> bool:
        h1, h2 = _hash2(key)
        for i in range(self.k):
            bit = (h1 + i * h2) % self.nbits
            if not (self.bits[bit >> 3] >> (bit & 7)) & 1:
                return False
        return True

    @property
    def false_positive_rate(self) -> float:
        """Analytic FPR given current fill (used by the cost model)."""
        ones = sum(bin(b).count("1") for b in self.bits)
        fill = ones / self.nbits
        return fill**self.k

    # -- serialization ------------------------------------------------------
    def to_bytes(self) -> bytes:
        return struct.pack("<II", self.nbits, self.k) + bytes(self.bits)

    @classmethod
    def from_bytes(cls, raw: bytes) -> "BloomFilter":
        nbits, k = struct.unpack_from("<II", raw)
        return cls(nbits, k, bytearray(raw[8:]))
