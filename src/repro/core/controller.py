"""Adaptive controller (paper §3.3): sliding-window workload monitoring +
threshold-triggered (T, K) re-optimization with lazy adoption.

Operation classes map to the paper's coefficients:
  w — put_batch index inserts (writes)
  s — get_batch range scans
  r — probe point lookups that found an entry
  z — probe point lookups that found nothing (Bloom-pruned empty probes)
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional

from .costmodel import TreeShape, optimize

OP_WRITE = "w"
OP_RANGE = "s"
OP_READ = "r"
OP_EMPTY = "z"
_OPS = (OP_WRITE, OP_RANGE, OP_READ, OP_EMPTY)


@dataclass
class TuningEvent:
    op_count: int
    mix: dict
    T: int
    K: int
    predicted_cost: float


@dataclass
class AdaptiveController:
    """Observes the operation stream and retunes the LSM when the workload
    mix drifts (threshold detection à la CAMAL)."""

    lsm: object  # LSMTree (duck-typed: set_targets, buffer_bytes, n_entries)
    window: int = 4096
    threshold: float = 0.15  # L1 distance on the op-mix simplex
    min_ops_between_tunings: int = 512
    entry_bytes: int = 64
    avg_range_entries: float = 8.0
    t_max: int = 16
    enabled: bool = True
    _ops: Deque = field(default_factory=deque)
    _counts: dict = field(default_factory=lambda: {o: 0 for o in _OPS})
    _last_mix: Optional[dict] = None
    _since_tune: int = 0
    history: list = field(default_factory=list)

    def record(self, op: str, n: int = 1) -> None:
        if op not in self._counts:
            raise ValueError(f"unknown op class {op!r}")
        for _ in range(min(n, self.window)):
            self._ops.append(op)
            self._counts[op] += 1
            if len(self._ops) > self.window:
                old = self._ops.popleft()
                self._counts[old] -= 1
        self._since_tune += n
        if self.enabled and self._since_tune >= self.min_ops_between_tunings:
            if self._drifted():
                self.tune()

    def mix(self) -> dict:
        total = max(1, sum(self._counts.values()))
        return {o: self._counts[o] / total for o in _OPS}

    def _drifted(self) -> bool:
        if sum(self._counts.values()) < self.window // 4:
            return False
        if self._last_mix is None:
            return True
        cur = self.mix()
        l1 = sum(abs(cur[o] - self._last_mix[o]) for o in _OPS)
        return l1 > self.threshold

    def tune(self) -> Optional[TuningEvent]:
        """Re-optimize (T, K) from the current window and hand the targets to
        the LSM for lazy adoption."""
        cur = self.mix()
        shape = TreeShape(
            n_entries=max(1, self.lsm.n_entries),
            entry_bytes=self.entry_bytes,
            buffer_bytes=self.lsm.buffer_bytes,
        )
        best = optimize(
            shape,
            w=cur[OP_WRITE],
            s=cur[OP_RANGE],
            r=cur[OP_READ],
            z=cur[OP_EMPTY],
            t_max=self.t_max,
            avg_range_entries=self.avg_range_entries,
        )
        self.lsm.set_targets(best["T"], best["K"])
        self._last_mix = cur
        self._since_tune = 0
        ev = TuningEvent(
            op_count=sum(self._counts.values()),
            mix=cur,
            T=best["T"],
            K=best["K"],
            predicted_cost=best["cost"],
        )
        self.history.append(ev)
        return ev
