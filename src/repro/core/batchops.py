"""Serial reference implementations of the multi-sequence batch ops
(``probe_many`` / ``get_many`` / ``put_many``).

Lives in its own module so both ``backend`` (the protocol) and the
concrete stores can import it without a cycle.  ``ShardedKVBlockStore``
overrides these with parallel shard fan-out on an ``IOExecutor``.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


class BatchOpsMixin:
    """Loop-based multi-sequence ops; ``out[i]`` answers ``items[i]``."""

    def probe_many(self, seqs: Sequence[Sequence[int]]) -> List[int]:
        return [self.probe(t) for t in seqs]

    def get_many(self, items: Sequence[Tuple[Sequence[int], int]]) -> List[List[np.ndarray]]:
        return [self.get_batch(t, n) for t, n in items]

    def put_many(
        self, items: Sequence[Tuple[Sequence[int], Sequence[np.ndarray], int]]
    ) -> List[int]:
        return [self.put_batch(t, blocks, start_block=s) for t, blocks, s in items]
