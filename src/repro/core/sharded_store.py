"""``ShardedKVBlockStore`` — N independent LSM shards behind one
``StorageBackend``.

The monolithic ``KVBlockStore`` funnels every request through a single
memtable, WAL, tensor log, and controller, which serializes all index and
log I/O; the paper's scalability claim (bounded file counts and metadata
overhead as the footprint grows) extends naturally to partitioned storage —
the move enterprise KV-cache layers make (LMCache-style partitioned,
independently-compacted shards behind one interface).

Routing: a stable 64-bit hash of the **first block's tokens** picks the
shard.  Every extension of a prefix shares its first block, so a whole
prefix tree lands on one shard — probes, range scans, and block contiguity
stay shard-local, and the prefix-closure property each shard's binary
search relies on is preserved.  Divergent corpora (different first blocks,
e.g. different tenants' system prompts) spread across shards.

Each shard is a full ``KVBlockStore`` (own memtable, WAL, tensor log,
merge service, and ``AdaptiveController``), so shards tune their LSM
shapes to *their* traffic independently and never contend on a shared
commit point.

Maintenance is round-robin: each cycle compacts ``shards_per_cycle``
shards, bounding per-cycle compaction work to O(1) shards regardless of N
(the paper's "scheduled compaction cycles", now amortized across the
fleet).  The byte budget is global: eviction drains the largest-footprint
shard first, so pressure lands proportional to shard footprint rather than
uniformly punishing cold shards.

Parallel fan-out: the multi-sequence operations (``probe_many`` /
``get_many`` / ``put_many``) group sequences by shard and run the shard
groups concurrently on an ``IOExecutor`` (``io_threads`` constructor
argument, or a shared executor via ``io_executor``).  Shards are fully
independent stores, so the groups contend on nothing — this is the step
that converts sharding from a locality win into a throughput win.  The
maintenance cycle fans its ``shards_per_cycle`` shard cycles out the same
way.  With no executor (``io_threads=0``) every path degrades to the
serial loop.
"""

from __future__ import annotations

import hashlib
import json
import os
import struct
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..runtime.executor import IOExecutor
from .backend import merge_stats
from .keycodec import TOKEN_WIDTH, encode_tokens
from .store import KVBlockStore, StoreStats

_META_FILE = "shards.json"


def shard_of_key(key: bytes, block_size: int, n_shards: int) -> int:
    """Stable shard index for an already-encoded index key: hash of the
    first block's worth of bytes.  Keys are the big-endian token encoding,
    so ``key[:TOKEN_WIDTH * block_size]`` is exactly the first block —
    migration imports route without decoding tokens."""
    head = bytes(key[: TOKEN_WIDTH * block_size])
    return int.from_bytes(hashlib.blake2b(head, digest_size=8).digest(), "little") % n_shards


def shard_of(tokens: Sequence[int], block_size: int, n_shards: int) -> int:
    """Stable shard index for a token sequence: hash of the first block.

    Uses blake2b (not ``hash()``) so routing survives process restarts —
    a shard must find its own data after recovery.
    """
    head = encode_tokens(tokens[: min(block_size, len(tokens))])
    return int.from_bytes(hashlib.blake2b(head, digest_size=8).digest(), "little") % n_shards


class ShardedKVBlockStore:
    """N-way sharded LSM KV-cache store satisfying ``StorageBackend``."""

    name = "lsm-sharded"

    def __init__(
        self,
        root: str,
        n_shards: int = 4,
        block_size: int = 16,
        budget_bytes: Optional[int] = None,
        shards_per_cycle: int = 2,
        io_threads: int = 0,
        io_executor: Optional[IOExecutor] = None,
        fsync_writes: bool = False,
        **shard_kwargs,
    ):
        """``shard_kwargs`` are forwarded to every ``KVBlockStore`` shard
        (codec, buffer_bytes, vlog_file_bytes, adaptive, ...).  The byte
        budget is enforced globally here, never per shard.

        ``io_threads`` > 0 creates an owned ``IOExecutor`` for parallel
        shard fan-out (closed with the store); alternatively pass a shared
        ``io_executor`` (not closed here).  ``fsync_writes`` is plumbed to
        every shard: each shard fsyncs its tensor log before the WAL-backed
        index insert commits (two-phase durability ordering)."""
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.root = root
        os.makedirs(root, exist_ok=True)
        meta_path = os.path.join(root, _META_FILE)
        if os.path.exists(meta_path):
            with open(meta_path) as f:
                meta = json.load(f)
            if meta["n_shards"] != n_shards or meta["block_size"] != block_size:
                raise ValueError(
                    f"store at {root} was created with n_shards={meta['n_shards']}, "
                    f"block_size={meta['block_size']}; reopened with n_shards={n_shards}, "
                    f"block_size={block_size} — routing (first-block hash) would orphan data"
                )
        else:
            with open(meta_path, "w") as f:
                json.dump({"n_shards": n_shards, "block_size": block_size}, f)
        self.n_shards = n_shards
        self.block_size = block_size
        self.budget_bytes = budget_bytes
        self.shards_per_cycle = max(1, min(shards_per_cycle, n_shards))
        # Each shard observes ~1/N of the op stream, so its adaptive
        # controller needs a proportionally smaller window (and tuning
        # cadence) to react on the same wall-clock horizon as a monolithic
        # store — otherwise shards never reach the drift threshold and stay
        # pinned to the default leveling policy, over-compacting under
        # write-heavy traffic.
        window = shard_kwargs.pop("controller_window", 4096)
        shard_kwargs["controller_window"] = max(256, window // n_shards)
        self.shards: List[KVBlockStore] = [
            KVBlockStore(
                os.path.join(root, f"shard_{i:03d}"),
                block_size=block_size,
                budget_bytes=None,
                fsync_writes=fsync_writes,
                **shard_kwargs,
            )
            for i in range(n_shards)
        ]
        self.fsync_writes = fsync_writes
        if io_executor is not None:
            self._executor, self._owns_executor = io_executor, False
        elif io_threads > 0:
            self._executor = IOExecutor(max_workers=io_threads)
            self._owns_executor = True
        else:
            self._executor, self._owns_executor = None, False
        for s in self.shards:
            s.controller.min_ops_between_tunings = max(
                64, s.controller.min_ops_between_tunings // n_shards
            )
        self._rr = 0  # round-robin maintenance cursor

    def set_io_executor(self, executor: Optional[IOExecutor], own: bool = False) -> None:
        """Swap the fan-out executor (e.g. to share the serving runtime's
        pool, or for benchmark sweeps over thread counts).  Closes the
        previous executor if this store owned it."""
        if self._owns_executor and self._executor is not None and self._executor is not executor:
            self._executor.close()
        self._executor = executor
        self._owns_executor = bool(own and executor is not None)

    # --------------------------------------------------------------- routing
    def shard_for(self, tokens: Sequence[int]) -> KVBlockStore:
        return self.shards[shard_of(tokens, self.block_size, self.n_shards)]

    # ---------------------------------------------------------------- contract
    def put_batch(
        self,
        tokens: Sequence[int],
        blocks: Sequence[np.ndarray],
        start_block: int = 0,
        skip_existing: bool = True,
    ) -> int:
        return self.shard_for(tokens).put_batch(
            tokens, blocks, start_block=start_block, skip_existing=skip_existing
        )

    def probe(self, tokens: Sequence[int]) -> int:
        return self.shard_for(tokens).probe(tokens)

    def get_batch(self, tokens: Sequence[int], n_tokens: int) -> List[np.ndarray]:
        return self.shard_for(tokens).get_batch(tokens, n_tokens)

    def get_batch_raw(self, tokens: Sequence[int], n_tokens: int):
        """Sendfile-able extent for the sequence, if its shard has one
        (a prefix tree lives entirely on one shard, so this is a pure
        delegation)."""
        return self.shard_for(tokens).get_batch_raw(tokens, n_tokens)

    def get_batch_encoded(self, tokens: Sequence[int], n_tokens: int):
        """Encoded (still-compressed) payloads for the cached prefix —
        shard-local like every other per-sequence op."""
        return self.shard_for(tokens).get_batch_encoded(tokens, n_tokens)

    # ------------------------------------------------------- parallel fan-out
    def _shard_groups(self, seqs: Sequence[Sequence[int]]) -> Dict[int, List[int]]:
        """Map shard index -> positions in ``seqs`` routed to it."""
        groups: Dict[int, List[int]] = {}
        for pos, tokens in enumerate(seqs):
            groups.setdefault(shard_of(tokens, self.block_size, self.n_shards), []).append(pos)
        return groups

    def _fan_out(self, seqs: Sequence[Sequence[int]], per_item) -> list:
        """Run ``per_item(shard, position)`` for every sequence, grouped by
        shard; groups run in parallel on the executor (serial without one).
        Large groups are split into chunks so a hot shard (hash skew) does
        not become the fan-out's makespan — shards are thread-safe, so
        same-shard chunks may run concurrently.  Results are positional:
        ``out[i]`` answers item ``i``."""
        groups = self._shard_groups(seqs)
        out: list = [None] * len(seqs)

        def run_chunk(arg: Tuple[int, List[int]]) -> None:
            si, positions = arg
            shard = self.shards[si]
            for pos in positions:
                out[pos] = per_item(shard, pos)

        if self._executor is not None and len(seqs) > 1:
            # chunk for load balance: ~4 tasks per worker across the batch
            workers = max(1, self._executor.max_workers)
            chunk = max(1, len(seqs) // (4 * workers))
            tasks = [
                (si, positions[i : i + chunk])
                for si, positions in groups.items()
                for i in range(0, len(positions), chunk)
            ]
            self._executor.map_parallel(run_chunk, tasks)
        else:
            for item in groups.items():
                run_chunk(item)
        return out

    def probe_many(self, seqs: Sequence[Sequence[int]]) -> List[int]:
        return self._fan_out(seqs, lambda shard, pos: shard.probe(seqs[pos]))

    def get_many(self, items: Sequence[Tuple[Sequence[int], int]]) -> List[List[np.ndarray]]:
        return self._fan_out(
            [t for t, _ in items],
            lambda shard, pos: shard.get_batch(items[pos][0], items[pos][1]),
        )

    def put_many(
        self, items: Sequence[Tuple[Sequence[int], Sequence[np.ndarray], int]]
    ) -> List[int]:
        return self._fan_out(
            [t for t, _, _ in items],
            lambda shard, pos: shard.put_batch(items[pos][0], items[pos][1], start_block=items[pos][2]),
        )

    # ----------------------------------------------- key export (elasticity)
    # The cursor prefixes the inner shard cursor with a u16 shard index, so
    # the page stream walks shard 0's keyspace, then shard 1's, ... — still
    # a stable total order, which is all ``cluster.migration`` needs.

    def scan_keys(self, cursor: Optional[bytes] = None, limit: int = 1024):
        if cursor is None:
            si, inner = 0, None
        else:
            (si,) = struct.unpack(">H", bytes(cursor[:2]))
            inner = bytes(cursor[2:]) or None
        while si < self.n_shards:
            keys, nxt = self.shards[si].scan_keys(inner, limit)
            if nxt is not None:
                return keys, struct.pack(">H", si) + nxt
            if si + 1 < self.n_shards:
                if keys:
                    return keys, struct.pack(">H", si + 1)
                si, inner = si + 1, None
                continue
            return keys, None
        return [], None

    def export_encoded(self, keys: Sequence[bytes]):
        out: list = [None] * len(keys)
        groups: Dict[int, List[int]] = {}
        for pos, key in enumerate(keys):
            groups.setdefault(shard_of_key(key, self.block_size, self.n_shards), []).append(pos)
        for si, positions in groups.items():
            recs = self.shards[si].export_encoded([keys[p] for p in positions])
            for p, rec in zip(positions, recs):
                out[p] = rec
        return out

    def import_encoded(self, records, skip_existing: bool = True) -> int:
        groups: Dict[int, list] = {}
        for rec in records:
            groups.setdefault(
                shard_of_key(rec[0], self.block_size, self.n_shards), []
            ).append(rec)
        return sum(
            self.shards[si].import_encoded(recs, skip_existing=skip_existing)
            for si, recs in groups.items()
        )

    def maintenance(self, compact_steps: int = 8) -> dict:
        """One cycle: compact/merge the next ``shards_per_cycle`` shards
        (round-robin), then enforce the global budget.  The report carries
        the same top-level keys as the monolithic store (``compactions``,
        ``evicted_files``) plus a per-shard breakdown, so callers account
        for maintenance uniformly across backends."""
        rep: dict = {"compactions": 0, "shards": {}}
        cycle: List[int] = []
        for _ in range(self.shards_per_cycle):
            cycle.append(self._rr % self.n_shards)
            self._rr += 1
        # shards are independent engines: their compaction/merge cycles fan
        # out in parallel (each shard's maintenance serializes internally)
        def one(i: int) -> dict:
            return self.shards[i].maintenance(compact_steps)

        if self._executor is not None and len(cycle) > 1:
            reports = self._executor.map_parallel(one, cycle)
        else:
            reports = [one(i) for i in cycle]
        for i, srep in zip(cycle, reports):
            rep["shards"][i] = srep
            rep["compactions"] += srep.get("compactions", 0)
            tiering = srep.get("tiering")
            if tiering:
                agg = rep.setdefault(
                    "tiering", {"files": 0, "demoted_blocks": 0,
                                "bytes_before": 0, "bytes_after": 0})
                for k in agg:
                    agg[k] += tiering.get(k, 0)
        if self.budget_bytes is not None:
            rep["evicted_files"] = self._evict_to_budget()
        return rep

    def _evict_to_budget(self) -> int:
        """Global FIFO eviction, heaviest shard first: repeatedly drop the
        oldest tensor-log file of the largest-footprint shard until the
        aggregate is under budget.  Footprint-proportional by construction —
        a shard holding k× the bytes absorbs ~k× the evictions."""
        evicted = 0
        while self.disk_bytes > self.budget_bytes:
            # heaviest shard first, but fall through to lighter shards when
            # the heaviest is down to its active file (it can't evict, yet
            # others may still hold sealed files)
            for s in sorted(self.shards, key=lambda s: s.disk_bytes, reverse=True):
                if s.evict_oldest_file():
                    evicted += 1
                    break
            else:
                break  # every shard is down to its active file
        return evicted

    # ------------------------------------------------------------ lifecycle
    def flush(self) -> None:
        for s in self.shards:
            s.flush()

    def sync_wal(self) -> None:
        for s in self.shards:
            s.sync_wal()

    def close(self) -> None:
        if self._owns_executor and self._executor is not None:
            self._executor.close()
        for s in self.shards:
            s.close()

    # ----------------------------------------------------------------- stats
    @property
    def stats(self) -> StoreStats:
        return merge_stats(s.stats for s in self.shards)

    @property
    def disk_bytes(self) -> int:
        return sum(s.disk_bytes for s in self.shards)

    @property
    def file_count(self) -> int:
        return sum(s.file_count for s in self.shards)

    def shard_disk_bytes(self) -> List[int]:
        return [s.disk_bytes for s in self.shards]

    def shard_file_counts(self) -> List[int]:
        return [s.file_count for s in self.shards]

    def per_shard_stats(self) -> Dict[int, StoreStats]:
        return {i: s.stats for i, s in enumerate(self.shards)}

    @property
    def write_amplification(self) -> float:
        """Aggregate LSM write amplification across shard indexes."""
        cin = sum(s.index.stats.compact_bytes_in for s in self.shards)
        cout = sum(s.index.stats.compact_bytes_out for s in self.shards)
        return cout / max(1, cin)
