"""Auto-resuming train loop.

Fault-tolerance contract:
  * resume: on start, restore the newest *committed* checkpoint (atomic
    manifest rename — see checkpoint.py) and replay the deterministic data
    stream from that step;
  * periodic checkpoints + pruning;
  * a ``crash_after`` hook lets tests kill the loop mid-run and assert the
    restart reproduces the uninterrupted loss trajectory exactly.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from ..models import api
from . import checkpoint as ckpt
from . import optim
from .data import DataConfig, SyntheticLM


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    seed: int = 0


def make_train_step(cfg, ocfg: optim.OptimizerConfig):
    lfn = api.loss_fn(cfg)

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(lfn, has_aux=True)(params, batch)
        params, opt_state, om = optim.apply_updates(ocfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **parts, **om}

    return step_fn


def train(
    model_cfg,
    tcfg: TrainConfig,
    ocfg: Optional[optim.OptimizerConfig] = None,
    shardings=None,
    crash_after: Optional[int] = None,
    log: Callable[[str], None] = print,
) -> Dict:
    """Run (or resume) training.  Returns {step, losses, resumed_from}."""
    ocfg = ocfg or optim.OptimizerConfig(
        total_steps=tcfg.steps, warmup_steps=max(1, min(100, tcfg.steps // 10))
    )
    data = SyntheticLM(
        DataConfig(model_cfg.vocab_size, seq_len=128, global_batch=8, seed=tcfg.seed)
    )
    step_fn = make_train_step(model_cfg, ocfg)

    start = ckpt.latest_step(tcfg.ckpt_dir)
    if start is not None:
        like = {
            "params": api.init_params(model_cfg, jax.random.key(tcfg.seed)),
            "opt": None,
        }
        like["opt"] = optim.init_state(ocfg, like["params"])
        state, manifest = ckpt.restore(tcfg.ckpt_dir, start, like, shardings)
        params, opt_state = state["params"], state["opt"]
        step0 = start
        log(f"[train] resumed from step {start}")
    else:
        params = api.init_params(model_cfg, jax.random.key(tcfg.seed))
        opt_state = optim.init_state(ocfg, params)
        step0 = 0

    losses: List[float] = []
    for step in range(step0, tcfg.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in data.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % tcfg.log_every == 0 or step == step0:
            log(f"[train] step {step + 1} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f}")
        losses.append(float(metrics["loss"]))
        if (step + 1) % tcfg.ckpt_every == 0 or step + 1 == tcfg.steps:
            ckpt.save(tcfg.ckpt_dir, step + 1, {"params": params, "opt": opt_state})
            ckpt.prune(tcfg.ckpt_dir, tcfg.keep)
        if crash_after is not None and step + 1 >= crash_after:
            return {"step": step + 1, "losses": losses, "resumed_from": step0, "crashed": True}
    return {"step": tcfg.steps, "losses": losses, "resumed_from": step0, "crashed": False}
