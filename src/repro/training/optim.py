"""In-repo optimizer: AdamW with production memory knobs.

Two distributed-scale options (used by the 1T-param kimi-k2 config, where
fp32 moments alone would be 8 TB):

  * ``moment_dtype`` — store the first moment in bf16 (stochastic-rounding
    -free variant; the fp32 master math happens in-register per step).
  * ``factored_second_moment`` — Adafactor-style row/col factorization of v
    for >=2D parameters: O(n+m) state instead of O(n*m).

Optimizer state inherits the parameter sharding (ZeRO: moments are sharded
exactly like their parameter, so they never replicate).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"  # float32 | bfloat16
    factored_second_moment: bool = False


def lr_schedule(cfg: OptimizerConfig, step):
    """Linear warmup + cosine decay to ``min_lr_frac``."""
    step = step.astype(F32)
    warm = cfg.lr * step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def _is_factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 2 and shape[-2] >= 2


def init_state(cfg: OptimizerConfig, params) -> Dict:
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else F32

    def leaf_m(p):
        return jnp.zeros(p.shape, mdt)

    def leaf_v(p):
        if cfg.factored_second_moment and _is_factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], F32),  # row stat (sum over last dim)
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32),  # col stat
            }
        return {"v": jnp.zeros(p.shape, F32)}

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(leaf_m, params),
        "v": jax.tree.map(leaf_v, params, is_leaf=lambda x: hasattr(x, "shape")),
    }


def state_specs(cfg: OptimizerConfig, param_specs) -> Dict:
    """ShapeDtypeStruct tree mirroring ``init_state`` (dry-run path)."""
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else F32

    def leaf_m(p):
        return jax.ShapeDtypeStruct(p.shape, mdt)

    def leaf_v(p):
        if cfg.factored_second_moment and _is_factored(p.shape):
            return {
                "vr": jax.ShapeDtypeStruct(p.shape[:-1], F32),
                "vc": jax.ShapeDtypeStruct(p.shape[:-2] + p.shape[-1:], F32),
            }
        return {"v": jax.ShapeDtypeStruct(p.shape, F32)}

    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(leaf_m, param_specs),
        "v": jax.tree.map(leaf_v, param_specs, is_leaf=lambda x: hasattr(x, "shape")),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(F32))) for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    nrm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(nrm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype), grads), nrm


def _update_leaf(cfg: OptimizerConfig, lr, t, p, g, m, v):
    b1, b2 = cfg.betas
    gf = g.astype(F32)
    m_new = b1 * m.astype(F32) + (1 - b1) * gf
    if "v" in v:
        v_new = {"v": b2 * v["v"] + (1 - b2) * gf * gf}
        v_hat = v_new["v"] / (1 - b2**t)
    else:
        g2 = gf * gf
        v_new = {
            "vr": b2 * v["vr"] + (1 - b2) * g2.mean(axis=-1),
            "vc": b2 * v["vc"] + (1 - b2) * g2.mean(axis=-2),
        }
        # rank-1 reconstruction: vr ⊗ vc / mean(vc)
        denom = jnp.maximum(v_new["vc"].mean(axis=-1, keepdims=True), 1e-30)
        v_hat = (v_new["vr"][..., None] * v_new["vc"][..., None, :] / denom[..., None]) / (1 - b2**t)
    m_hat = m_new / (1 - b1**t)
    upd = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
    if p.ndim >= 2:  # decoupled weight decay on matrices only
        upd = upd + cfg.weight_decay * p.astype(F32)
    p_new = (p.astype(F32) - lr * upd).astype(p.dtype)
    return p_new, m_new.astype(m.dtype), jax.tree.map(lambda a, b: b, v, v_new)


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    t = step.astype(F32)
    lr = lr_schedule(cfg, step)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    # v leaves are dicts; flatten at the dict level
    v_subtrees = jax.tree.flatten(
        state["v"], is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    )[0]

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, v_subtrees):
        pn, mn, vn = _update_leaf(cfg, lr, t, p, g, m, v)
        new_p.append(pn)
        new_m.append(mn)
        new_v.append(vn)

    params_out = jax.tree.unflatten(treedef, new_p)
    state_out = {
        "step": step,
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
    }
    return params_out, state_out, {"grad_norm": gnorm, "lr": lr}
