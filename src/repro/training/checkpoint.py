"""Sharded checkpointing with atomic commit and elastic restore.

Layout::

    <dir>/step_000123/
        shard_00000.npz ... shard_NNNNN.npz   (one per checkpoint shard)
        MANIFEST.json                          (atomic commit marker)

Writes go to ``step_XXX.tmp/`` and are renamed into place only after the
manifest is fully written — a crash mid-checkpoint leaves no half-valid
step, and ``latest_step`` only ever sees committed checkpoints (the train
loop's auto-resume contract).

Elastic restore: arrays are stored per-leaf (container-scale checkpoints
fit a host); ``restore`` re-device_puts every leaf under the *current*
mesh's shardings, so a checkpoint taken on one mesh shape restores onto
any other (tested 2x2 -> 4x1 and 1-pod -> 2-pod smoke meshes).  At real
scale the same manifest format extends to per-shard files keyed by
PartitionSpec — the commit protocol is the part that matters.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Any], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None) -> str:
    """Atomically persist ``tree`` (params/opt_state/metadata pytree)."""
    leaves, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    arrs = {}
    for i, leaf in enumerate(leaves):
        a = np.asarray(leaf)
        if a.dtype.name == "bfloat16":  # npz can't store bf16 natively
            arrs[f"leaf_{i:05d}__bf16"] = a.view(np.uint16)
        else:
            arrs[f"leaf_{i:05d}"] = a
    np.savez(os.path.join(tmp, "shard_00000.npz"), **arrs)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "extra": extra or {},
        "format": 1,
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest *committed* step (tmp dirs and manifest-less dirs ignored)."""
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        if not os.path.exists(os.path.join(ckpt_dir, name, "MANIFEST.json")):
            continue
        s = int(name.split("_")[1])
        best = s if best is None else max(best, s)
    return best


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore a pytree saved by ``save``.  ``like`` supplies the treedef
    (and dtypes); ``shardings`` (optional pytree of NamedSharding) places
    every leaf for the current mesh — elastic resharding is just this
    placement, since leaves are stored whole."""
    import ml_dtypes

    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_00000.npz"))
    leaves_like, treedef = _flatten(like)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves_like)}"
        )
    out = []
    for i in range(len(leaves_like)):
        if f"leaf_{i:05d}__bf16" in data:
            a = data[f"leaf_{i:05d}__bf16"].view(ml_dtypes.bfloat16)
        else:
            a = data[f"leaf_{i:05d}"]
        out.append(a)
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, manifest


def prune(ckpt_dir: str, keep: int = 3) -> None:
    steps = sorted(
        int(n.split("_")[1])
        for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, n, "MANIFEST.json"))
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"))
