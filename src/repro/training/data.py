"""Deterministic, checkpointable data pipeline.

Batches are a pure function of (seed, step): resuming from a checkpoint at
step k replays exactly the batches k, k+1, ... with no iterator state to
persist beyond the step counter — the property the auto-resume train loop
and the elastic-restore tests rely on.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLM:
    """Seeded synthetic LM stream: shifted-token prediction over structured
    random sequences (mixture of repeated motifs so the loss is learnable)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # motifs are fixed per seed (not per step) so the stream is learnable
        self._motifs = np.random.default_rng(cfg.seed).integers(
            0, cfg.vocab_size, size=(8, 32)
        )

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed << 20) ^ step)
        motifs = self._motifs
        rows = []
        for _ in range(c.global_batch):
            parts = []
            while sum(len(p) for p in parts) < c.seq_len + 1:
                if rng.random() < 0.7:
                    parts.append(motifs[rng.integers(0, len(motifs))])
                else:
                    parts.append(rng.integers(0, c.vocab_size, size=16))
            row = np.concatenate(parts)[: c.seq_len + 1]
            rows.append(row)
        arr = np.stack(rows).astype(np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
