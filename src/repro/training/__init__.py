"""Training runtime: in-repo optimizer, data pipeline, sharded
checkpointing with elastic restore, and the auto-resuming train loop."""
