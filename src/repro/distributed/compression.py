"""Error-feedback int8 gradient compression for the data-parallel axis.

At 1000+-node scale the DP gradient all-reduce crosses the slowest links
(DCI between pods); int8 compression cuts those bytes 4x vs f32 (2x vs
bf16).  Error feedback keeps the quantization noise unbiased over steps:

    e_t      accumulated residual (f32, sharded like the grad)
    g'_t     = g_t + e_t
    q_t      = int8(g'_t)  per-tensor scale
    e_{t+1}  = g'_t - dequant(q_t)
    update   uses mean_dp(dequant(q_t))

The compressed all-reduce is expressed with shard_map + psum over the DP
axes so the int8 <-> f32 conversion happens inside the per-device block and
XLA emits the collective on the quantized tensor.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

F32 = jnp.float32


def quantize_tensor(g) -> Tuple[jnp.ndarray, jnp.ndarray]:
    gf = g.astype(F32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_tensor(q, scale):
    return q.astype(F32) * scale


def compress_grads(grads, residuals):
    """Error-feedback quantization. Returns (q_tree, scale_tree, new_residuals)."""

    def leaf(g, e):
        gf = g.astype(F32) + e
        q, s = quantize_tensor(gf)
        return q, s, gf - dequantize_tensor(q, s)

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(residuals)
    qs, ss, es = zip(*(leaf(g, e) for g, e in zip(flat, eflat)))
    return (
        jax.tree.unflatten(treedef, qs),
        jax.tree.unflatten(treedef, ss),
        jax.tree.unflatten(treedef, es),
    )


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


def allreduce_compressed(mesh, grads, residuals, axes=("pod", "data")):
    """Mean-all-reduce grads over ``axes`` with int8 error feedback.

    Each leaf is quantized against (grad + residual), psum'd as int8-widened
    i32 partial sums, and dequantized with the mean scale — the wire format
    is the int8 payload + one f32 scale per leaf.
    """
    live = tuple(a for a in axes if a in mesh.axis_names)
    if not live:
        return grads, residuals
    n = 1
    for a in live:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]

    q_tree, s_tree, new_res = compress_grads(grads, residuals)

    def reduce_leaf(q, s):
        # max-scale requantization: all devices agree on s_max (pmax of a
        # scalar), rescale their int payload to it (values stay <= 127),
        # and psum the ints — the wire carries 1-byte lanes + one scalar.
        # (mean-of-scales x mean-of-ints is NOT mean of products; measured
        # 13% error — see tests/test_compression_e2e.py)
        s_max = jax.lax.pmax(s, live)
        qr = jnp.round(q.astype(F32) * (s / s_max))
        qsum = jax.lax.psum(qr.astype(jnp.int32), live)
        return qsum.astype(F32) * (s_max / n)

    def spmd(q_tree, s_tree):
        return jax.tree.map(reduce_leaf, q_tree, s_tree)

    from jax.experimental.shard_map import shard_map

    # grads arrive replicated over the model axis and sharded over DP axes
    # as produced by the backward pass; shard_map with full-replication
    # in/out specs keeps leaf shapes intact while exposing the axes to psum.
    fn = shard_map(
        spmd,
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=P(),
        check_rep=False,
    )
    reduced = fn(q_tree, s_tree)
    return reduced, new_res
