"""Training-side JAX distribution: sharding rules (DP/FSDP/TP/EP/SP),
gradient compression, and collective helpers for the model zoo.

Naming note: despite the name, this package has nothing to do with
*cache* distribution.  It shards model **parameters and activations**
across JAX device meshes inside one training/serving job.  Distributing
the KV *cache* across processes/nodes — socket-served cache nodes,
consistent-hash routing, replication — lives in ``repro.cluster``
(see ``docs/ARCHITECTURE.md``)."""
