"""Distribution layer: sharding rules (DP/FSDP/TP/EP/SP), gradient
compression, and collective helpers."""
