"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

The assignment's production mesh is 2-axis (data, model), so PP is not part
of the default dry-run config; this module demonstrates the capability for
larger deployments (DESIGN.md §5): layer blocks are sharded one-per-stage,
microbatches stream through a ``ppermute`` ring inside ``shard_map``, and
the schedule is the standard (n_micro + n_stages - 1)-step fill/drain.

All stages execute every step (SPMD); bubble steps compute on zeros and
their results are masked out — the classic JAX pipeline formulation.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def gpipe(
    stage_fn: Callable,
    mesh: Mesh,
    axis: str = "stage",
):
    """Build a pipelined apply: ``f(stage_params, xs) -> ys``.

    stage_fn(params_one_stage, x) -> y   (same shape as x)
    stage_params: pytree with leading [n_stages] dim on every leaf
    xs: (n_micro, mb, ...) microbatches; ys: same shape, after all stages.
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def spmd(params, xs):
        # params: this stage's slice, leading dim 1; xs fully replicated
        local = jax.tree.map(lambda p: p[0], params)
        idx = jax.lax.axis_index(axis)
        n_micro = xs.shape[0]
        steps = n_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def step(t, carry):
            buf, outs = carry
            # stage 0 consumes microbatch t (zeros once drained)
            feed_idx = jnp.clip(t, 0, n_micro - 1)
            x0 = jax.lax.dynamic_index_in_dim(xs, feed_idx, 0, keepdims=False)
            x0 = jnp.where(t < n_micro, x0, jnp.zeros_like(x0))
            x_in = jnp.where(idx == 0, x0, buf)
            y = stage_fn(local, x_in)
            # last stage emits microbatch t - (n_stages - 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (idx == n_stages - 1) & (t >= n_stages - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, out_idx, 0, keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(emit, y, cur), out_idx, 0
            )
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        _, outs = jax.lax.fori_loop(0, steps, step, (buf0, outs0))
        # outputs accumulated on the last stage only; broadcast via psum of
        # the masked buffers (zeros elsewhere)
        outs = jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    fn = shard_map(
        spmd,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn


def pipeline_stage_params(params_stacked, n_stages: int):
    """Validate a [L, ...]-stacked block tree splits evenly into stages and
    reshape to [n_stages, L/n_stages, ...] (stage-major)."""

    def leaf(p):
        L = p.shape[0]
        assert L % n_stages == 0, f"layers {L} % stages {n_stages} != 0"
        return p.reshape(n_stages, L // n_stages, *p.shape[1:])

    return jax.tree.map(leaf, params_stacked)
