"""Training-side sharding rules: parameter / batch / decode-cache
PartitionSpecs for every architecture family, mesh-shape agnostic.
(Device-mesh sharding of model state — not the KV-cache disk tier;
cross-process cache sharding is ``repro.cluster``.)

Strategy (DESIGN.md §5):
  * TP over ``model``: attention heads, ffn hidden, expert dim, vocab.
  * ZeRO-3/FSDP over ``data`` (and ``pod`` when present for the largest
    archs): the non-TP matrix dim of every weight; optimizer moments
    inherit the parameter spec exactly.
  * Batch over ``(pod, data)``.
  * Decode KV caches: heads over ``model`` when divisible, else the KV
    sequence axis over ``model`` (flash-decoding style partial softmax).

Rules are matched on the parameter's tree path (joined with '/'), longest
match wins; every spec is filtered against the live mesh's axis names so the
same rules serve the 1-pod (data, model) and 2-pod (pod, data, model)
meshes.
"""

from __future__ import annotations

import re
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

BATCH = ("pod", "data")


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def filter_spec(spec: P, mesh: Mesh, shape: Optional[Tuple[int, ...]] = None) -> P:
    """Drop axis names the mesh lacks; drop axes that don't divide the dim."""
    names = set(mesh.axis_names)
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        parts = entry if isinstance(entry, (tuple, list)) else (entry,)
        kept = [a for a in parts if a in names]
        if shape is not None and kept:
            # keep the largest prefix of axes whose product divides the dim
            prod = 1
            ok = []
            for a in kept:
                prod *= _axis_size(mesh, a)
                if shape[i] % prod == 0:
                    ok.append(a)
                else:
                    prod //= _axis_size(mesh, a)
            kept = ok
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


# --------------------------------------------------------------- param rules
# (regex on 'path', ndim-adjusted PartitionSpec builder). Specs are written
# for the UNSTACKED parameter; a leading layer-stack axis is auto-prepended.
# "fsdp" is substituted with the configured ZeRO axis set.

_RULES: Sequence[Tuple[str, Tuple]] = (
    # embeddings: vocab over model, d_model over fsdp
    (r"(^|/)embed$", ("model", "fsdp")),
    (r"(^|/)lm_head$", ("fsdp", "model")),
    # MoE experts (E, D, F) / (E, F, D): expert dim over model (EP)
    (r"moe/w_(gate|up)$", ("model", "fsdp", None)),
    (r"moe/w_down$", ("model", None, "fsdp")),
    (r"moe/router$", (None, None)),
    # MLA: latent ranks are small; shard the head-expanded dim over model
    (r"wq_a$", ("fsdp", None)),
    (r"wq_b$", ("fsdp", "model")),
    (r"wkv_a$", ("fsdp", None)),
    (r"w[kv]_b$", (None, "model")),
    # attention in-projections: heads over model
    (r"(attn|self_attn|cross)/w[qkv]$", ("fsdp", "model")),
    (r"(attn|self_attn|cross)/wo$", ("model", "fsdp")),
    (r"(^|/)wo$", ("model", "fsdp")),
    (r"b[qkv]$", ("model",)),
    # MLP: hidden over model
    (r"mlp/w_(gate|up)$", ("fsdp", "model")),
    (r"mlp/w_down$", ("model", "fsdp")),
    # RWKV6 time-mix: square (D,D) — out dim over model; wo back
    (r"time/w[rkvg]$", ("fsdp", "model")),
    (r"time/lora_a$", (None, "fsdp", None)),
    (r"time/lora_b$", (None, None, "fsdp")),
    # RWKV6 channel-mix
    (r"chan/wk$", ("fsdp", "model")),
    (r"chan/wv$", ("model", "fsdp")),
    (r"chan/wr$", ("fsdp", "model")),
    # Mamba2: inner dim over model
    (r"mamba/w_in$", ("fsdp", "model")),
    (r"mamba/w_out$", ("model", "fsdp")),
    (r"mamba/conv_w$", (None, "model")),
    (r"mamba/conv_b$", ("model",)),
    (r"mamba/norm_w$", ("model",)),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec_for(path_str: str, ndim: int, stacked: bool, fsdp_axes: Tuple[str, ...]) -> P:
    def expand(entry):
        if entry == "fsdp":
            return fsdp_axes if len(fsdp_axes) != 1 else fsdp_axes[0]
        return entry

    for pat, spec in _RULES:
        if re.search(pat, path_str):
            body = tuple(expand(e) for e in spec)
            if stacked and len(body) == ndim - 1:
                return P(None, *body)
            if len(body) == ndim:
                return P(*body)
            # rank mismatch (e.g. bias rules vs stacked): pad on the left
            if len(body) < ndim:
                return P(*((None,) * (ndim - len(body)) + body))
    return P()  # replicated (norms, scalars, small tables)


def param_shardings(mesh: Mesh, param_specs, fsdp_axes: Tuple[str, ...] = ("data",)):
    """ShapeDtypeStruct (or array) tree -> NamedSharding tree."""

    def leaf(path, x):
        ps = _path_str(path)
        stacked = "blocks" in ps or "enc_blocks" in ps or "dec_blocks" in ps
        spec = param_spec_for(ps, len(x.shape), stacked, fsdp_axes)
        spec = filter_spec(spec, mesh, x.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, param_specs)


def opt_state_shardings(mesh: Mesh, opt_specs, p_shardings):
    """Optimizer state inherits parameter sharding (ZeRO: moments are
    sharded exactly like their parameter); factored-v stats get the
    parameter spec minus the factored-out dim; step is replicated."""

    def _param_sharding(ppath):
        sub = p_shardings
        for k in ppath:
            sub = sub[k.key if hasattr(k, "key") else k.idx]
        return sub

    m_shardings = jax.tree_util.tree_map_with_path(
        lambda path, x: _param_sharding(path), opt_specs["m"]
    )

    def v_leaf(path, x):
        psh = _param_sharding(path[:-1])
        entries = list(psh.spec) + [None] * (len(x.shape) + 1 - len(psh.spec))
        kind = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if kind == "v":
            return psh
        if kind == "vr":  # param spec minus last dim
            return NamedSharding(mesh, filter_spec(P(*entries[: len(x.shape)]), mesh, x.shape))
        # vc: param spec minus second-to-last dim
        spec = P(*(entries[: len(x.shape) - 1] + [entries[len(x.shape)]]))
        return NamedSharding(mesh, filter_spec(spec, mesh, x.shape))

    return {
        "step": NamedSharding(mesh, P()),
        "m": m_shardings,
        "v": jax.tree_util.tree_map_with_path(v_leaf, opt_specs["v"]),
    }


# --------------------------------------------------------------- batch/cache
def batch_shardings(mesh: Mesh, specs):
    def leaf(x):
        spec = P(BATCH) if len(x.shape) >= 1 else P()
        return NamedSharding(mesh, filter_spec(spec, mesh, x.shape))

    return jax.tree.map(leaf, specs)


_ATTN_CACHE = {"k", "v", "attn_k", "attn_v", "self_k", "self_v", "cross_k", "cross_v"}


def cache_shardings(mesh: Mesh, cache_specs, cfg):
    """KV/state cache sharding for decode, dispatched on the leaf name:

      k/v-style      (L, B, S, KVH, Dh) — batch over (pod,data); heads over
                     model when divisible, else the KV sequence axis
                     (flash-decoding partial softmax)
      c / kr (MLA)   (L, B, S, r)       — batch + sequence over model
      wkv / ssm      (L, B, H, ...)     — batch + heads over model
      *_shift        (L, B, D)          — batch + channels over model
      conv           (L, B, K-1, C)     — batch + channels over model
    """
    model = _axis_size(mesh, "model")

    def leaf(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        spec, check_shape = cache_spec_for(name, x.shape, model)
        return NamedSharding(mesh, filter_spec(spec, mesh, check_shape))

    return jax.tree_util.tree_map_with_path(leaf, cache_specs)


def cache_spec_for(name: str, shape, model: int):
    """Pure rule: (leaf name, shape, TP degree) -> (PartitionSpec,
    shape-to-check-divisibility-or-None)."""
    nd = len(shape)
    check_shape = shape  # filter axes that don't divide, unless uneven is intended
    entries = [None] * nd
    if nd >= 2:
        entries[1] = BATCH
    if True:
        if name in _ATTN_CACHE and nd == 5:
            # Never shard the cache SEQ axis: writing one token at a traced
            # position into a seq-sharded cache lowers to a masked
            # full-buffer rewrite per layer (GSPMD "involuntary full
            # rematerialization") — it dominated the decode memory term
            # (EXPERIMENTS §Perf).  Prefer KV heads; when they don't divide
            # the TP degree, shard the head-dim instead (the q·k contraction
            # all-reduces one small score chunk, and the update stays local).
            if shape[3] % model == 0 and model > 1:
                entries[3] = "model"
            elif shape[4] % model == 0 and model > 1:
                entries[4] = "model"  # head-dim sharding (contraction axis)
            else:
                entries[2] = "model"  # sequence-parallel decode (last resort)
        elif name in ("c", "kr") and nd == 4:
            # same seq-DUS hazard as k/v: prefer the latent dim
            if shape[3] % model == 0 and model > 1:
                entries[3] = "model"
            else:
                entries[2] = "model"
        elif name in ("wkv", "ssm") and nd == 5:
            entries[2] = "model"
        elif name in ("time_shift", "chan_shift") and nd == 3:
            entries[2] = "model"
        elif name == "conv" and nd == 4:
            entries[3] = "model"
    return P(*entries), check_shape
