"""Recurrent mixers: RWKV-6 'Finch' time/channel mix (data-dependent decay,
arXiv:2404.05892) and Mamba-2 SSD (arXiv:2405.21060).

Both expose a *sequence* form (lax.scan over time — the pure-jnp oracle for
the Pallas chunked kernel) and a *single-step* form used by decode.  State
shapes are the objects the LSM store snapshots for prefix reuse
(DESIGN.md §4: attention-free archs cache state snapshots, not token KV).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import BATCH_AXES, MODEL_AXIS, Spec, constrain

F32 = jnp.float32
LORA_R = 32  # rank of the data-dependent interpolation MLPs (RWKV6 ddlerp)


# ---------------------------------------------------------------- RWKV-6
def build_rwkv6_template(cfg) -> Dict:
    D = cfg.d_model
    H, N = cfg.n_heads, cfg.d_head
    return {
        "time": {
            # token-shift interpolation: static mus + low-rank data-dependent
            "mu": Spec((5, D), init="small"),  # r,k,v,g,w
            "lora_a": Spec((5, D, LORA_R), init="small"),
            "lora_b": Spec((5, LORA_R, D), init="zeros"),
            "w0": Spec((D,), init="small"),  # decay bias
            "wr": Spec((D, D)),
            "wk": Spec((D, D)),
            "wv": Spec((D, D)),
            "wg": Spec((D, D)),
            "wo": Spec((D, D)),
            "u": Spec((H, N), init="small"),  # bonus for current token
            "ln_w": Spec((H, N), init="ones"),  # per-head group norm
            "ln_b": Spec((H, N), init="zeros"),
        },
        "chan": {
            "mu_k": Spec((D,), init="small"),
            "mu_r": Spec((D,), init="small"),
            "wk": Spec((D, cfg.d_ff)),
            "wv": Spec((cfg.d_ff, D)),
            "wr": Spec((D, D)),
        },
    }


def _ddlerp(p, x, x_prev):
    """RWKV6 data-dependent lerp: 5 mixed views of (x, shifted x)."""
    diff = x_prev - x  # (B,S,D)
    mixed = x[:, :, None, :] + diff[:, :, None, :] * p["mu"][None, None, :, :]
    lora = jnp.einsum("bsfd,fdr->bsfr", jnp.tanh(mixed), p["lora_a"])
    dyn = jnp.einsum("bsfr,frd->bsfd", lora, p["lora_b"])
    out = x[:, :, None, :] + diff[:, :, None, :] * (p["mu"][None, None] + dyn)
    return [out[:, :, i, :] for i in range(5)]


# Chunked scans: per-token log-decay is clamped at -_LOG_CLAMP/chunk so the
# within-chunk inverse-decay factor exp(-cum) stays finite in f32.  A channel
# decaying faster than e^-80 per chunk has forgotten its state to below f32
# resolution anyway, so the clamp is semantically free.
_LOG_CLAMP = 80.0


def wkv_chunked(r, k, v, w, u, state, chunk: int = 16):
    """Chunked RWKV6 WKV (FLA-style closed form) — same math as the
    sequential scan but O(S/chunk) state round-trips and matmul-shaped
    intra-chunk work.  r/k/v/w (B,S,H,N) f32; u (H,N); state (B,H,N,N) f32.
    Returns (y (B,S,H,N) f32, state')."""
    B, S, H, N = r.shape
    pad = (-S) % chunk
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, zp)
        k = jnp.pad(k, zp)
        v = jnp.pad(v, zp)
        w = jnp.pad(w, zp, constant_values=1.0)  # no-op steps
    Sp = S + pad
    nc = Sp // chunk

    # NOTE(perf, refuted hypothesis): forcing H over the model axis here
    # adds collective-permutes inside the chunk loop (+60% collective term)
    # with no memory win — the projections' natural D-sharding already
    # propagates through the reshape.  Keep propagation-driven sharding.
    def resh(t):
        return t.reshape(B, nc, chunk, H, N).transpose(1, 0, 2, 3, 4).astype(F32)

    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)
    logw = jnp.maximum(jnp.log(jnp.maximum(wc, 1e-38)), -_LOG_CLAMP / chunk)
    cum = jnp.cumsum(logw, axis=2)  # (nc,B,C,H,N) inclusive
    cum_prev = cum - logw
    ti = jnp.arange(chunk)
    lower = ti[:, None] > ti[None, :]  # strict j < t

    def chunk_step(s, inp):
        rb, kb, vb, cumb, cumpb = inp  # (B,C,H,N) each
        r_dec = rb * jnp.exp(cumpb)  # exponent <= 0
        k_inv = kb * jnp.exp(-cumb)  # bounded by the clamp
        y_inter = jnp.einsum("bchn,bhnm->bchm", r_dec, s)
        A = jnp.einsum("bthn,bjhn->bhtj", r_dec, k_inv)
        A = jnp.where(lower[None, None], A, 0.0)
        y_intra = jnp.einsum("bhtj,bjhm->bthm", A, vb)
        diag = jnp.sum(rb * u[None, None] * kb, axis=-1)  # (B,C,H)
        y = y_inter + y_intra + diag[..., None] * vb
        cum_last = cumb[:, -1]  # (B,H,N)
        k_rem = kb * jnp.exp(cum_last[:, None] - cumb)
        s_new = jnp.exp(cum_last)[..., None] * s + jnp.einsum("bchn,bchm->bhnm", k_rem, vb)
        return s_new, y

    s_new, ys = jax.lax.scan(chunk_step, state.astype(F32), (rc, kc, vc, cum, cum_prev))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, N)
    return y[:, :S], s_new


def rwkv6_time_mix(p, cfg, x, state: Tuple):
    """x (B,S,D); state = (shift (B,D), wkv (B,H,N,N)).  Returns out + new
    state.  S==1 steps sequentially; longer sequences use the chunked
    closed form (bounded backward residuals)."""
    B, S, D = x.shape
    H, N = cfg.n_heads, cfg.d_head
    shift, wkv = state
    x_prev = jnp.concatenate([shift[:, None, :], x[:, :-1, :]], axis=1)
    xr, xk, xv, xg, xw = _ddlerp(p, x, x_prev)
    r = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(B, S, H, N)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(B, S, H, N)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"]).reshape(B, S, H, N)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"]))
    # data-dependent decay w in (0,1): exp(-exp(w0 + dyn))
    w = jnp.exp(-jnp.exp((p["w0"][None, None] + xw).astype(F32))).reshape(B, S, H, N)

    u = p["u"].astype(F32)

    if S > 1:
        y, wkv_new = wkv_chunked(r, k, v, w, u, wkv.astype(F32))
    else:

        def step(s, inp):
            rt, kt, vt, wt = inp  # (B,H,N) each
            kv = kt[..., :, None] * vt[..., None, :]  # (B,H,N,N)
            y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
            s_new = wt[..., :, None] * s + kv
            return s_new, y

        xs = (
            r.transpose(1, 0, 2, 3).astype(F32),
            k.transpose(1, 0, 2, 3).astype(F32),
            v.transpose(1, 0, 2, 3).astype(F32),
            w.transpose(1, 0, 2, 3).astype(F32),
        )
        wkv_new, ys = jax.lax.scan(step, wkv.astype(F32), xs)
        y = ys.transpose(1, 0, 2, 3)  # (B,S,H,N)
    # per-head group norm
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5) * p["ln_w"][None, None] + p["ln_b"][None, None]
    out = (y.reshape(B, S, D) * g.astype(F32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", out, p["wo"])
    return out, (x[:, -1, :], wkv_new.astype(F32))


def rwkv6_channel_mix(p, cfg, x, shift):
    B, S, D = x.shape
    x_prev = jnp.concatenate([shift[:, None, :], x[:, :-1, :]], axis=1)
    xk = x + (x_prev - x) * p["mu_k"][None, None]
    xr = x + (x_prev - x) * p["mu_r"][None, None]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk"])))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]))
    return r * kv, x[:, -1, :]


def rwkv6_state_specs(cfg, batch: int):
    H, N, D = cfg.n_heads, cfg.d_head, cfg.d_model
    L = cfg.n_layers
    return {
        "time_shift": jax.ShapeDtypeStruct((L, batch, D), jnp.bfloat16),
        "wkv": jax.ShapeDtypeStruct((L, batch, H, N, N), jnp.float32),
        "chan_shift": jax.ShapeDtypeStruct((L, batch, D), jnp.bfloat16),
    }


def rwkv6_init_state(cfg, batch: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), rwkv6_state_specs(cfg, batch))


# ---------------------------------------------------------------- Mamba-2
def build_mamba2_template(cfg) -> Dict:
    D = cfg.d_model
    d_in = cfg.expand * D
    H = cfg.ssm_heads
    N = cfg.ssm_state
    # in_proj emits z, x, B, C, dt
    return {
        "w_in": Spec((D, 2 * d_in + 2 * N + H)),
        "conv_w": Spec((cfg.d_conv, d_in + 2 * N), init="small"),
        "conv_b": Spec((d_in + 2 * N,), init="zeros"),
        "a_log": Spec((H,), init="small"),
        "dt_bias": Spec((H,), init="small"),
        "d_skip": Spec((H,), init="ones"),
        "norm_w": Spec((d_in,), init="ones"),
        "w_out": Spec((d_in, D)),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv1d.  x (B,S,C), w (K,C).  state (B,K-1,C) carries
    the tail of the previous segment; returns (y, new_state)."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i : i + S, :] * w[i][None, None, :] for i in range(K))
    y = y + b[None, None, :]
    return jax.nn.silu(y), xp[:, -(K - 1) :, :]


def mamba2_ssd_chunked(xin, Bm, Cm, a, dt, ssm0, chunk: int = 256):
    """Chunked SSD (Mamba-2 paper §6): intra-chunk work as masked matmuls,
    inter-chunk state carried once per chunk.  xin (B,S,H,P); Bm/Cm (B,S,N);
    a/dt (B,S,H) f32; ssm0 (B,H,P,N) f32.  Returns (y (B,S,H,P) f32, s')."""
    B_, S, H, P = xin.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    xc = xin.reshape(B_, nc, chunk, H, P).transpose(1, 0, 2, 3, 4).astype(F32)
    bc = Bm.reshape(B_, nc, chunk, N).transpose(1, 0, 2, 3).astype(F32)
    cc = Cm.reshape(B_, nc, chunk, N).transpose(1, 0, 2, 3).astype(F32)
    ac = a.reshape(B_, nc, chunk, H).transpose(1, 0, 2, 3).astype(F32)
    dc = dt.reshape(B_, nc, chunk, H).transpose(1, 0, 2, 3).astype(F32)
    # per-head scalar decay: log-differences are formed BEFORE exp, so the
    # kept (j <= t) entries have exponent <= 0 — exact, no clamp needed.
    # Masked entries are set to -inf pre-exp (post-exp masking of overflowed
    # values would produce NaN gradients through the untaken branch).
    loga = jnp.log(jnp.maximum(ac, 1e-38))
    cum = jnp.cumsum(loga, axis=2)  # (nc,B,C,H) inclusive
    ti = jnp.arange(chunk)
    incl = ti[:, None] >= ti[None, :]  # j <= t (y uses the post-update state)

    def chunk_step(s, inp):
        xb, bb, cb, cumb, db = inp
        G = jnp.einsum("btn,bjn->btj", cb, bb)  # (B,C,C)
        delta = cumb[:, :, None, :] - cumb[:, None, :, :]  # (B,t,j,H)
        L = jnp.exp(jnp.where(incl[None, :, :, None], delta, -jnp.inf))
        W = G[..., None] * L * db[:, None]
        y_intra = jnp.einsum("btjh,bjhp->bthp", W, xb)
        y_inter = jnp.exp(cumb)[..., None] * jnp.einsum("btn,bhpn->bthp", cb, s)
        cum_last = cumb[:, -1]  # (B,H)
        decay_rem = jnp.exp(cum_last[:, None] - cumb) * db  # (B,C,H)
        s_new = jnp.exp(cum_last)[..., None, None] * s + jnp.einsum(
            "bch,bchp,bcn->bhpn", decay_rem, xb, bb
        )
        return s_new, y_intra + y_inter

    s_new, ys = jax.lax.scan(chunk_step, ssm0.astype(F32), (xc, bc, cc, cum, dc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, Sp, H, P)
    return y[:, :S], s_new


def mamba2_mix(p, cfg, x, state: Tuple):
    """SSD sequence form.  state = (conv_state (B,K-1,C), ssm (B,H,P,N)).
    S==1 steps sequentially; longer sequences use chunked SSD."""
    B, S, D = x.shape
    d_in = cfg.expand * D
    H, N = cfg.ssm_heads, cfg.ssm_state
    P = d_in // H
    conv_state, ssm = state

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xc, dt = (
        zxbcdt[..., :d_in],
        zxbcdt[..., d_in : 2 * d_in + 2 * N],
        zxbcdt[..., 2 * d_in + 2 * N :],
    )
    xc, conv_new = _causal_conv(xc, p["conv_w"], p["conv_b"], conv_state)
    xin = xc[..., :d_in].reshape(B, S, H, P)
    Bm = xc[..., d_in : d_in + N]
    Cm = xc[..., d_in + N :]
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"][None, None].astype(F32))  # (B,S,H)
    a = jnp.exp(-dt * jnp.exp(p["a_log"].astype(F32))[None, None])  # decay (B,S,H)

    if S > 1:
        y, ssm_new = mamba2_ssd_chunked(xin, Bm, Cm, a, dt, ssm.astype(F32))
    else:

        def step(s, inp):
            xt, bt, ct, at, dtt = inp  # (B,H,P),(B,N),(B,N),(B,H),(B,H)
            upd = (dtt * 1.0)[..., None, None] * (xt[..., :, None] * bt[:, None, None, :])
            s_new = at[..., None, None] * s + upd  # (B,H,P,N)
            y = jnp.einsum("bhpn,bn->bhp", s_new, ct)
            return s_new, y

        xs = (
            xin.transpose(1, 0, 2, 3).astype(F32),
            Bm.transpose(1, 0, 2).astype(F32),
            Cm.transpose(1, 0, 2).astype(F32),
            a.transpose(1, 0, 2),
            dt.transpose(1, 0, 2),
        )
        ssm_new, ys = jax.lax.scan(step, ssm.astype(F32), xs)
        y = ys.transpose(1, 0, 2, 3)  # (B,S,H,P)
    y = y + p["d_skip"].astype(F32)[None, None, :, None] * xin.astype(F32)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    # gated RMSNorm (mamba2 norm_before_gate=False)
    yf = y.astype(F32) * jax.nn.silu(z.astype(F32))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6) * p["norm_w"].astype(F32)
    out = jnp.einsum("bse,ed->bsd", yf.astype(x.dtype), p["w_out"])
    return out, (conv_new, ssm_new.astype(F32))


def mamba2_state_specs(cfg, batch: int):
    D = cfg.d_model
    d_in = cfg.expand * D
    H, N = cfg.ssm_heads, cfg.ssm_state
    P = d_in // H
    L = cfg.n_layers
    K = cfg.d_conv
    return {
        "conv": jax.ShapeDtypeStruct((L, batch, K - 1, d_in + 2 * N), jnp.bfloat16),
        "ssm": jax.ShapeDtypeStruct((L, batch, H, P, N), jnp.float32),
    }


def mamba2_init_state(cfg, batch: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), mamba2_state_specs(cfg, batch))
