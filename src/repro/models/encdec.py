"""Whisper-style encoder-decoder backbone.  The conv/mel frontend is a stub
per the assignment: ``input_specs`` supplies precomputed frame embeddings
(B, enc_frames, d_model); everything downstream (bidirectional encoder,
causal decoder with cross-attention, KV caches) is real."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .common import BATCH_AXES, MODEL_AXIS, Spec, constrain, tree_init, tree_specs
from .layers import (
    build_gqa_template,
    build_mlp_template,
    gqa_attention,
    rms_norm,
    sdpa,
    swiglu_mlp,
)

F32 = jnp.float32


def build_cross_template(cfg) -> Dict:
    D, H, KVH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return {
        "wq": Spec((D, H * Dh)),
        "wk": Spec((D, KVH * Dh)),
        "wv": Spec((D, KVH * Dh)),
        "wo": Spec((H * Dh, D)),
    }


def cross_attention(p, cfg, x, mem_k, mem_v):
    """Decoder x (B,S,D) attends to encoder memory K/V (B,T,KVH,Dh)."""
    B, S, _ = x.shape
    H, Dh = cfg.n_heads, cfg.d_head
    T = mem_k.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, Dh)
    kv_len = jnp.full((B,), T, jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    out = sdpa(q, mem_k, mem_v, pos, kv_len, causal=False)
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * Dh), p["wo"])


def cross_kv(p, mem, cfg):
    B, T, _ = mem.shape
    KVH, Dh = cfg.n_kv_heads, cfg.d_head
    k = jnp.einsum("btd,dh->bth", mem, p["wk"]).reshape(B, T, KVH, Dh)
    v = jnp.einsum("btd,dh->bth", mem, p["wv"]).reshape(B, T, KVH, Dh)
    return k, v


def build_encdec_template(cfg) -> Dict:
    D, V = cfg.d_model, cfg.vocab_size
    enc_block = {
        "attn_norm": Spec((D,), init="ones"),
        "attn": build_gqa_template(cfg),
        "mlp_norm": Spec((D,), init="ones"),
        "mlp": build_mlp_template(cfg),
    }
    dec_block = {
        "self_norm": Spec((D,), init="ones"),
        "self_attn": build_gqa_template(cfg),
        "cross_norm": Spec((D,), init="ones"),
        "cross": build_cross_template(cfg),
        "mlp_norm": Spec((D,), init="ones"),
        "mlp": build_mlp_template(cfg),
    }

    def stack(t, L):
        return jax.tree.map(
            lambda s: Spec((L,) + s.shape, s.dtype, s.init, s.scale),
            t,
            is_leaf=lambda x: isinstance(x, Spec),
        )

    return {
        "enc_blocks": stack(enc_block, cfg.n_enc_layers),
        "enc_norm": Spec((D,), init="ones"),
        "embed": Spec((V, D), scale=1.0),
        "dec_blocks": stack(dec_block, cfg.n_layers),
        "final_norm": Spec((D,), init="ones"),
        "lm_head": Spec((D, V)),
    }


def encdec_param_specs(cfg):
    return tree_specs(build_encdec_template(cfg))


def encdec_init(cfg, key):
    return tree_init(build_encdec_template(cfg), key)


def encode(params, cfg, frames):
    """frames (B, T_enc, D) from the stub frontend -> encoder memory."""
    enc_cfg = dataclasses.replace(cfg, causal=False)
    x = frames

    def body(x, bp):
        h, _ = gqa_attention(bp["attn"], enc_cfg, rms_norm(x, bp["attn_norm"]),
                             jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2]))
        x = x + h
        x = x + swiglu_mlp(bp["mlp"], rms_norm(x, bp["mlp_norm"]))
        return constrain(x, BATCH_AXES, None, None), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"])


def encdec_cache_specs(cfg, batch: int, max_seq: int) -> Dict:
    L, B = cfg.n_layers, batch
    KVH, Dh, T = cfg.n_kv_heads, cfg.d_head, cfg.enc_frames
    return {
        "self_k": jax.ShapeDtypeStruct((L, B, max_seq, KVH, Dh), jnp.bfloat16),
        "self_v": jax.ShapeDtypeStruct((L, B, max_seq, KVH, Dh), jnp.bfloat16),
        "cross_k": jax.ShapeDtypeStruct((L, B, T, KVH, Dh), jnp.bfloat16),
        "cross_v": jax.ShapeDtypeStruct((L, B, T, KVH, Dh), jnp.bfloat16),
    }


def encdec_init_cache(cfg, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), encdec_cache_specs(cfg, batch, max_seq))


def decode_forward(params, cfg, tokens, memory=None, pos=0, cache: Optional[Dict] = None):
    """Decoder forward.  Training/prefill supply ``memory`` (encoder output);
    decode steps reuse the cached cross K/V instead."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(pos + jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))

    def body(x, xs):
        if cache is None:
            bp = xs
            h, _ = gqa_attention(bp["self_attn"], cfg, rms_norm(x, bp["self_norm"]), positions, None)
            x = x + h
            mk, mv = cross_kv(bp["cross"], memory, cfg)
            x = x + cross_attention(bp["cross"], cfg, rms_norm(x, bp["cross_norm"]), mk, mv)
            x = x + swiglu_mlp(bp["mlp"], rms_norm(x, bp["mlp_norm"]))
            return constrain(x, BATCH_AXES, None, None), None
        bp, lc = xs
        h, (sk, sv) = gqa_attention(
            bp["self_attn"], cfg, rms_norm(x, bp["self_norm"]), positions,
            (lc["self_k"], lc["self_v"], pos),
        )
        x = x + h
        if memory is not None:  # prefill: (re)build cross KV from memory
            mk, mv = cross_kv(bp["cross"], memory, cfg)
        else:
            mk, mv = lc["cross_k"], lc["cross_v"]
        x = x + cross_attention(bp["cross"], cfg, rms_norm(x, bp["cross_norm"]), mk, mv)
        x = x + swiglu_mlp(bp["mlp"], rms_norm(x, bp["mlp_norm"]))
        x = constrain(x, BATCH_AXES, None, None)
        return x, {"self_k": sk, "self_v": sv, "cross_k": mk, "cross_v": mv}

    if cache is None:
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        new_cache = None
    else:
        x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = constrain(logits, BATCH_AXES, None, MODEL_AXIS)
    return logits, new_cache


def encdec_loss(params, cfg, batch):
    memory = encode(params, cfg, batch["frames"])
    logits, _ = decode_forward(params, cfg, batch["tokens"], memory=memory)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(F32)
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"ce": loss, "aux": jnp.zeros((), F32)}
