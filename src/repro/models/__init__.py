from .api import (
    cache_specs,
    decode_fn,
    init_cache,
    init_params,
    input_specs,
    loss_fn,
    param_specs,
    prefill_fn,
)
from .common import count_params

__all__ = [
    "param_specs",
    "init_params",
    "cache_specs",
    "init_cache",
    "loss_fn",
    "prefill_fn",
    "decode_fn",
    "input_specs",
    "count_params",
]
