"""Decoder-LM assembly for all LM-family architectures (dense / moe / rwkv6
/ hybrid).  One composable forward covering train (no cache), prefill
(cache fill, optional reused-prefix offset), and decode (single step).

Layers are stacked on a leading L axis and driven by ``jax.lax.scan`` so the
traced graph (and compile time) is O(1) in depth — essential for the 61-layer
/ 512-device dry-runs.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import BATCH_AXES, MODEL_AXIS, Spec, constrain, current_mesh, tree_init, tree_specs
from .layers import (
    build_gqa_template,
    build_mla_template,
    build_mlp_template,
    build_moe_template,
    gqa_attention,
    mla_attention,
    moe_layer,
    rms_norm,
    swiglu_mlp,
)
from .ssm import (
    build_mamba2_template,
    build_rwkv6_template,
    mamba2_mix,
    rwkv6_channel_mix,
    rwkv6_time_mix,
)

F32 = jnp.float32


# ------------------------------------------------------------- templates
def _attn_template(cfg):
    return build_mla_template(cfg) if cfg.attention == "mla" else build_gqa_template(cfg)


def build_block_template(cfg) -> Dict:
    fam = cfg.family
    if fam == "dense":
        return {
            "attn_norm": Spec((cfg.d_model,), init="ones"),
            "attn": _attn_template(cfg),
            "mlp_norm": Spec((cfg.d_model,), init="ones"),
            "mlp": build_mlp_template(cfg),
        }
    if fam == "moe":
        return {
            "attn_norm": Spec((cfg.d_model,), init="ones"),
            "attn": _attn_template(cfg),
            "moe_norm": Spec((cfg.d_model,), init="ones"),
            "moe": build_moe_template(cfg),
        }
    if fam == "rwkv6":
        return {
            "ln1": Spec((cfg.d_model,), init="ones"),
            "ln2": Spec((cfg.d_model,), init="ones"),
            **build_rwkv6_template(cfg),
        }
    if fam == "hybrid":
        return {
            "norm": Spec((cfg.d_model,), init="ones"),
            "mamba": build_mamba2_template(cfg),
        }
    raise ValueError(fam)


def _stack(template, L: int):
    return jax.tree.map(
        lambda s: Spec((L,) + s.shape, s.dtype, s.init, s.scale),
        template,
        is_leaf=lambda x: isinstance(x, Spec),
    )


def build_lm_template(cfg) -> Dict:
    D, V = cfg.d_model, cfg.vocab_size
    t = {
        "embed": Spec((V, D), scale=1.0),
        "blocks": _stack(build_block_template(cfg), cfg.n_layers),
        "final_norm": Spec((D,), init="ones"),
        "lm_head": Spec((D, V)),
    }
    if cfg.family == "hybrid":
        # one shared transformer block, reused at every site (Zamba2)
        t["shared_attn"] = {
            "attn_norm": Spec((D,), init="ones"),
            "attn": build_gqa_template(cfg),
            "mlp_norm": Spec((D,), init="ones"),
            "mlp": build_mlp_template(cfg),
        }
    return t


def lm_param_specs(cfg):
    return tree_specs(build_lm_template(cfg))


def lm_init(cfg, key):
    return tree_init(build_lm_template(cfg), key)


# ----------------------------------------------------------------- caches
def n_attn_sites(cfg) -> int:
    if cfg.family != "hybrid":
        return cfg.n_layers
    return (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every


def cache_specs(cfg, batch: int, max_seq: int) -> Dict:
    """ShapeDtypeStruct tree of the serve-time cache (the object the LSM
    store persists block-wise)."""
    L, B, S = cfg.n_layers, batch, max_seq
    fam = cfg.family
    if fam == "rwkv6":
        from .ssm import rwkv6_state_specs

        return rwkv6_state_specs(cfg, batch)
    if fam == "hybrid":
        from .ssm import mamba2_state_specs

        sites = n_attn_sites(cfg)
        return {
            **mamba2_state_specs(cfg, batch),
            "attn_k": jax.ShapeDtypeStruct((sites, B, S, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16),
            "attn_v": jax.ShapeDtypeStruct((sites, B, S, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16),
        }
    if cfg.attention == "mla":
        return {
            "c": jax.ShapeDtypeStruct((L, B, S, cfg.kv_lora_rank), jnp.bfloat16),
            "kr": jax.ShapeDtypeStruct((L, B, S, cfg.qk_rope_dim), jnp.bfloat16),
        }
    return {
        "k": jax.ShapeDtypeStruct((L, B, S, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((L, B, S, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16),
    }


def init_cache(cfg, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_specs(cfg, batch, max_seq))


# ---------------------------------------------------------------- forward
def _attn_apply(bp, cfg, x, positions, cache):
    if cfg.attention == "mla":
        return mla_attention(bp, cfg, x, positions, cache)
    return gqa_attention(bp, cfg, x, positions, cache)


def lm_forward(params, cfg, tokens, pos=0, cache: Optional[Dict] = None, embeds=None):
    """tokens (B,S) int32.  ``cache=None`` => training forward.  Otherwise
    the cache is consumed/updated at offset ``pos`` (scalar).  Returns
    (logits (B,S,V), new_cache, aux) with aux = dict of aux losses."""
    B, S = tokens.shape
    x = params["embed"][tokens] if embeds is None else embeds
    # Megatron-style sequence parallelism: residual-stream activations are
    # sharded over the model axis on the seq dim between blocks (attention
    # gathers seq and shards heads; MLP shards hidden).  Cuts saved-remat
    # activation memory by the TP degree.
    mesh = current_mesh()
    msize = 1
    if mesh is not None and MODEL_AXIS in mesh.axis_names:
        msize = dict(zip(mesh.axis_names, mesh.devices.shape))[MODEL_AXIS]
    seq_axis = MODEL_AXIS if (cfg.seq_shard and msize > 1 and S % msize == 0) else None
    x = constrain(x, BATCH_AXES, seq_axis, None)
    positions = jnp.broadcast_to(pos + jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    fam = cfg.family
    aux_acc = jnp.zeros((), F32)

    if fam in ("dense", "moe"):
        cache_keys = ("c", "kr") if cfg.attention == "mla" else ("k", "v")

        def block_compute(bp, x, aux, layer_cache):
            h = rms_norm(x, bp["attn_norm"])
            h, new_cache = _attn_apply(bp["attn"], cfg, h, positions, layer_cache)
            x = x + h
            if fam == "dense":
                h = rms_norm(x, bp["mlp_norm"])
                x = x + swiglu_mlp(bp["mlp"], h)
            else:
                h = rms_norm(x, bp["moe_norm"])
                mo, probs = moe_layer(bp["moe"], cfg, h, dropless=cache is not None)
                x = x + mo
                me = probs.mean(axis=0)
                aux = aux + cfg.n_experts * jnp.sum(me * me)  # mean-prob balance proxy
            x = constrain(x, BATCH_AXES, seq_axis, None)
            return x, aux, new_cache

        if cache is None:

            def body(carry, bp):
                x, aux = carry
                x, aux, _ = block_compute(bp, x, aux, None)
                return (x, aux), None

            if cfg.remat:
                body = jax.checkpoint(body)
            (x, aux_acc), _ = jax.lax.scan(body, (x, aux_acc), params["blocks"])
            new_cache = None
        else:
            # Cache rides as scan xs/ys.  NOTE(perf, measured): the
            # carry-with-layer-index form (MaxText-style) was tried and
            # REGRESSED the decode memory term 20% on this backend (extra
            # f32 layer-slice round-trips from CPU bf16-dot legalization);
            # see EXPERIMENTS §Perf iteration A3.
            def body(carry, xs):
                x, aux = carry
                bp, lc = xs
                layer_cache = (lc[cache_keys[0]], lc[cache_keys[1]], pos)
                x, aux, new_lc = block_compute(bp, x, aux, layer_cache)
                return (x, aux), dict(zip(cache_keys, new_lc))

            (x, aux_acc), new_cache = jax.lax.scan(body, (x, aux_acc), (params["blocks"], cache))

    elif fam == "rwkv6":
        live = cache if cache is not None else init_cache(cfg, B, 0)

        def body(carry, xs):
            x = carry
            bp, lc = xs
            h, (tshift, wkv) = rwkv6_time_mix(
                bp["time"], cfg, rms_norm(x, bp["ln1"]), (lc["time_shift"], lc["wkv"])
            )
            x = x + h
            h, cshift = rwkv6_channel_mix(bp["chan"], cfg, rms_norm(x, bp["ln2"]), lc["chan_shift"])
            x = x + h
            return x, {"time_shift": tshift, "wkv": wkv, "chan_shift": cshift}

        if cfg.remat and cache is None:
            body = jax.checkpoint(body)
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], live))
        if cache is None:
            new_cache = None

    elif fam == "hybrid":
        live = cache if cache is not None else init_cache(cfg, B, 0)
        sp = params["shared_attn"]
        has_attn_cache = cache is not None
        attn_k = live.get("attn_k") if has_attn_cache else None
        attn_v = live.get("attn_v") if has_attn_cache else None

        def apply_shared(x, ak, av, site_idx):
            h = rms_norm(x, sp["attn_norm"])
            if has_attn_cache:
                ck = jax.lax.dynamic_index_in_dim(ak, site_idx, 0, keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(av, site_idx, 0, keepdims=False)
                h, (ck2, cv2) = gqa_attention(sp["attn"], cfg, h, positions, (ck, cv, pos))
                ak = jax.lax.dynamic_update_index_in_dim(ak, ck2, site_idx, 0)
                av = jax.lax.dynamic_update_index_in_dim(av, cv2, site_idx, 0)
            else:
                h, _ = gqa_attention(sp["attn"], cfg, h, positions, None)
            x = x + h
            x = x + swiglu_mlp(sp["mlp"], rms_norm(x, sp["mlp_norm"]))
            return x, ak, av

        def body(carry, xs):
            x, ak, av, lidx = carry
            bp, lc = xs
            h, (conv, ssm) = mamba2_mix(bp["mamba"], cfg, rms_norm(x, bp["norm"]), (lc["conv"], lc["ssm"]))
            x = x + h
            is_site = (lidx % cfg.attn_every) == 0
            site_idx = lidx // cfg.attn_every
            if has_attn_cache:
                x, ak, av = jax.lax.cond(
                    is_site,
                    lambda op: apply_shared(*op),
                    lambda op: (op[0], op[1], op[2]),
                    (x, ak, av, site_idx),
                )
            else:
                x, _, _ = jax.lax.cond(
                    is_site,
                    lambda op: apply_shared(op, None, None, 0),
                    lambda op: (op, None, None),
                    x,
                )
            return (x, ak, av, lidx + 1), {"conv": conv, "ssm": ssm}

        if not has_attn_cache:
            attn_k = attn_v = jnp.zeros((), jnp.bfloat16)  # unused placeholders
        if cfg.remat and cache is None:
            body = jax.checkpoint(body)
        (x, attn_k, attn_v, _), mamba_out = jax.lax.scan(
            body,
            (x, attn_k, attn_v, jnp.int32(0)),
            (params["blocks"], {"conv": live["conv"], "ssm": live["ssm"]}),
        )
        if cache is None:
            new_cache = None
        else:
            new_cache = {**mamba_out, "attn_k": attn_k, "attn_v": attn_v}
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = constrain(logits, BATCH_AXES, None, MODEL_AXIS)
    return logits, new_cache, {"aux_loss": aux_acc}


# ------------------------------------------------------------------- loss
def lm_loss(params, cfg, batch, aux_weight: float = 0.01):
    logits, _, aux = lm_forward(params, cfg, batch["tokens"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(F32)
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux_weight * aux["aux_loss"], {"ce": loss, "aux": aux["aux_loss"]}
