"""Unified model API: every assigned architecture exposes the same five
entry points, dispatched on ``cfg.family``:

  param_specs(cfg)                  abstract params (dry-run)
  init_params(cfg, key)             materialized params (smoke/train)
  loss_fn(cfg)(params, batch)       training loss
  prefill_fn(cfg)(params, inputs, cache, pos)  -> (logits, cache)
  decode_fn(cfg)(params, tokens, cache, pos)   -> (logits, cache)

plus ``input_specs(cfg, shape)`` producing the exact ShapeDtypeStruct
stand-ins each (arch x shape) dry-run cell lowers with.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import encdec, transformer
from ..configs.base import ModelConfig, ShapeConfig


def param_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec.encdec_param_specs(cfg)
    return transformer.lm_param_specs(cfg)


def init_params(cfg: ModelConfig, key):
    if cfg.family == "encdec":
        return encdec.encdec_init(cfg, key)
    return transformer.lm_init(cfg, key)


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    if cfg.family == "encdec":
        return encdec.encdec_cache_specs(cfg, batch, max_seq)
    return transformer.cache_specs(cfg, batch, max_seq)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    if cfg.family == "encdec":
        return encdec.encdec_init_cache(cfg, batch, max_seq)
    return transformer.init_cache(cfg, batch, max_seq)


def loss_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        return lambda params, batch: encdec.encdec_loss(params, cfg, batch)
    return lambda params, batch: transformer.lm_loss(params, cfg, batch)


def prefill_fn(cfg: ModelConfig):
    """(params, inputs, cache, pos) -> (logits, cache).  ``inputs`` is the
    batch dict: tokens (+ frames for encdec)."""
    if cfg.family == "encdec":

        def prefill(params, inputs, cache, pos=0):
            memory = encdec.encode(params, cfg, inputs["frames"])
            return encdec.decode_forward(params, cfg, inputs["tokens"], memory=memory, pos=pos, cache=cache)

        return prefill

    def prefill(params, inputs, cache, pos=0):
        logits, new_cache, _ = transformer.lm_forward(params, cfg, inputs["tokens"], pos=pos, cache=cache)
        return logits, new_cache

    return prefill


def decode_fn(cfg: ModelConfig):
    """(params, tokens (B,1), cache, pos) -> (logits (B,1,V), cache)."""
    if cfg.family == "encdec":

        def decode(params, tokens, cache, pos):
            return encdec.decode_forward(params, cfg, tokens, memory=None, pos=pos, cache=cache)

        return decode

    def decode(params, tokens, cache, pos):
        logits, new_cache, _ = transformer.lm_forward(params, cfg, tokens, pos=pos, cache=cache)
        return logits, new_cache

    return decode


# ------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for one dry-run cell.  Decode cells carry
    the KV cache (seq_len of context) as an input per the assignment."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), tok),
            "labels": jax.ShapeDtypeStruct((B, S), tok),
        }
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one new token against a cache of S context tokens
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), tok),
        "cache": cache_specs(cfg, B, S),
    }
