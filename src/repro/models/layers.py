"""Transformer layers: RMSNorm, RoPE, GQA / MLA attention (direct + KV-block
-chunked online-softmax paths), SwiGLU MLP, and the sort-based top-k MoE.

All functions are pure; parameters arrive as dict trees built from
``build_*_template``.  The chunked attention path is the pure-jnp oracle the
Pallas flash kernel is checked against, and the path the dry-run lowers for
long sequences (bounded memory, clean HLO for roofline parsing).
"""

from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import BATCH_AXES, MODEL_AXIS, Spec, constrain, current_mesh

F32 = jnp.float32

# direct-softmax path up to this many KV tokens; chunked scan beyond
ATTN_CHUNK = 1024


def _grouped_head_axes(kvh: int, g: int):
    """TP axes for the grouped-head layout (..., KVH, G, ...).  The model
    axis goes on whichever of (group, kv-head) it divides evenly; otherwise
    on the larger one (GSPMD pads uneven tiles).  Returns (kvh_ax, g_ax)."""
    mesh = current_mesh()
    if mesh is None or MODEL_AXIS not in mesh.axis_names:
        return None, None
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))[MODEL_AXIS]
    if g % msize == 0:
        return None, MODEL_AXIS
    if kvh % msize == 0:
        return MODEL_AXIS, None
    return (MODEL_AXIS, None) if kvh >= g else (None, MODEL_AXIS)


# ------------------------------------------------------------------- norms
def rms_norm(x, w, eps: float = 1e-6):
    xf = x.astype(F32)
    nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nrm * w.astype(F32)).astype(x.dtype)


def head_rms_norm(x, w, eps: float = 1e-6):
    """QK-norm: normalize over the head dim (..., H, D)."""
    xf = x.astype(F32)
    nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nrm * w.astype(F32)).astype(x.dtype)


# -------------------------------------------------------------------- rope
def rope_tables(positions, dim: int, theta: float):
    """positions (...,) -> cos/sin tables (..., dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=F32) / dim))
    ang = positions.astype(F32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, S, H, D); cos/sin (B, S, D/2) or (S, D/2)."""
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# --------------------------------------------------------------- attention
def _direct_attention(q, k, v, q_pos, kv_len, causal: bool, scale: float):
    """q (B,S,H,D), k/v (B,T,KVH,D).  Materializes scores; used for short T.
    ``kv_len`` masks out unwritten cache slots; q_pos (B,S) for causality."""
    B, S, H, D = q.shape
    T, KVH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]  # may differ from D (MLA)
    G = H // KVH
    kvh_ax, g_ax = _grouped_head_axes(KVH, G)
    qg = q.reshape(B, S, KVH, G, D)
    qg = constrain(qg, BATCH_AXES, None, kvh_ax, g_ax, None)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(F32), k.astype(F32)) * scale
    scores = constrain(scores, BATCH_AXES, kvh_ax, g_ax, None, None)
    k_pos = jnp.arange(T)
    mask = k_pos[None, None, :] < kv_len[:, None, None]  # (B,1,T) valid slots
    if causal:
        mask = mask & (k_pos[None, None, :] <= q_pos[:, :, None])  # (B,S,T)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(F32))
    out = constrain(out, BATCH_AXES, None, kvh_ax, g_ax, None)
    return out.reshape(B, S, H, Dv).astype(q.dtype)


def _chunked_attention(q, k, v, q_pos, kv_len, causal: bool, scale: float, chunk: int):
    """Online-softmax scan over KV chunks (flash-style in pure XLA): memory
    O(S·chunk) instead of O(S·T)."""
    B, S, H, D = q.shape
    T, KVH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]  # may differ from D (MLA)
    G = H // KVH
    n_chunks = (T + chunk - 1) // chunk
    Tp = n_chunks * chunk
    if Tp != T:
        pad = [(0, 0), (0, Tp - T), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    kc = k.reshape(B, n_chunks, chunk, KVH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KVH, Dv).transpose(1, 0, 2, 3, 4)
    kvh_ax, g_ax = _grouped_head_axes(KVH, G)
    qg = q.reshape(B, S, KVH, G, D)  # storage dtype; f32 accum via MXU
    qg = constrain(qg, BATCH_AXES, None, kvh_ax, g_ax, None)

    def step(carry, xs):
        m, l, acc, c_idx = carry
        kb, vb = xs
        k_pos = c_idx * chunk + jnp.arange(chunk)
        # K/V chunks stay in their storage dtype; the MXU accumulates in f32
        # via preferred_element_type — materializing f32 copies of every
        # chunk cost ~40% of the decode memory term (EXPERIMENTS §Perf)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kb, preferred_element_type=F32) * scale
        s = constrain(s, BATCH_AXES, kvh_ax, g_ax, None, None)
        mask = k_pos[None, None, :] < kv_len[:, None, None]
        if causal:
            mask = mask & (k_pos[None, None, :] <= q_pos[:, :, None])
        s = jnp.where(mask[:, None, None, :, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(v.dtype), vb, preferred_element_type=F32
        )
        acc_new = constrain(acc_new, BATCH_AXES, kvh_ax, g_ax, None, None)
        return (m_new, l_new, acc_new, c_idx + 1), None

    m0 = constrain(jnp.full((B, KVH, G, S), -jnp.inf, F32), BATCH_AXES, kvh_ax, g_ax, None)
    l0 = constrain(jnp.zeros((B, KVH, G, S), F32), BATCH_AXES, kvh_ax, g_ax, None)
    a0 = constrain(jnp.zeros((B, KVH, G, S, Dv), F32), BATCH_AXES, kvh_ax, g_ax, None, None)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dv).astype(q.dtype)


def sdpa(q, k, v, q_pos, kv_len, causal: bool = True, chunk: int = ATTN_CHUNK):
    """Dispatch direct vs chunked by static KV length."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    if k.shape[1] <= chunk:
        return _direct_attention(q, k, v, q_pos, kv_len, causal, scale)
    return _chunked_attention(q, k, v, q_pos, kv_len, causal, scale, chunk)


# ----------------------------------------------------------- GQA attention
def build_gqa_template(cfg) -> Dict:
    D, H, KVH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    t = {
        "wq": Spec((D, H * Dh)),
        "wk": Spec((D, KVH * Dh)),
        "wv": Spec((D, KVH * Dh)),
        "wo": Spec((H * Dh, D)),
    }
    if cfg.qkv_bias:
        t["bq"] = Spec((H * Dh,), init="zeros")
        t["bk"] = Spec((KVH * Dh,), init="zeros")
        t["bv"] = Spec((KVH * Dh,), init="zeros")
    if cfg.qk_norm:
        t["q_norm"] = Spec((Dh,), init="ones")
        t["k_norm"] = Spec((Dh,), init="ones")
    return t


def gqa_attention(p, cfg, x, positions, cache: Optional[Tuple] = None):
    """x (B,S,D), positions (B,S).

    ``cache=None``: self-attention over x only (training) -> (out, None).
    ``cache=(ck, cv, pos)``: ck/cv are (B, S_max, KVH, Dh); this call's K/V
    are written at offset ``pos`` and attention runs over the first
    ``pos+S`` slots -> (out, (ck, cv) updated).  Covers both prefill
    (S large, pos = reused-prefix length) and decode (S=1)."""
    B, S, D = x.shape
    H, KVH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, KVH, Dh)
    v = v.reshape(B, S, KVH, Dh)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"])
        k = head_rms_norm(k, p["k_norm"])
    cos, sin = rope_tables(positions, Dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q, BATCH_AXES, None, MODEL_AXIS, None)

    if cache is None:
        kv_len = positions[:, -1] + 1  # (B,)
        out = sdpa(q, k, v, positions, kv_len, cfg.causal)
        new_cache = None
    else:
        ck, cv, pos = cache
        ck = jax.lax.dynamic_update_slice(ck, k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, pos, 0, 0))
        kv_len = jnp.broadcast_to(pos + S, (B,))
        out = sdpa(q, ck, cv, positions, kv_len, cfg.causal)
        new_cache = (ck, cv)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * Dh), p["wo"])
    return out, new_cache


# ----------------------------------------------------------- MLA attention
def build_mla_template(cfg) -> Dict:
    D, H = cfg.d_model, cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": Spec((D, r_q)),
        "q_a_norm": Spec((r_q,), init="ones"),
        "wq_b": Spec((r_q, H * (dn + dr))),
        "wkv_a": Spec((D, r_kv + dr)),
        "kv_a_norm": Spec((r_kv,), init="ones"),
        "wk_b": Spec((r_kv, H * dn)),
        "wv_b": Spec((r_kv, H * dv)),
        "wo": Spec((H * dv, D)),
    }


def mla_project_latent(p, cfg, x, positions):
    """x -> (c_kv, k_rope): the compressed per-token state that is cached —
    and persisted by the LSM store (DESIGN.md §4: MLA stores the latent)."""
    r_kv, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = rms_norm(kv_a[..., :r_kv], p["kv_a_norm"])
    k_rope = kv_a[..., r_kv:]
    cos, sin = rope_tables(positions, dr, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def mla_queries(p, cfg, x, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q_a = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_a_norm"])
    q = jnp.einsum("bsr,rh->bsh", q_a, p["wq_b"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope_tables(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def mla_attention(p, cfg, x, positions, cache: Optional[Tuple] = None):
    """MLA attention.  ``cache=None``: train (materialized K/V, no cache out).
    ``cache=(c, kr, pos)`` with c (B,S_max,r), kr (B,S_max,dr): writes this
    call's latent at offset ``pos``.  S>1 uses the materialized path
    (prefill); S==1 uses the absorbed path (decode) which attends directly
    in latent space and never expands per-head K/V."""
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    q_nope, q_rope = mla_queries(p, cfg, x, positions)
    c_new, kr_new = mla_project_latent(p, cfg, x, positions)

    if cache is None:
        c_kv, k_rope, kv_len = c_new, kr_new, positions[:, -1] + 1
        new_cache = None
        absorbed = False
    else:
        c_all, kr_all, pos = cache
        c_all = jax.lax.dynamic_update_slice(c_all, c_new, (0, pos, 0))
        kr_all = jax.lax.dynamic_update_slice(kr_all, kr_new, (0, pos, 0))
        c_kv, k_rope = c_all, kr_all
        kv_len = jnp.broadcast_to(pos + S, (B,))
        new_cache = (c_all, kr_all)
        absorbed = S == 1

    T = c_kv.shape[1]
    if not absorbed:
        # materialized path: expand latent to per-head K/V
        k_nope = jnp.einsum("btr,rh->bth", c_kv, p["wk_b"]).reshape(B, T, H, dn)
        vv = jnp.einsum("btr,rh->bth", c_kv, p["wv_b"]).reshape(B, T, H, dv)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, T, H, dr))], axis=-1
        )
        out = sdpa(q, k, vv, positions, kv_len, cfg.causal)
    else:
        # absorbed decode: scores/values in the compressed latent space
        wk = p["wk_b"].reshape(r, H, dn)
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope.astype(F32), wk.astype(F32))
        scores = jnp.einsum("bshr,btr->bhst", q_lat, c_kv.astype(F32))
        scores = scores + jnp.einsum("bshd,btd->bhst", q_rope.astype(F32), k_rope.astype(F32))
        scores = scores / ((dn + dr) ** 0.5)
        k_pos = jnp.arange(T)
        mask = k_pos[None, None, :] < kv_len[:, None, None]
        if cfg.causal:
            mask = mask & (k_pos[None, None, :] <= positions[:, :, None])
        scores = jnp.where(mask[:, None, :, :], scores, -1e30)
        pr = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", pr, c_kv.astype(F32))
        wv = p["wv_b"].reshape(r, H, dv)
        out = jnp.einsum("bshr,rhd->bshd", o_lat, wv.astype(F32)).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * dv), p["wo"])
    return out, new_cache


# --------------------------------------------------------------------- MLP
def build_mlp_template(cfg, d_ff: Optional[int] = None) -> Dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    return {"w_gate": Spec((D, F)), "w_up": Spec((D, F)), "w_down": Spec((F, D))}


def swiglu_mlp(p, x):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = constrain(h, BATCH_AXES, None, MODEL_AXIS)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# --------------------------------------------------------------------- MoE
def build_moe_template(cfg) -> Dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    return {
        "router": Spec((D, E), dtype=jnp.float32, init="small"),
        "w_gate": Spec((E, D, F)),
        "w_up": Spec((E, D, F)),
        "w_down": Spec((E, F, D)),
    }


def moe_layer(p, cfg, x, dropless: bool = False):
    """Top-k token-choice MoE with *group-local* sort-based dispatch.

    Routing, sorting and capacity are evaluated per dispatch group (= one
    batch row), so every index in the scatter/gather is group-relative and
    the whole dispatch stays sharded over the batch axes — no global
    argsort, no replicated (T·k, D) intermediates, no all-reduce of expert
    buffers (the previous flat-token formulation cost ~10 TB/device of
    collective traffic per train step on the 256-chip mesh).

    Capacity semantics are GShard-style per-group: training drops overflow
    within each group; inference (``dropless=True``) sizes capacity at the
    per-group worst case (decode) or 2x factor (prefill).  Dispatch buffers
    shard (batch -> data axes, experts -> model); expert tensors shard over
    the model axis (EP).
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    Tg = S  # tokens per dispatch group
    if dropless and Tg * k <= 4096:
        C = Tg * k  # exact per-group worst case: nothing can drop
    elif dropless:
        C = max(k, int(round(Tg * k / E * max(2.0, cfg.capacity_factor))))
    else:
        C = max(1, int(round(Tg * k / E * cfg.capacity_factor)))

    logits = jnp.einsum("bsd,de->bse", x.astype(F32), p["router"].astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E)
    gate_w, gate_e = jax.lax.top_k(probs, k)  # (B,S,k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Everything below is expressed with batched take_along_axis (and its
    # transpose) ONLY: GSPMD partitions those along the batch dim with zero
    # collectives, whereas fancy indexing / explicit batched scatter-add
    # replicate the operand and all-reduce (measured; see EXPERIMENTS §Perf).
    flat_e = gate_e.reshape(B, S * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)  # group-local sort
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # first slot of each expert in the sorted stream (binary search, per group)
    first = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_e)  # (B,E)
    pos_in_e = jnp.arange(S * k)[None, :] - jnp.take_along_axis(first, sorted_e, axis=-1)
    valid = pos_in_e < C
    tok_of = order // k  # (B, S*k)

    # dispatch: buf[b,e,c] = sorted slot first[b,e]+c (gather, not scatter)
    xs_sorted = jnp.take_along_axis(x, tok_of[..., None], axis=1)  # (B, S*k, D)
    slot = first[:, :, None] + jnp.arange(C)[None, None, :]  # (B,E,C)
    slot_ok = slot < jnp.concatenate([first[:, 1:], jnp.full((B, 1), S * k)], axis=1)[:, :, None]
    slot_flat = jnp.clip(slot, 0, S * k - 1).reshape(B, E * C)
    buf = jnp.take_along_axis(xs_sorted, slot_flat[..., None], axis=1)  # (B, E*C, D)
    buf = jnp.where(slot_ok.reshape(B, E * C)[..., None], buf, 0).reshape(B, E, C, D)
    buf = constrain(buf, BATCH_AXES, MODEL_AXIS, None, None)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) * jnp.einsum(
        "becd,edf->becf", buf, p["w_up"]
    )
    y = jnp.einsum("becf,efd->becd", h, p["w_down"])
    y = constrain(y, BATCH_AXES, MODEL_AXIS, None, None)

    # combine: sorted slot j reads buf[e=sorted_e[j], c=pos_in_e[j]], then
    # unsort via the inverse permutation and sum the k slots per token
    flat_pos = jnp.clip(sorted_e * C + jnp.where(valid, pos_in_e, 0), 0, E * C - 1)
    picked_sorted = jnp.take_along_axis(y.reshape(B, E * C, D), flat_pos[..., None], axis=1)
    w_sorted = jnp.take_along_axis(gate_w.reshape(B, S * k), order, axis=-1)
    picked_sorted = picked_sorted * (w_sorted * valid)[..., None].astype(y.dtype)
    inv_order = jnp.argsort(order, axis=-1)
    picked = jnp.take_along_axis(picked_sorted, inv_order[..., None], axis=1)
    out = picked.reshape(B, S, k, D).sum(axis=2)
    return out, probs.reshape(B * S, E)


def moe_aux_loss(probs, gate_e, n_experts: int):
    """Switch-style load-balancing loss."""
    T = probs.shape[0]
    me = probs.mean(axis=0)
    onehot = jax.nn.one_hot(gate_e, n_experts).sum(axis=1)  # (T,E)
    ce = onehot.mean(axis=0)
    return n_experts * jnp.sum(me * ce)
