"""Shared model utilities: parameter-spec trees (single source of truth for
abstract dry-run specs AND materialized init), dtype helpers, and the
sharding-constraint hook used by layers."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

try:  # non-deprecated home of thread_resources (jax >= 0.5)
    from jax._src.mesh import thread_resources as _thread_resources
except ImportError:  # pragma: no cover
    from jax.interpreters.pxla import thread_resources as _thread_resources


@dataclass(frozen=True)
class Spec:
    """Declarative parameter leaf: shape + dtype + init scheme."""

    shape: Tuple[int, ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | small
    scale: float = 1.0


def tree_specs(template) -> Dict:
    """Spec tree -> ShapeDtypeStruct tree (for .lower() dry-runs)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        template,
        is_leaf=lambda x: isinstance(x, Spec),
    )


def tree_init(template, key) -> Dict:
    """Spec tree -> materialized params (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(template, is_leaf=lambda x: isinstance(x, Spec))
    out = []
    for i, s in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if s.init == "zeros":
            arr = jnp.zeros(s.shape, s.dtype)
        elif s.init == "ones":
            arr = jnp.ones(s.shape, s.dtype)
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else max(1, s.shape[-1])
            std = s.scale / np.sqrt(fan_in)
            if s.init == "small":
                std *= 0.1
            arr = (jax.random.normal(k, s.shape, jnp.float32) * std).astype(s.dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def count_params(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


# ----------------------------------------------------------------- sharding
def current_mesh():
    m = _thread_resources.env.physical_mesh
    return None if m.empty else m


def constrain(x, *axes):
    """with_sharding_constraint that degrades to identity when no mesh is
    active and silently drops axis names the active mesh doesn't have —
    models stay mesh-agnostic."""
    mesh = current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def fix(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in names)
            return kept if kept else None
        return e if e in names else None

    spec = P(*(fix(e) for e in axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# batch is sharded over (pod, data); model-parallel dims over model
BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"
