"""Production mesh construction (assignment spec).

``make_production_mesh`` is a function (never module-level state) so that
importing this module never touches jax device state.  The 1-pod mesh is
(data=16, model=16) = 256 chips; the 2-pod mesh prepends a pure-DP ``pod``
axis = 512 chips.
"""

from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_smoke_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh over however many (host) devices exist — used by tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"), axis_types=_auto(3))
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))


# TPU v5e hardware constants used by the roofline analysis (assignment spec)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
