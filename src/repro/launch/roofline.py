"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory term     = HLO_bytes_per_device / HBM_bw               [s]
    collective term = collective_bytes_per_device / link_bw       [s]

``compiled.cost_analysis()`` reports the per-device (post-SPMD) module, so
dividing by per-chip peaks is exactly the assignment's
``global / (chips x peak)``.  Collective bytes are not in cost_analysis:
we parse the optimized HLO and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(start variants included; done/update ops skipped to avoid double count).
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Optional

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one HLO instruction:  %name = TYPE opcode(OPERANDS...), attrs
_INSTR_RE = re.compile(
    r"=\s*(?P<restype>\([^)]*\)|\S+)\s+(?P<op>[\w-]+)(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(?P<dt>\w+)\[(?P<dims>[\d,]*)\]")


def _shape_bytes(dt: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dt)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def _all_shape_bytes(text: str) -> int:
    return sum(_shape_bytes(m.group("dt"), m.group("dims")) for m in _SHAPE_RE.finditer(text))


def _split_operands(line: str) -> str:
    """Return the operand text inside the top-level parens of the op call."""
    i = line.find("(")
    if i < 0:
        return ""
    depth = 0
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                return line[i + 1 : j]
    return line[i + 1 :]


@dataclass
class CollectiveSummary:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveSummary:
    """Sum operand bytes of every collective in the optimized HLO (per-device
    module).  ``-done`` ops carry no payload; ``-start`` ops are where the
    operands appear, async pairs are therefore counted once."""
    by_op: Dict[str, int] = defaultdict(int)
    cnt: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        for coll in _COLLECTIVES:
            # match "opcode(" or "opcode-start(" right after the result type
            if f" {coll}(" in ls or f" {coll}-start(" in ls:
                opnds = _split_operands(ls)
                by_op[coll] += _all_shape_bytes(opnds)
                cnt[coll] += 1
                break
    return CollectiveSummary(dict(by_op), dict(cnt))


@dataclass
class Roofline:
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device bytes accessed
    collective_bytes: float  # per-device collective operand bytes
    chips: int
    model_flops: float = 0.0  # 6*N*D (dense) or 6*N_active*D

    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        return self.flops / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s, "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops — remat/redundancy waste meter."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        denom = self.step_s * self.chips * self.peak_flops
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_s": self.step_s,
            "useful_flop_ratio": self.useful_flop_ratio,
            "mfu": self.mfu,
        }


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the cell: 6·N·D(+attention) for train,
    2·N·D for inference (forward only), D = tokens processed this step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n * tokens
        # causal attention flops: 6 * L * B * S^2/2 * H * Dh * 2 (fwd+bwd qk+av)
        if cfg.attention != "none" and cfg.family != "rwkv6":
            sites = cfg.n_layers if cfg.attn_every == 0 else cfg.n_layers // cfg.attn_every
            base += 6.0 * sites * shape.global_batch * shape.seq_len**2 * cfg.n_heads * cfg.d_head
        return base
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n * tokens
        if cfg.attention != "none" and cfg.family != "rwkv6":
            sites = cfg.n_layers if cfg.attn_every == 0 else cfg.n_layers // cfg.attn_every
            base += 2.0 * sites * shape.global_batch * shape.seq_len**2 * cfg.n_heads * cfg.d_head
        return base
    # decode: one token per sequence
    tokens = shape.global_batch
    base = 2.0 * n * tokens
    if cfg.attention != "none" and cfg.family != "rwkv6":
        sites = cfg.n_layers if cfg.attn_every == 0 else cfg.n_layers // cfg.attn_every
        base += 4.0 * sites * shape.global_batch * shape.seq_len * cfg.n_heads * cfg.d_head
    return base


def extract(compiled, cfg, shape, chips: int, hlo_text: Optional[str] = None):
    """Roofline terms from the compiled per-device module.

    Uses the trip-count-aware HLO walk (``hlocost``) — XLA's own
    cost_analysis() counts while bodies once, undercounting every layer
    scan by ~n_layers (verified; see hlocost docstring).  The raw XLA
    numbers are kept alongside for reference.
    """
    from .hlocost import analyze_text

    text = hlo_text if hlo_text is not None else compiled.as_text()
    tot = analyze_text(text)
    coll = CollectiveSummary(
        {k: int(v) for k, v in tot.coll_bytes_by_op.items()},
        {k: int(v) for k, v in tot.coll_count_by_op.items()},
    )
    return Roofline(
        flops=tot.flops,
        hbm_bytes=tot.bytes,
        collective_bytes=float(tot.collective_bytes),
        chips=chips,
        model_flops=model_flops_for(cfg, shape),
    ), coll
