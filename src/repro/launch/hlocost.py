"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once*,
regardless of trip count (verified: a 10-step scan of matmuls reports the
flops of one matmul).  Every layer stack in this framework is a
``jax.lax.scan`` — i.e. a while loop — so flops, bytes AND collective bytes
would be undercounted by ~n_layers without correction.

This module parses the optimized per-device HLO text (``compiled.as_text()``)
into a computation graph and walks it with multipliers:

    while:        cost(body) * trip + cost(cond) * (trip + 1)
    fusion:       internal flops; boundary bytes only (operands + result =
                  HBM traffic at the fusion boundary, XLA-style)
    conditional:  max over branches (one branch executes per invocation)
    collectives:  operand bytes * enclosing trip counts
                  (-start counted, -done skipped)

Trip counts come from the loop-condition computation: the largest integer
literal among its ``constant(N)`` instructions — exact for jax.lax.scan
loops, whose trip counts are static.

FLOP model per instruction (matches XLA's own convention):
    dot           2 * prod(result) * prod(lhs contracting dims)
    convolution   2 * prod(result) * prod(kernel) / out_features
    elementwise   1 * prod(result)
    reduce        1 * prod(operand)
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "tanh", "exp", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "rsqrt", "sqrt", "cbrt", "logistic", "sine", "cosine", "tan", "atan2",
    "negate", "abs", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "compare", "select", "and", "or", "xor", "not",
    "clamp", "remainder", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "is-finite", "erf",
}

_SKIP = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "rng-get-and-update-state",
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _elems(type_str: str) -> int:
    n = 1
    for d in _dims(type_str):
        n *= d
    return n


@dataclass
class Instr:
    name: str
    rtype: str
    opcode: str
    operands: List[str]
    attrs: str
    literal: Optional[int] = None  # integer constants only
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, str] = field(default_factory=dict)  # instr name -> rtype
    root: Optional[Instr] = None


_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_INT_LIT_RE = re.compile(r"^\s*(-?\d+)\s*$")


def _split_result(line: str) -> Tuple[str, str]:
    """Split 'TYPE rest' where TYPE may be a tuple '(a, b)'."""
    line = line.lstrip()
    if line.startswith("("):
        depth = 0
        for j, ch in enumerate(line):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return line[: j + 1], line[j + 1 :].lstrip()
    i = line.find(" ")
    return line[:i], line[i + 1 :].lstrip()


def _balanced_parens(s: str, start: int) -> int:
    depth = 0
    for j in range(start, len(s)):
        if s[j] == "(":
            depth += 1
        elif s[j] == ")":
            depth -= 1
            if depth == 0:
                return j
    return len(s)


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        s = raw.strip()
        if not s:
            continue
        if cur is None:
            if (s.startswith("%") or s.startswith("ENTRY")) and s.endswith("{"):
                is_entry = s.startswith("ENTRY")
                name = (s.split()[1] if is_entry else s.split()[0]).lstrip("%")
                cur = Computation(name)
                comps[name] = cur
                if is_entry:
                    entry = name
            continue
        if s == "}":
            cur = None
            continue
        if " = " not in s:
            continue
        is_root = s.startswith("ROOT ")
        if is_root:
            s = s[5:]
        if not s.startswith("%"):
            continue
        lhs, rhs = s.split(" = ", 1)
        iname = lhs.strip().lstrip("%")
        rtype, rest = _split_result(rhs)
        sp = rest.find("(")
        if sp < 0:
            continue
        opcode = rest[:sp].strip()
        close = _balanced_parens(rest, sp)
        opnd_text = rest[sp + 1 : close]
        attrs = rest[close + 1 :]
        operands = _OPERAND_NAME_RE.findall(opnd_text)
        literal = None
        if opcode in ("constant", "parameter"):
            m = _INT_LIT_RE.match(opnd_text)
            if m:
                literal = int(m.group(1))
        inst = Instr(iname, rtype, opcode, operands, attrs, literal, is_root)
        cur.instrs.append(inst)
        if is_root:
            cur.root = inst
        cur.table[iname] = rtype
    return comps, entry


_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_FEATURE_GROUP_RE = re.compile(r"feature_group_count=(\d+)")


def _operand_bytes(comp: Computation, instr: Instr) -> int:
    total = 0
    for op in instr.operands:
        t = comp.table.get(op)
        if t is not None:
            total += _shape_bytes(t)
    return total


def _instr_bytes(comp: Computation, instr: Instr) -> int:
    """Physical HBM traffic for one instruction.  Unlike XLA's cost
    analysis we model slicing/in-place ops at their *touched* sizes —
    that is what a TPU actually moves:

      dynamic-slice / gather        read the slice, write the result
      dynamic-update-slice          read the update, write the region
                                    (the big operand aliases the result)
      scatter                       indices + updates + touched region
    """
    op = instr.opcode
    res = _shape_bytes(instr.rtype)
    if op in ("dynamic-slice", "gather", "slice"):
        idx = 0
        if op == "gather" and len(instr.operands) > 1:
            idx = _shape_bytes(comp.table.get(instr.operands[1], ""))
        return 2 * res + idx
    if op == "dynamic-update-slice":
        upd = _shape_bytes(comp.table.get(instr.operands[1], "")) if len(instr.operands) > 1 else 0
        return 2 * upd
    if op == "scatter":
        touched = 0
        for o in instr.operands[1:]:
            touched += _shape_bytes(comp.table.get(o, ""))
        return 2 * touched
    return _operand_bytes(comp, instr) + res


def _instr_flops(comp: Computation, instr: Instr) -> float:
    op = instr.opcode
    if op == "dot":
        out = _elems(instr.rtype)
        contract = 1
        m = _CONTRACT_RE.search(instr.attrs)
        if m and instr.operands:
            ld = _dims(comp.table.get(instr.operands[0], ""))
            for di in m.group(1).split(","):
                if di and int(di) < len(ld):
                    contract *= ld[int(di)]
        return 2.0 * out * contract
    if op == "convolution":
        out = _elems(instr.rtype)
        kd = _dims(comp.table.get(instr.operands[1], "")) if len(instr.operands) > 1 else []
        k_elems = 1
        for d in kd:
            k_elems *= d
        od = _dims(instr.rtype)
        out_feat = od[-1] if od else 1
        g = 1
        m = _FEATURE_GROUP_RE.search(instr.attrs)
        if m:
            g = int(m.group(1))
        return 2.0 * out * max(1, k_elems // max(1, out_feat)) / g
    if op in _ELEMENTWISE:
        return float(_elems(instr.rtype))
    if op in ("reduce", "reduce-window"):
        first = instr.operands[0] if instr.operands else None
        t = comp.table.get(first, "") if first else ""
        return float(_elems(t)) if t else float(_elems(instr.rtype))
    return 0.0


_SCOPE_RE = re.compile(r'op_name="([^"]*)"')


def _scope_of(attrs: str, depth: int = 4) -> str:
    """Collapse the jax op_name metadata to its leading scope components
    (e.g. 'jit(train_step)/transpose(jvp())/while/body')."""
    m = _SCOPE_RE.search(attrs)
    if not m:
        return "<no-scope>"
    return "/".join(m.group(1).split("/")[:depth])


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_bytes_by_op: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_count_by_op: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    bytes_by_scope: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    def merge(self, other: "CostTotals"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        for k, v in other.coll_bytes_by_op.items():
            self.coll_bytes_by_op[k] += v
        for k, v in other.coll_count_by_op.items():
            self.coll_count_by_op[k] += v
        for k, v in other.bytes_by_scope.items():
            self.bytes_by_scope[k] += v

    def top_scopes(self, n: int = 12):
        return sorted(self.bytes_by_scope.items(), key=lambda kv: -kv[1])[:n]


class HloCostModel:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._trip_cache: Dict[str, int] = {}
        self._fusion_cache: Dict[str, float] = {}

    def _trip_from_cond(self, cond_name: str) -> int:
        if cond_name not in self._trip_cache:
            comp = self.comps.get(cond_name)
            vals = [
                i.literal
                for i in comp.instrs
                if i.literal is not None and i.opcode == "constant"
            ] if comp else []
            self._trip_cache[cond_name] = max(vals, default=1)
        return self._trip_cache[cond_name]

    def _fusion_flops(self, name: str) -> float:
        if name in self._fusion_cache:
            return self._fusion_cache[name]
        fused = self.comps.get(name)
        total = 0.0
        if fused is not None:
            self._fusion_cache[name] = 0.0  # cycle guard
            for instr in fused.instrs:
                if instr.opcode == "fusion":
                    m = _CALLS_RE.search(instr.attrs)
                    if m:
                        total += self._fusion_flops(m.group(1))
                    continue
                total += _instr_flops(fused, instr)
        self._fusion_cache[name] = total
        return total

    def analyze(self) -> CostTotals:
        totals = CostTotals()
        if self.entry:
            self._walk(self.entry, 1.0, totals, frozenset())
        totals.coll_bytes_by_op = dict(totals.coll_bytes_by_op)
        totals.coll_count_by_op = dict(totals.coll_count_by_op)
        totals.bytes_by_scope = dict(totals.bytes_by_scope)
        return totals

    def _walk(self, comp_name: str, mult: float, totals: CostTotals, stack: frozenset):
        comp = self.comps.get(comp_name)
        if comp is None or comp_name in stack:
            return
        stack = stack | {comp_name}
        for instr in comp.instrs:
            op = instr.opcode
            if op in _SKIP:
                continue
            if op == "while":
                m_cond, m_body = _COND_RE.search(instr.attrs), _BODY_RE.search(instr.attrs)
                trips = self._trip_from_cond(m_cond.group(1)) if m_cond else 1
                if m_body:
                    self._walk(m_body.group(1), mult * trips, totals, stack)
                if m_cond:
                    self._walk(m_cond.group(1), mult * (trips + 1), totals, stack)
                continue
            if op == "conditional":
                m = _BRANCHES_RE.search(instr.attrs)
                if m:
                    best: Optional[CostTotals] = None
                    for b in m.group(1).split(","):
                        sub = CostTotals()
                        self._walk(b.strip().lstrip("%"), mult, sub, stack)
                        if best is None or sub.flops + sub.bytes > best.flops + best.bytes:
                            best = sub
                    if best:
                        totals.merge(best)
                continue
            if op == "call":
                m = _TO_APPLY_RE.search(instr.attrs)
                if m:
                    self._walk(m.group(1), mult, totals, stack)
                continue
            if op == "fusion":
                m = _CALLS_RE.search(instr.attrs)
                if m:
                    totals.flops += self._fusion_flops(m.group(1)) * mult
                b = self._fusion_bytes(comp, instr, m) * mult
                totals.bytes += b
                totals.bytes_by_scope[self._fusion_scope(instr, m)] += b
                continue
            if op.endswith("-done") or op.endswith("-update"):
                continue
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                ob = _operand_bytes(comp, instr)
                totals.coll_bytes_by_op[base] += ob * mult
                totals.coll_count_by_op[base] += mult
                totals.collective_bytes += ob * mult
                totals.bytes += (ob + _shape_bytes(instr.rtype)) * mult
                totals.bytes_by_scope[f"<collective>/{base}"] += ob * mult
                continue
            b = _instr_bytes(comp, instr) * mult
            totals.bytes += b
            totals.bytes_by_scope[_scope_of(instr.attrs)] += b
            totals.flops += _instr_flops(comp, instr) * mult

    def _fusion_bytes(self, comp: Computation, instr: Instr, calls_match) -> float:
        """Boundary bytes of a fusion, with two physical-traffic corrections:

        * a parameter consumed ONLY by dynamic-slice/gather inside the
          fused computation is charged at the slice sizes, not the full
          buffer (fused scan-input reads touch one slice per trip);
        * a fusion whose root is dynamic-update-slice aliases the sliced
          operand with its result (in-place cache write on TPU): the
          update region is charged twice, the big buffer not at all.
        """
        fused = self.comps.get(calls_match.group(1)) if calls_match else None
        res = _shape_bytes(instr.rtype)
        if fused is None:
            return _operand_bytes(comp, instr) + res

        # map parameter index -> charged bytes
        params = sorted(
            (i for i in fused.instrs if i.opcode == "parameter"),
            key=lambda i: i.literal if i.literal is not None else 0,
        )
        charged: Dict[str, float] = {}
        for p in params:
            consumers = [i for i in fused.instrs if p.name in i.operands]
            if consumers and all(
                c.opcode in ("dynamic-slice", "gather", "slice")
                or (c.opcode == "dynamic-update-slice" and c.operands and c.operands[0] == p.name)
                for c in consumers
            ):
                b = 0.0
                for c in consumers:
                    if c.opcode == "dynamic-update-slice":
                        upd = _shape_bytes(fused.table.get(c.operands[1], "")) if len(c.operands) > 1 else 0
                        b += 2 * upd
                    else:
                        b += _shape_bytes(c.rtype)
                charged[p.name] = b
            else:
                charged[p.name] = float(_shape_bytes(p.rtype))

        total_in = 0.0
        for pi, op in enumerate(instr.operands):
            if pi < len(params):
                total_in += charged.get(params[pi].name, 0.0)
            else:
                total_in += _shape_bytes(comp.table.get(op, ""))

        # result charge: buffers aliased by a root dynamic-update-slice
        # (directly, or as elements of a root tuple) are written only in the
        # update region — charge 2x update, not the whole buffer
        root = fused.root or (fused.instrs[-1] if fused.instrs else None)
        dus_elems: List[Instr] = []
        if root is not None:
            if root.opcode == "dynamic-update-slice":
                dus_elems = [root]
            elif root.opcode == "tuple":
                dus_elems = [
                    i for i in fused.instrs
                    if i.name in root.operands and i.opcode == "dynamic-update-slice"
                ]
        res_charge = float(res)
        for d in dus_elems:
            upd = _shape_bytes(fused.table.get(d.operands[1], "")) if len(d.operands) > 1 else 0
            res_charge -= _shape_bytes(d.rtype)
            res_charge += 2 * upd
        return total_in + max(0.0, res_charge)

    def _fusion_scope(self, instr: Instr, calls_match) -> str:
        """Fusions often carry no metadata; borrow the scope of the first
        metadata-bearing instruction inside the fused computation."""
        s = _scope_of(instr.attrs)
        if s != "<no-scope>" or not calls_match:
            return s
        fused = self.comps.get(calls_match.group(1))
        if fused:
            for fi in fused.instrs:
                fs = _scope_of(fi.attrs)
                if fs != "<no-scope>":
                    return fs
        return "<no-scope>"


def analyze_text(text: str) -> CostTotals:
    return HloCostModel(text).analyze()
