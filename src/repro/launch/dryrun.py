import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (assignment deliverable e).

For every (architecture x input-shape) cell, lower + compile the real step
function under the production mesh — 1-pod (16 data x 16 model = 256 chips)
and 2-pod (2 pod x 16 data x 16 model = 512 chips) — with 512 placeholder
host devices.  Prints ``memory_analysis()`` (fits?) and ``cost_analysis()``
(roofline terms), and writes one JSON artifact per cell under
``benchmarks/artifacts/dryrun/``.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --pods 1
  python -m repro.launch.dryrun --all --pods 1,2        # every cell, subprocesses
  python -m repro.launch.dryrun --all --missing-only
"""

import argparse
import gzip
import json
import subprocess
import sys
import time
import traceback

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts", "dryrun")


def _mem_dict(mem) -> dict:
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        # peak live bytes per device (args may alias outputs via donation)
        out["peak_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


def cell_path(arch: str, shape: str, pods: int) -> str:
    return os.path.join(ART_DIR, f"{arch}__{shape}__{pods}pod.json")


def run_cell(arch: str, shape_name: str, pods: int, save_hlo: bool = False, smoke: bool = False) -> dict:
    import jax

    from repro.configs import SHAPES, get_config
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import extract

    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(pods == 2))
    chips = mesh.devices.size

    rec = {"arch": arch, "shape": shape_name, "pods": pods, "chips": chips, "ok": False}
    t0 = time.time()
    lowered = steps.lower_cell(mesh, cfg, shape)
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    try:
        rec["memory_analysis"] = _mem_dict(compiled.memory_analysis())
    except Exception as e:  # pragma: no cover - backend specific
        rec["memory_analysis"] = {"error": str(e)}
    hlo = compiled.as_text()
    rl, coll = extract(compiled, cfg, shape, chips, hlo_text=hlo)
    rec["cost_analysis"] = {"flops": rl.flops, "bytes_accessed": rl.hbm_bytes}
    rec["collectives"] = {"bytes_by_op": coll.bytes_by_op, "count_by_op": coll.count_by_op}
    rec["roofline"] = rl.to_dict()
    rec["ok"] = True

    os.makedirs(ART_DIR, exist_ok=True)
    with open(cell_path(arch, shape_name, pods), "w") as f:
        json.dump(rec, f, indent=1)
    if save_hlo:
        with gzip.open(cell_path(arch, shape_name, pods).replace(".json", ".hlo.gz"), "wt") as f:
            f.write(hlo)

    print(f"[dryrun] {arch} x {shape_name} x {pods}-pod ({chips} chips): OK "
          f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)")
    print(f"  memory_analysis: {rec['memory_analysis']}")
    print(f"  cost_analysis: flops/device={rl.flops:.3e} bytes/device={rl.hbm_bytes:.3e}")
    print(f"  collectives: {coll.bytes_by_op}")
    print(f"  roofline: compute={rl.compute_s*1e3:.2f}ms memory={rl.memory_s*1e3:.2f}ms "
          f"collective={rl.collective_s*1e3:.2f}ms -> bottleneck={rl.bottleneck} mfu={rl.mfu:.3f}")
    return rec


def run_all(pods_list, missing_only: bool, save_hlo: bool, timeout_s: int = 3600) -> int:
    from repro.configs import cells

    failures = 0
    todo = []
    for pods in pods_list:
        for arch, shape_name, skip in cells(include_skipped=True):
            if skip:
                continue
            if missing_only and os.path.exists(cell_path(arch, shape_name, pods)):
                continue
            todo.append((arch, shape_name, pods))
    print(f"[dryrun] {len(todo)} cells to run")
    for arch, shape_name, pods in todo:
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape_name, "--pods", str(pods)]
        if save_hlo:
            cmd.append("--save-hlo")
        r = subprocess.run(cmd, timeout=timeout_s)
        if r.returncode != 0:
            failures += 1
            print(f"[dryrun] FAIL {arch} x {shape_name} x {pods}-pod (rc={r.returncode})")
    print(f"[dryrun] done: {len(todo) - failures}/{len(todo)} ok")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--pods", default="1")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--missing-only", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    pods_list = [int(p) for p in str(args.pods).split(",")]
    if args.all:
        sys.exit(1 if run_all(pods_list, args.missing_only, args.save_hlo) else 0)
    try:
        run_cell(args.arch, args.shape, pods_list[0], save_hlo=args.save_hlo, smoke=args.smoke)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
