"""Step builders: one jitted (train | prefill | decode) step per
(architecture x shape), with in/out shardings resolved from
``repro.distributed.sharding`` for whatever mesh is active.

These are the exact callables the dry-run lowers and the train/serve
launchers execute; there is no separate "dry-run model".
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..distributed import sharding as shd
from ..models import api
from ..training import optim

# archs big enough that ZeRO-3 must span the pod axis too (1T params)
FSDP_POD_ARCHS = {"kimi-k2-1t-a32b"}


def fsdp_axes_for(cfg: ModelConfig, mesh) -> Tuple[str, ...]:
    if cfg.name.split("-smoke")[0] in FSDP_POD_ARCHS and "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def opt_config_for(cfg: ModelConfig) -> optim.OptimizerConfig:
    """1T-class archs get bf16 first moment + factored second moment
    (fp32 AdamW state alone would be 8 TB)."""
    big = cfg.param_count() > 50e9
    return optim.OptimizerConfig(
        moment_dtype="bfloat16" if big else "float32",
        factored_second_moment=big,
    )


def _train_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, remat=True, seq_shard=True)


# ------------------------------------------------------------------ train
def build_train_step(cfg: ModelConfig, ocfg: Optional[optim.OptimizerConfig] = None):
    cfg = _train_cfg(cfg)
    ocfg = ocfg or opt_config_for(cfg)
    lfn = api.loss_fn(cfg)

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(lfn, has_aux=True)(params, batch)
        params, opt_state, om = optim.apply_updates(ocfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **parts, **om}

    return train_step, ocfg


def train_abstract_inputs(cfg: ModelConfig, shape: ShapeConfig, ocfg: optim.OptimizerConfig):
    p_specs = api.param_specs(cfg)
    return p_specs, optim.state_specs(ocfg, p_specs), api.input_specs(cfg, shape)


def train_shardings(mesh, cfg: ModelConfig, shape: ShapeConfig, ocfg: optim.OptimizerConfig):
    fsdp = fsdp_axes_for(cfg, mesh)
    p_specs, o_specs, in_specs = train_abstract_inputs(cfg, shape, ocfg)
    p_sh = shd.param_shardings(mesh, p_specs, fsdp)
    o_sh = shd.opt_state_shardings(mesh, o_specs, p_sh)
    b_sh = shd.batch_shardings(mesh, in_specs)
    metrics_sh = None  # let XLA choose (scalars)
    return (p_sh, o_sh, b_sh), (p_sh, o_sh, metrics_sh)


# ---------------------------------------------------------------- prefill
def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig):
    pfn = api.prefill_fn(cfg)
    B, S = shape.global_batch, shape.seq_len

    def prefill_step(params, inputs):
        cache = api.init_cache(cfg, B, S)
        logits, cache = pfn(params, inputs, cache, 0)
        return logits[:, -1, :], cache

    return prefill_step


def prefill_shardings(mesh, cfg: ModelConfig, shape: ShapeConfig):
    fsdp = fsdp_axes_for(cfg, mesh)
    p_sh = shd.param_shardings(mesh, api.param_specs(cfg), fsdp)
    in_sh = shd.batch_shardings(mesh, api.input_specs(cfg, shape))
    c_specs = api.cache_specs(cfg, shape.global_batch, shape.seq_len)
    c_sh = shd.cache_shardings(mesh, c_specs, cfg)
    logits_sh = NamedSharding(
        mesh,
        shd.filter_spec(P(shd.BATCH, "model"), mesh, (shape.global_batch, cfg.vocab_size)),
    )
    return (p_sh, in_sh), (logits_sh, c_sh)


# ----------------------------------------------------------------- decode
def build_decode_step(cfg: ModelConfig, shape: ShapeConfig):
    dfn = api.decode_fn(cfg)

    def decode_step(params, tokens, cache, pos):
        logits, cache = dfn(params, tokens, cache, pos)
        return logits[:, -1, :], cache

    return decode_step


def decode_shardings(mesh, cfg: ModelConfig, shape: ShapeConfig):
    fsdp = fsdp_axes_for(cfg, mesh)
    p_sh = shd.param_shardings(mesh, api.param_specs(cfg), fsdp)
    specs = api.input_specs(cfg, shape)
    tok_sh = shd.batch_shardings(mesh, specs["tokens"])
    c_sh = shd.cache_shardings(mesh, specs["cache"], cfg)
    pos_sh = NamedSharding(mesh, P())
    logits_sh = NamedSharding(
        mesh,
        shd.filter_spec(P(shd.BATCH, "model"), mesh, (shape.global_batch, cfg.vocab_size)),
    )
    return (p_sh, tok_sh, c_sh, pos_sh), (logits_sh, c_sh)


# ------------------------------------------------------------- cell lowering
def lower_cell(mesh, cfg: ModelConfig, shape: ShapeConfig):
    """Lower one (arch x shape) cell under ``mesh``.  Returns the jax
    ``Lowered`` object; callers .compile() it."""
    with mesh:
        if shape.kind == "train":
            step, ocfg = build_train_step(cfg)
            in_sh, out_sh = train_shardings(mesh, _train_cfg(cfg), shape, ocfg)
            p, o, b = train_abstract_inputs(_train_cfg(cfg), shape, ocfg)
            jitted = jax.jit(
                step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(0, 1)
            )
            return jitted.lower(p, o, b)
        if shape.kind == "prefill":
            step = build_prefill_step(cfg, shape)
            in_sh, out_sh = prefill_shardings(mesh, cfg, shape)
            p = api.param_specs(cfg)
            inputs = api.input_specs(cfg, shape)
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            return jitted.lower(p, inputs)
        # decode
        step = build_decode_step(cfg, shape)
        in_sh, out_sh = decode_shardings(mesh, cfg, shape)
        p = api.param_specs(cfg)
        specs = api.input_specs(cfg, shape)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(2,))
        return jitted.lower(p, specs["tokens"], specs["cache"], pos)
