"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Runs the full serving stack — staged workload -> radix/LSM cache hierarchy
-> continuous-batching engine — with the disk tier on real files.  With
``--real-model`` the prefill is executed for real on the reduced config
(KV blocks come from the model's cache); otherwise compute is modeled and
I/O measured (DESIGN.md §7).
"""

import argparse
import os
import tempfile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--backend", default="lsm", choices=["lsm", "file", "memory"])
    ap.add_argument("--prompt-len", type=int, default=512)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--stages", default="0.2,0.5,0.7")
    ap.add_argument("--root", default=None)
    args = ap.parse_args()

    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    from benchmarks import common

    stages = tuple(float(x) for x in args.stages.split(","))
    s = common.BenchScale(
        prompt_len=args.prompt_len,
        requests_per_stage=args.requests,
        stages=stages,
        corpus_size=max(16, args.requests),
    )
    root = args.root or tempfile.mkdtemp(prefix="serve_")
    eng = common.make_engine(root, args.backend, s, arch=args.arch)
    results = common.run_staged(eng, s)
    print(f"[launch.serve] arch={args.arch} backend={args.backend} prompt={args.prompt_len}")
    print(f"{'stage':>5s} {'exp_hit':>8s} {'hit':>6s} {'TTFT(s)':>9s} {'IO(ms)':>8s}")
    for st in results:
        print(f"{st.stage:5d} {st.expected_hit:8.2f} {st.hit_rate:6.3f} "
              f"{st.mean_ttft_s:9.4f} {st.mean_io_s*1e3:8.2f}")
    if eng.h.store is not None:
        st = eng.h.store
        print(f"[store] files={st.file_count} disk={st.disk_bytes/1e6:.1f}MB "
              + (f"compression={st.stats.compression_ratio:.2f}x" if hasattr(st.stats, "compression_ratio") else ""))


if __name__ == "__main__":
    main()
