"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

On this container the full configs cannot execute (CPU, 1 core), so the
default is the reduced smoke config on a small host-device mesh — the same
code path (sharded params, jit train step, checkpoint/auto-resume) the
production mesh uses; the full config is exercised by dryrun.py.
"""

import os

if "XLA_FLAGS" not in os.environ:  # small host mesh for the smoke launcher
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--model", type=int, default=2)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback DP gradient compression")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_smoke_mesh
    from repro.models import api
    from repro.training import optim
    from repro.training.loop import TrainConfig, train

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_smoke_mesh(args.data, args.model)
    print(f"[launch.train] arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    with mesh:
        p_specs = api.param_specs(cfg)
        p_sh = shd.param_shardings(mesh, p_specs)
        o_sh = None  # inherited via init under mesh
        t0 = time.time()
        res = train(
            cfg,
            TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir),
        )
        print(f"[launch.train] {res['step']} steps in {time.time()-t0:.1f}s; "
              f"final loss {res['losses'][-1]:.4f} (resumed from {res['resumed_from']})")


if __name__ == "__main__":
    main()
