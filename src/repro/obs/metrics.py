"""Thread-safe metrics core: counters, gauges, and fixed-log-bucket
histograms behind a lock-striped :class:`MetricsRegistry`.

Every instrument is safe to update from ``IOExecutor`` workers, the
engine thread, and the cluster selector threads concurrently.  Locks are
striped: the registry owns a small fixed pool of locks and assigns each
instrument one by name hash, so unrelated hot instruments rarely
contend while the total lock count stays bounded.

Metric naming scheme (enforced by convention, documented in
``docs/OBSERVABILITY.md``): ``repro_<layer>_<what>[_<unit>]``, e.g.
``repro_store_get_blocks``, ``repro_node_request_seconds``.

Existing ``*Stats`` dataclasses are bridged in via *collectors*:
``registry.register_collector(dataclass_gauges("repro_store", store.stats))``
re-exports every numeric field as a gauge at snapshot time, so legacy
stats mutate exactly as before but read out through one registry.
"""

from __future__ import annotations

import threading
import zlib
from bisect import bisect_left
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "dataclass_gauges",
    "render_prometheus",
]

_STRIPES = 16

# Default histogram geometry: 1 microsecond lower bound, doubling
# buckets.  40 buckets span 1e-6 s .. ~550 s, plenty for any latency
# this repo measures; values above the top bound land in +Inf.
DEFAULT_START = 1e-6
DEFAULT_FACTOR = 2.0
DEFAULT_BUCKETS = 40


class Counter:
    """Monotonic counter. ``inc`` only; resets never."""

    __slots__ = ("name", "help", "_lock", "_value")

    def __init__(self, name: str, help: str = "", lock: Optional[threading.Lock] = None):
        self.name = name
        self.help = help
        self._lock = lock or threading.Lock()
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """A value that can go up and down (queue depth, open connections)."""

    __slots__ = ("name", "help", "_lock", "_value")

    def __init__(self, name: str, help: str = "", lock: Optional[threading.Lock] = None):
        self.name = name
        self.help = help
        self._lock = lock or threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value -= amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed-log-bucket histogram with cheap ``observe`` and quantile
    estimates by linear interpolation inside the containing bucket.

    Bucket upper bounds are ``start * factor**i`` for ``i`` in
    ``range(buckets)`` with an implicit final ``+Inf`` bucket, matching
    Prometheus ``le`` (cumulative, inclusive-upper) semantics.
    """

    __slots__ = ("name", "help", "_lock", "_bounds", "_counts",
                 "_count", "_sum", "_min", "_max")

    def __init__(self, name: str, help: str = "",
                 start: float = DEFAULT_START, factor: float = DEFAULT_FACTOR,
                 buckets: int = DEFAULT_BUCKETS,
                 lock: Optional[threading.Lock] = None):
        if start <= 0 or factor <= 1.0 or buckets < 1:
            raise ValueError("histogram needs start > 0, factor > 1, buckets >= 1")
        self.name = name
        self.help = help
        self._lock = lock or threading.Lock()
        self._bounds: List[float] = [start * factor ** i for i in range(buckets)]
        self._counts = [0] * (buckets + 1)  # final slot is the +Inf bucket
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")

    @property
    def bounds(self) -> Tuple[float, ...]:
        return tuple(self._bounds)

    def observe(self, value: float) -> None:
        v = float(value)
        idx = bisect_left(self._bounds, v)  # first bound >= v, i.e. v <= le
        with self._lock:
            self._counts[idx] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    def bucket_index(self, value: float) -> int:
        """Index of the bucket ``observe(value)`` lands in (exposed for tests)."""
        return bisect_left(self._bounds, float(value))

    def quantile(self, q: float) -> float:
        with self._lock:
            return self._quantile_locked(q)

    def _quantile_locked(self, q: float) -> float:
        if self._count == 0:
            return 0.0
        rank = q * self._count
        cum = 0
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            prev = cum
            cum += c
            if cum >= rank:
                hi = self._bounds[i] if i < len(self._bounds) else self._max
                lo = self._bounds[i - 1] if i > 0 else 0.0
                hi = min(hi, self._max)
                lo = max(lo, self._min if self._min <= hi else lo)
                if hi <= lo:
                    return hi
                frac = (rank - prev) / c
                return lo + (hi - lo) * frac
        return self._max

    def snapshot(self) -> dict:
        with self._lock:
            cum = 0
            buckets = []
            for i, c in enumerate(self._counts[:-1]):
                cum += c
                buckets.append([self._bounds[i], cum])
            buckets.append([float("inf"), cum + self._counts[-1]])
            return {
                "count": self._count,
                "sum": self._sum,
                "min": self._min if self._count else 0.0,
                "max": self._max if self._count else 0.0,
                "p50": self._quantile_locked(0.50),
                "p95": self._quantile_locked(0.95),
                "p99": self._quantile_locked(0.99),
                "buckets": buckets,
            }


class MetricsRegistry:
    """Named instruments plus read-time *collectors*.

    Instruments are get-or-create by name (re-registering with the same
    name and type returns the existing instrument; a type clash raises).
    Collectors are zero-arg callables returning ``{full_name: value}``
    dicts, merged into the gauge section of every snapshot — the bridge
    that lets the existing ``*Stats`` dataclasses keep their in-place
    mutation style while exporting through the registry.
    """

    def __init__(self, stripes: int = _STRIPES):
        self._meta = threading.Lock()
        self._stripes = [threading.Lock() for _ in range(max(1, stripes))]
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._collectors: List[Callable[[], Dict[str, float]]] = []

    def _stripe(self, name: str) -> threading.Lock:
        return self._stripes[zlib.crc32(name.encode()) % len(self._stripes)]

    def counter(self, name: str, help: str = "") -> Counter:
        with self._meta:
            got = self._counters.get(name)
            if got is not None:
                return got
            self._check_free(name, self._counters)
            c = Counter(name, help, lock=self._stripe(name))
            self._counters[name] = c
            return c

    def gauge(self, name: str, help: str = "") -> Gauge:
        with self._meta:
            got = self._gauges.get(name)
            if got is not None:
                return got
            self._check_free(name, self._gauges)
            g = Gauge(name, help, lock=self._stripe(name))
            self._gauges[name] = g
            return g

    def histogram(self, name: str, help: str = "",
                  start: float = DEFAULT_START, factor: float = DEFAULT_FACTOR,
                  buckets: int = DEFAULT_BUCKETS) -> Histogram:
        with self._meta:
            got = self._histograms.get(name)
            if got is not None:
                return got
            self._check_free(name, self._histograms)
            h = Histogram(name, help, start=start, factor=factor,
                          buckets=buckets, lock=self._stripe(name))
            self._histograms[name] = h
            return h

    def _check_free(self, name: str, own: dict) -> None:
        for kind, table in (("counter", self._counters),
                            ("gauge", self._gauges),
                            ("histogram", self._histograms)):
            if table is not own and name in table:
                raise ValueError(f"metric {name!r} already registered as a {kind}")

    def register_collector(self, fn: Callable[[], Dict[str, float]]) -> None:
        with self._meta:
            self._collectors.append(fn)

    def metric_names(self) -> List[str]:
        """Every name this registry can emit right now (instruments plus
        whatever the collectors currently produce)."""
        snap = self.snapshot()
        names = set(snap["counters"]) | set(snap["gauges"]) | set(snap["histograms"])
        return sorted(names)

    def snapshot(self) -> dict:
        with self._meta:
            counters = list(self._counters.values())
            gauges = list(self._gauges.values())
            hists = list(self._histograms.items())
            collectors = list(self._collectors)
        out = {
            "counters": {c.name: c.value for c in counters},
            "gauges": {g.name: g.value for g in gauges},
            "histograms": {name: h.snapshot() for name, h in hists},
        }
        for fn in collectors:
            try:
                produced = fn()
            except Exception:
                continue  # a broken collector must never break the scrape
            for name, value in produced.items():
                try:
                    out["gauges"][name] = float(value)
                except (TypeError, ValueError):
                    continue
        return out

    def render_prometheus(self) -> str:
        return render_prometheus(self.snapshot())


def dataclass_gauges(prefix: str, obj: object,
                     lock: Optional[threading.Lock] = None,
                     extra: Optional[Callable[[], Dict[str, float]]] = None,
                     ) -> Callable[[], Dict[str, float]]:
    """Collector over every numeric attribute of a stats object.

    Reads ``obj.__dict__`` at snapshot time, exporting int/float fields
    as ``<prefix>_<field>`` gauges (bools and non-numerics skipped).
    ``lock`` is taken during the read when the stats object has one;
    ``extra`` merges derived values (means, list lengths) on top.
    """

    def collect() -> Dict[str, float]:
        out: Dict[str, float] = {}
        if lock is not None:
            lock.acquire()
        try:
            fields = dict(vars(obj))
        finally:
            if lock is not None:
                lock.release()
        for k, v in fields.items():
            if k.startswith("_") or isinstance(v, bool):
                continue
            if isinstance(v, (int, float)):
                out[f"{prefix}_{k}"] = float(v)
        if extra is not None:
            for k, v in extra().items():
                out[k] = float(v)
        return out

    return collect


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def render_prometheus(snapshot: dict) -> str:
    """Prometheus text-format (0.0.4) exposition of a registry snapshot.

    Histograms render the standard ``_bucket{le=...}`` / ``_count`` /
    ``_sum`` series plus non-standard ``_p50/_p95/_p99`` gauge
    convenience series (documented in docs/OBSERVABILITY.md).
    """
    lines: List[str] = []
    for name in sorted(snapshot.get("counters", {})):
        lines.append(f"# TYPE {name} counter")
        lines.append(f"{name} {_fmt(snapshot['counters'][name])}")
    for name in sorted(snapshot.get("gauges", {})):
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {_fmt(snapshot['gauges'][name])}")
    for name in sorted(snapshot.get("histograms", {})):
        h = snapshot["histograms"][name]
        lines.append(f"# TYPE {name} histogram")
        for le, cum in h["buckets"]:
            lines.append(f'{name}_bucket{{le="{_fmt(le)}"}} {int(cum)}')
        lines.append(f"{name}_count {int(h['count'])}")
        lines.append(f"{name}_sum {_fmt(h['sum'])}")
        for q in ("p50", "p95", "p99"):
            lines.append(f"# TYPE {name}_{q} gauge")
            lines.append(f"{name}_{q} {_fmt(h[q])}")
    return "\n".join(lines) + "\n"
