"""Canonical catalog of every metric name this repo can emit.

``docs/OBSERVABILITY.md`` documents the metric namespace and
``scripts/check_metrics_docs.py`` lints it against this module, so the
catalog — not grep — is the source of truth for "what can show up in a
scrape".  Names are derived the same way the runtime derives them:
dataclass introspection for the ``*Stats`` bridges (``dataclass_gauges``
exports every numeric field), plus the explicitly-registered counters
and histograms, plus the per-op and per-span histogram families expanded
from ``OP_NAMES`` / ``ENGINE_SPANS``.

Unlike the rest of ``repro.obs`` (stdlib-only, imported by every layer)
this module imports back into the repo to introspect the stats
dataclasses — which is why ``repro.obs.__init__`` does not re-export it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from .tracing import ENGINE_SPANS


def _numeric_fields(cls) -> List[str]:
    out = []
    for f in dataclasses.fields(cls):
        if f.type in ("int", "float", int, float):
            out.append(f.name)
    return out


def _dataclass_names(prefix: str, cls) -> List[str]:
    return [f"{prefix}_{name}" for name in _numeric_fields(cls)]


def stats_bridges() -> List[Tuple[str, type]]:
    """(prefix, dataclass) for every ``*Stats`` bridged via
    ``dataclass_gauges`` somewhere in the stack."""
    from ..cache.hierarchy import CacheStats
    from ..cluster.client import RpcStats
    from ..cluster.cluster_store import ClusterStats
    from ..cluster.migration import MigrationStats
    from ..cluster.server import ServerStats
    from ..core.lsm import LSMStats
    from ..core.store import StoreStats
    from ..runtime.executor import ExecutorStats
    from ..runtime.maintenance import MaintenanceStats
    from ..runtime.writebehind import CommitQueueStats
    from ..serving.engine import EngineStats

    return [
        ("repro_server", ServerStats),
        ("repro_store", StoreStats),
        ("repro_lsm", LSMStats),
        ("repro_cluster", ClusterStats),
        ("repro_migration", MigrationStats),
        ("repro_rpc", RpcStats),
        ("repro_engine", EngineStats),
        ("repro_cache", CacheStats),
        ("repro_executor", ExecutorStats),
        ("repro_commit_queue", CommitQueueStats),
        ("repro_maintenance", MaintenanceStats),
    ]


def catalog() -> Dict[str, List[str]]:
    """All emittable metric names, grouped by instrument kind."""
    from ..cluster import protocol as P

    gauges: List[str] = []
    for prefix, cls in stats_bridges():
        gauges.extend(_dataclass_names(prefix, cls))
    # derived values merged via collector ``extra`` callables
    gauges += [
        "repro_engine_mean_ttft_s",
        "repro_engine_mean_ttfb_s",
        "repro_engine_mean_hit",
        "repro_engine_streamed_fetches",
        "repro_cluster_nodes",
        "repro_cluster_live",
        "repro_cluster_replication",
        "repro_migration_active",
        # node backend probes (server-side collector)
        "repro_node_disk_bytes",
        "repro_node_file_count",
    ]

    counters = [
        "repro_node_trace_requests_total",
    ]

    histograms = [
        "repro_node_request_seconds",
        "repro_node_trace_server_span_seconds",
        "repro_engine_ttft_seconds",
        "repro_engine_io_wait_seconds",
    ]
    histograms += [f"repro_node_op_seconds_{name}" for name in P.OP_NAMES.values()]
    histograms += [f"repro_engine_span_seconds_{name}" for name in ENGINE_SPANS]

    return {
        "counters": sorted(set(counters)),
        "gauges": sorted(set(gauges)),
        "histograms": sorted(set(histograms)),
    }


def all_names() -> List[str]:
    cat = catalog()
    return sorted(set(cat["counters"]) | set(cat["gauges"]) | set(cat["histograms"]))


if __name__ == "__main__":
    for kind, names in catalog().items():
        print(f"# {kind}")
        for n in names:
            print(n)
