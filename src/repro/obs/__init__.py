"""Observability layer: metrics registry, tracing, and exposition.

Import surface is deliberately light (stdlib only) — ``runtime``,
``cache``, ``core`` and ``cluster`` all import from here, so this
package must not import back into them.  The metric-name catalog
(``repro.obs.catalog``), which *does* import the rest of the repo to
introspect stats dataclasses, is intentionally not re-exported here.
"""

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      dataclass_gauges, render_prometheus)
from .tracing import (ENGINE_SPANS, TRACE_ID_BYTES, TraceContext, activate,
                      current_trace, maybe_span)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "dataclass_gauges",
    "render_prometheus",
    "TraceContext",
    "activate",
    "current_trace",
    "maybe_span",
    "ENGINE_SPANS",
    "TRACE_ID_BYTES",
]
