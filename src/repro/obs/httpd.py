"""Prometheus-style scrape endpoint over a :class:`MetricsRegistry`.

Stdlib-only (``http.server``), one daemon thread, ephemeral-port
friendly.  Started by ``python -m repro.cluster.node --metrics-port N``;
``GET /metrics`` (or ``/``) returns the text exposition.
"""

from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from .metrics import MetricsRegistry

__all__ = ["MetricsHTTPServer"]

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsHTTPServer:
    """Serve ``registry.render_prometheus()`` at ``/metrics``.

    ``port=0`` binds an ephemeral port; read it back via ``.port``.
    """

    def __init__(self, registry: MetricsRegistry, host: str = "127.0.0.1",
                 port: int = 0):
        self.registry = registry

        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (http.server API)
                if self.path.split("?")[0] not in ("/", "/metrics"):
                    self.send_error(404)
                    return
                body = outer.registry.render_prometheus().encode()
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):  # keep node stdout parseable
                pass

        self._server = ThreadingHTTPServer((host, port), Handler)
        self._server.daemon_threads = True
        self.host, self.port = self._server.server_address[:2]
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        name="metrics-httpd", daemon=True)
        self._thread.start()

    @property
    def address(self):
        return (self.host, self.port)

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5.0)
