"""Lightweight request tracing.

A :class:`TraceContext` carries an 8-byte trace id and a list of named
span timings.  It is *activated* on the current thread; the single
cheap check everywhere on the hot path is ``current_trace()`` (a
thread-local read), so tracing costs nothing measurable when off.

Propagation:

- ``ServingEngine`` creates a trace per request (when constructed with
  ``tracing=True``) and activates it around plan/fulfill on the engine
  thread.
- ``IOExecutor.submit``/``try_submit`` capture the submitting thread's
  trace and re-activate it inside the worker, so spans recorded in
  ``CacheHierarchy.fetch`` (and any cluster fan-out beneath it) land on
  the right trace without explicit plumbing.
- The cluster client attaches the active trace id to outgoing mux
  frames (``FLAG_TRACE`` + 8 id bytes, see ``cluster/protocol.py``);
  the node server closes the trace out by timing the request into its
  ``repro_node_trace_server_span_seconds`` histogram and remembering
  the id in a recent-traces ring surfaced by ``OP_METRICS``.
"""

from __future__ import annotations

import os
import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

__all__ = [
    "TRACE_ID_BYTES",
    "ENGINE_SPANS",
    "TraceContext",
    "current_trace",
    "activate",
    "maybe_span",
]

TRACE_ID_BYTES = 8

# Every span name the engine-side pipeline can record; enumerated here
# so the metric catalog and docs lint can enumerate the derived
# repro_engine_span_seconds_<name> histograms.
ENGINE_SPANS = ("plan", "fetch", "fulfill", "compute", "commit")

_tls = threading.local()


class TraceContext:
    """One request's trace: an id plus thread-safe span timings.

    Spans are (name, offset_from_trace_start_s, duration_s) tuples;
    multiple spans may share a name (e.g. a hedged fetch records two
    ``fetch`` spans) — ``span_totals`` aggregates by name.
    """

    __slots__ = ("trace_id", "t0", "_spans", "_lock")

    def __init__(self, trace_id: Optional[str] = None):
        self.trace_id = trace_id if trace_id else os.urandom(TRACE_ID_BYTES).hex()
        self.t0 = time.perf_counter()
        self._spans: List[Tuple[str, float, float]] = []
        self._lock = threading.Lock()

    def id_bytes(self) -> bytes:
        return bytes.fromhex(self.trace_id)

    def add_span(self, name: str, start: float, duration_s: float) -> None:
        with self._lock:
            self._spans.append((name, start - self.t0, duration_s))

    @contextmanager
    def span(self, name: str):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.add_span(name, t0, time.perf_counter() - t0)

    @property
    def spans(self) -> List[Tuple[str, float, float]]:
        with self._lock:
            return list(self._spans)

    def span_totals(self) -> Dict[str, float]:
        """Total seconds per span name (hedged/repeated spans summed)."""
        out: Dict[str, float] = {}
        with self._lock:
            for name, _off, dur in self._spans:
                out[name] = out.get(name, 0.0) + dur
        return out


def current_trace() -> Optional[TraceContext]:
    """The trace active on this thread, or None. One thread-local read."""
    return getattr(_tls, "trace", None)


@contextmanager
def activate(trace: Optional[TraceContext]):
    """Make ``trace`` the current trace for the dynamic extent; restores
    the previous one on exit. ``activate(None)`` suppresses tracing."""
    prev = getattr(_tls, "trace", None)
    _tls.trace = trace
    try:
        yield trace
    finally:
        _tls.trace = prev


@contextmanager
def maybe_span(name: str):
    """Record ``name`` on the current trace if one is active, else no-op.

    The inactive path is one thread-local read and a None check — cheap
    enough to leave permanently on the plan/fetch/fulfill hot path.
    """
    tr = getattr(_tls, "trace", None)
    if tr is None:
        yield None
        return
    t0 = time.perf_counter()
    try:
        yield tr
    finally:
        tr.add_span(name, t0, time.perf_counter() - t0)
