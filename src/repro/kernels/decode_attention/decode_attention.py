"""Paged decode attention for TPU (Pallas) — the serving hot path fed by
the LSM store (DESIGN.md §3): KV blocks promoted from disk land in a paged
HBM pool; attention reads them through a block-table indirection.

TPU adaptation of GPU paged attention: instead of warp-level gather, the
page indirection lives in the BlockSpec ``index_map`` via scalar prefetch
(``pltpu.PrefetchScalarGridSpec``) — the block table is prefetched to SMEM
and each grid step DMAs exactly one (page x D) KV tile HBM->VMEM.  Online
softmax state (m, l, acc) is carried in VMEM scratch across the sequential
page axis; tiles are (G x page) and (page x D), MXU-friendly for G or page
>= 8.  Pages past ``kv_len`` are masked; whole pages past the end are
skipped via ``pl.when`` (no DMA cost on TPU for skipped blocks is NOT
guaranteed — the win is the compute skip; block tables should be
right-sized by the pool allocator anyway).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref, acc, m, l, *, page, scale):
    b = pl.program_id(0)
    i = pl.program_id(2)
    ni = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)
        acc[...] = jnp.zeros_like(acc)

    kv_len = lens_ref[b]
    base = i * page
    run = base < kv_len  # page intersects the valid prefix

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (page, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, page)
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l[...] = l[...] * corr + p.sum(axis=1, keepdims=True)
        acc[...] = acc[...] * corr + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m[...] = m_new

    @pl.when(i == ni - 1)
    def _finalize():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_kernel(q, k_pages, v_pages, block_tables, kv_len, *, interpret: bool = False):
    """q (B, KVH, G, D); k/v_pages (P, page, KVH, D); block_tables (B, NB);
    kv_len (B,).  Returns (B, KVH, G, D)."""
    B, KVH, G, D = q.shape
    P, page, _, _ = k_pages.shape
    NB = block_tables.shape[1]
    grid = (B, KVH, NB)

    def q_map(b, h, i, tables, lens):
        return (b, h, 0, 0)

    def kv_map(b, h, i, tables, lens):
        return (tables[b, i], 0, h, 0)

    def o_map(b, h, i, tables, lens):
        return (b, h, 0, 0)

    kern = functools.partial(_kernel, page=page, scale=D**-0.5)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), q_map),
                pl.BlockSpec((1, page, 1, D), kv_map),
                pl.BlockSpec((1, page, 1, D), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D), o_map),
            scratch_shapes=[
                pltpu.VMEM((G, D), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables, kv_len, q, k_pages, v_pages)
