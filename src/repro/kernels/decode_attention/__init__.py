from .ops import paged_decode, paged_decode_ref  # noqa: F401
