"""jit wrapper: model layout (B, H, D) -> grouped kernel layout, GQA."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .decode_attention import paged_decode_kernel
from .ref import paged_decode_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode(q, k_pages, v_pages, block_tables, kv_len, *, interpret: bool = False):
    """q (B, H, D); k/v_pages (P, page, KVH, D); block_tables (B, NB) int32;
    kv_len (B,) int32 -> (B, H, D)."""
    B, H, D = q.shape
    KVH = k_pages.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, D)
    out = paged_decode_kernel(qg, k_pages, v_pages, block_tables, kv_len, interpret=interpret)
    return out.reshape(B, H, D)


__all__ = ["paged_decode", "paged_decode_ref"]
