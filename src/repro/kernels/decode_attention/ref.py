"""Pure-jnp oracle for paged decode attention.

Gathers the block table back into a contiguous KV view and runs masked
single-token attention — the semantics the Pallas kernel must match.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def paged_decode_ref(q, k_pages, v_pages, block_tables, kv_len):
    """q (B, H, D); k/v_pages (P, page, KVH, D); block_tables (B, NB) int32
    page ids; kv_len (B,) valid tokens.  Returns (B, H, D)."""
    B, H, D = q.shape
    P, page, KVH, _ = k_pages.shape
    NB = block_tables.shape[1]
    G = H // KVH
    # gather pages -> (B, NB*page, KVH, D)
    k = k_pages[block_tables].reshape(B, NB * page, KVH, D)
    v = v_pages[block_tables].reshape(B, NB * page, KVH, D)
    T = NB * page
    qg = q.reshape(B, KVH, G, D).astype(F32)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(F32)) / (D**0.5)
    mask = jnp.arange(T)[None, :] < kv_len[:, None]  # (B, T)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(F32))
    return out.reshape(B, H, D).astype(q.dtype)
