"""Blocked causal flash attention for TPU (Pallas).

Grid ``(B, H, n_q, n_k)`` with the KV dimension innermost/sequential; the
online-softmax running state (m, l, acc) lives in VMEM scratch and is
carried across KV blocks.  Block shapes are MXU-aligned (multiples of 128
on the matmul dims); fully-masked KV blocks are skipped (causal schedule),
halving work for square prefills.  GQA is handled in the k/v index_map
(``h -> h // group``) — no KV replication in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m, l, *, scale, causal, q_offset, block_q, block_k):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)
        acc[...] = jnp.zeros_like(acc)

    q_start = q_offset + pl.program_id(2) * block_q
    k_start = ik * block_k
    # causal block skip: block computes only if some key is visible
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l[...] = l[...] * corr + p.sum(axis=1, keepdims=True)
        acc[...] = acc[...] * corr + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(
    q, k, v, *, causal: bool = True, q_offset: int = 0,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    """q (B, H, Sq, D); k/v (B, KVH, Skv, D).  Sq/Skv must be multiples of
    the block sizes (ops.py pads)."""
    B, H, Sq, D = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    G = H // KVH
    grid = (B, H, Sq // block_q, Skv // block_k)
    kern = functools.partial(
        _kernel, scale=D**-0.5, causal=causal, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
