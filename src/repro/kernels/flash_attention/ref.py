"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True, q_offset: int = 0):
    """q (B, H, Sq, D); k/v (B, KVH, Skv, D); GQA via head grouping.
    Query i sits at absolute position q_offset + i; key j at position j."""
    B, H, Sq, D = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, k.astype(jnp.float32)) * (D**-0.5)
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        kpos = jnp.arange(Skv)
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)
