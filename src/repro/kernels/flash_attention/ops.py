"""jit-ready wrapper: layout handling, padding to block multiples, GQA."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_kernel
from .ref import attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *, causal: bool = True, q_offset: int = 0,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    """q (B, Sq, H, D); k/v (B, Skv, KVH, D) — model layout.  Pads sequence
    dims to block multiples (keys padded at the tail are masked by causality
    when q_offset + Sq == Skv; for non-causal use explicit Skv multiple)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Skv))
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    if not causal and pad_k:
        raise ValueError("non-causal flash requires Skv % block_k == 0 (pad keys are unmaskable)")
    out = flash_attention_kernel(
        qt, kt, vt, causal=causal, q_offset=q_offset,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    out = out[:, :, :Sq, :]
    return jnp.moveaxis(out, 2, 1)


__all__ = ["flash_attention", "attention_ref"]
