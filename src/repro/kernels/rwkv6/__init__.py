from .ops import wkv, wkv_ref  # noqa: F401
