"""Chunked RWKV6 WKV scan for TPU (Pallas).

TPU adaptation of the CUDA WKV kernel: the per-(batch, head) recurrent
state S (N x N, f32) lives in VMEM scratch for the *entire* sequence — the
grid iterates chunks sequentially per (b, h), so S never round-trips HBM
between tokens (the XLA scan moves B*H*N*N*4 bytes of state per token;
this kernel moves only r/k/v/w in and y out).

Inside a chunk the recurrence is evaluated in closed form with MXU matmuls
(FLA-style intra-chunk decomposition) rather than a token loop:

    cum_t = prod_{j<=t} w_j            (cumulative decay within the chunk)
    inter: y_t += (r_t ∘ cum_{t-1}) S_0
    intra: y_t += sum_{j<t} [ (r_t ∘ cum_{t-1}/cum_j) · k_j ] v_j
         + diag:  (r_t · (u ∘ k_t)) v_t
    S_new = diag(cum_C) S_0 + sum_j ((cum_C/cum_j) ∘ k_j) v_j^T

Decay ratios cum_{t-1}/cum_j (j < t) are always <= 1; the inverse factors
k_j/cum_j are bounded by the chunk length (default 32), keeping f32 safe —
same trade-off FLA makes on GPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, s_scratch, *, chunk):
    i = pl.program_id(2)
    ni = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        s_scratch[...] = s0_ref[0, 0]

    r = r_ref[0, 0].astype(jnp.float32)  # (C, N)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (1, N) -> broadcast
    S = s_scratch[...]  # (N, N)
    C, N = r.shape

    # clamp the per-token log-decay so exp(-cum) stays finite in f32 within
    # a chunk (a channel decaying below e^-80/chunk has forgotten its state
    # to sub-f32 resolution anyway) — same rule as models.ssm.wkv_chunked
    logw = jnp.maximum(jnp.log(jnp.maximum(w, 1e-38)), -80.0 / C)
    cum = jnp.cumsum(logw, axis=0)  # log cum_t, (C, N)
    cum_prev = cum - logw  # log cum_{t-1}
    r_decay = r * jnp.exp(cum_prev)  # r_t ∘ cum_{t-1}
    k_scaled = k * jnp.exp(-cum)  # k_j / cum_j

    # inter-chunk: contribution of the carried state
    y = jax.lax.dot_general(r_decay, S, (((1,), (0,)), ((), ())))  # (C, N)

    # intra-chunk: strictly-lower-triangular attention + u-weighted diagonal
    A = jax.lax.dot_general(r_decay, k_scaled, (((1,), (1,)), ((), ())))  # (C, C)
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    A = jnp.where(tj < ti, A, 0.0)
    diag = jnp.sum(r * u * k, axis=1)  # (C,)
    y = y + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())))
    y = y + diag[:, None] * v
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S_new = diag(cum_C) S + (k ∘ cum_C/cum)ᵀ v
    cum_C = cum[C - 1 : C, :]  # (1, N) log total decay
    k_rem = k * jnp.exp(cum_C - cum)  # (C, N)
    S_new = jnp.exp(cum_C).T * S + jax.lax.dot_general(
        k_rem, v, (((0,), (0,)), ((), ()))
    )
    s_scratch[...] = S_new

    @pl.when(i == ni - 1)
    def _final():
        sT_ref[0, 0] = S_new


def wkv_kernel(r, k, v, w, u, state, *, chunk: int = 32, interpret: bool = False):
    """r/k/v/w (B, H, S, N); u (H, N); state (B, H, N, N) f32.
    S % chunk == 0 (ops.py pads).  Returns (y (B,H,S,N) f32, state')."""
    B, H, S, N = r.shape
    grid = (B, H, S // chunk)
    kern = functools.partial(_kernel, chunk=chunk)
    seq_spec = pl.BlockSpec((1, 1, chunk, N), lambda b, h, i: (b, h, i, 0))
    state_spec = pl.BlockSpec((1, 1, N, N), lambda b, h, i: (b, h, 0, 0))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, N), lambda b, h, i: (h, 0)),
            state_spec,
        ],
        out_specs=[seq_spec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, N), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u, state)
