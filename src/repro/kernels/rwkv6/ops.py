"""jit wrapper: sequence padding (pad steps use decay w=1, k=0 so they are
exact no-ops on the state), layout handling."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import wkv_ref
from .rwkv6 import wkv_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r, k, v, w, u, state, *, chunk: int = 32, interpret: bool = False):
    """r/k/v/w (B, H, S, N); u (H, N); state (B, H, N, N) f32.
    Returns (y (B, H, S, N) f32, new_state (B, H, N, N) f32)."""
    B, H, S, N = r.shape
    c = min(chunk, S) if S % min(chunk, S) == 0 else chunk
    pad = (-S) % c
    if pad:
        zpad = ((0, 0), (0, 0), (0, pad), (0, 0))
        r = jnp.pad(r, zpad)
        k = jnp.pad(k, zpad)  # k=0 -> no state update from pad steps
        v = jnp.pad(v, zpad)
        w = jnp.pad(w, zpad, constant_values=1.0)  # w=1 -> no decay
    y, s = wkv_kernel(r, k, v, w, u, state, chunk=c, interpret=interpret)
    return y[:, :, :S, :], s


__all__ = ["wkv", "wkv_ref"]
