"""Pure-jnp oracle for the RWKV6 WKV recurrence — identical math to
``repro.models.ssm.rwkv6_time_mix``'s inner scan.

    y_t   = r_t · (S_{t-1} + (u ∘ k_t) v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def wkv_ref(r, k, v, w, u, state):
    """r/k/v/w (B, H, S, N); u (H, N); state (B, H, N, N) f32.
    Returns (y (B, H, S, N) f32, new_state (B, H, N, N) f32)."""
    B, H, S, N = r.shape

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B, H, N)
        kv = kt[..., :, None] * vt[..., None, :]  # (B, H, N, N)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, y

    xs = tuple(t.transpose(2, 0, 1, 3).astype(F32) for t in (r, k, v, w))
    s_new, ys = jax.lax.scan(step, state.astype(F32), xs)
    return ys.transpose(1, 2, 0, 3), s_new
