from .ops import ssd, ssd_ref  # noqa: F401
