"""jit wrapper: sequence padding (pad steps use a=1, dt=0 — exact no-ops
on the state), layout handling."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .mamba2 import ssd_kernel
from .ref import ssd_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, Bm, Cm, a, dt, state, *, chunk: int = 64, interpret: bool = False):
    """x (B,S,H,P); Bm/Cm (B,S,N); a/dt (B,S,H); state (B,H,P,N) f32.
    Returns (y (B,S,H,P) f32, new_state)."""
    B, S, H, P = x.shape
    c = min(chunk, S) if S % min(chunk, S) == 0 else chunk
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, s = ssd_kernel(x, Bm, Cm, a, dt, state, chunk=c, interpret=interpret)
    return y[:, :S], s


__all__ = ["ssd", "ssd_ref"]
