"""Chunked Mamba-2 SSD scan for TPU (Pallas).

Same TPU adaptation as the RWKV6 kernel: the per-(batch, head) SSM state
(P x N, f32) lives in VMEM scratch across the whole sequence; chunks
stream through sequentially and intra-chunk work is MXU matmuls
(the Mamba-2 paper's own chunked decomposition, §6):

    cum_t   = sum_{j<=t} log a_j                 (within chunk)
    y_intra = (C Bᵀ ∘ exp(cum_t - cum_j) ∘ dt_j, j<=t) X
    y_inter = exp(cum_t) * (C Sᵀ)
    S'      = exp(cum_C) S + Xᵀ (dt ∘ exp(cum_C - cum)) B

Per-head decay is a scalar, so the log-difference is formed before exp and
the kept entries have exponent <= 0 — exact, no clamp needed (masked
entries get -inf pre-exp).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, b_ref, c_ref, a_ref, dt_ref, s0_ref, y_ref, sT_ref, s_scratch, *, chunk):
    i = pl.program_id(2)
    ni = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        s_scratch[...] = s0_ref[0, 0]

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (C, P)
    bm = b_ref[0].astype(jnp.float32)  # (C, N)
    cm = c_ref[0].astype(jnp.float32)  # (C, N)
    a = a_ref[0, :, 0].astype(jnp.float32)  # (C,)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (C,)
    S = s_scratch[...]  # (P, N)
    C = x.shape[0]

    loga = jnp.log(jnp.maximum(a, 1e-38))
    cum = jnp.cumsum(loga)  # (C,)
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    delta = cum[:, None] - cum[None, :]
    L = jnp.exp(jnp.where(ti >= tj, delta, -jnp.inf))  # (C, C), incl diag

    G = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))  # (C, C)
    W = G * L * dt[None, :]
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())))  # intra (C, P)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, S, (((1,), (1,)), ((), ()))
    )  # inter: C·Sᵀ -> (C, P)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    decay_rem = jnp.exp(cum[C - 1] - cum) * dt  # (C,)
    S_new = jnp.exp(cum[C - 1]) * S + jax.lax.dot_general(
        x * decay_rem[:, None], bm, (((0,), (0,)), ((), ()))
    )
    s_scratch[...] = S_new

    @pl.when(i == ni - 1)
    def _final():
        sT_ref[0, 0] = S_new


def ssd_kernel(x, Bm, Cm, a, dt, state, *, chunk: int = 64, interpret: bool = False):
    """x (B,S,H,P); Bm/Cm (B,S,N); a/dt (B,S,H); state (B,H,P,N) f32.
    S % chunk == 0 (ops.py pads).  Returns (y (B,S,H,P) f32, state')."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    grid = (B, H, S // chunk)
    kern = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, i: (b, i, h)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, i: (b, i, h)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, Bm, Cm, a, dt, state)
