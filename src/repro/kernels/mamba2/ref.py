"""Pure-jnp oracle for the Mamba-2 SSD recurrence — identical math to
``repro.models.ssm.mamba2_mix``'s sequential step:

    S_t = a_t S_{t-1} + dt_t (x_t ⊗ B_t)
    y_t = C_t · S_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def ssd_ref(x, Bm, Cm, a, dt, state):
    """x (B,S,H,P); Bm/Cm (B,S,N); a/dt (B,S,H); state (B,H,P,N) f32.
    Returns (y (B,S,H,P) f32, new_state (B,H,P,N) f32)."""

    def step(s, inp):
        xt, bt, ct, at, dtt = inp  # (B,H,P),(B,N),(B,N),(B,H),(B,H)
        upd = dtt[..., None, None] * (xt[..., :, None] * bt[:, None, None, :])
        s_new = at[..., None, None] * s + upd
        y = jnp.einsum("bhpn,bn->bhp", s_new, ct)
        return s_new, y

    xs = (
        x.transpose(1, 0, 2, 3).astype(F32),
        Bm.transpose(1, 0, 2).astype(F32),
        Cm.transpose(1, 0, 2).astype(F32),
        a.transpose(1, 0, 2).astype(F32),
        dt.transpose(1, 0, 2).astype(F32),
    )
    s_new, ys = jax.lax.scan(step, state.astype(F32), xs)
    return ys.transpose(1, 0, 2, 3), s_new
