"""Pure-jnp oracle for the batch KV codec (per-channel symmetric int8).

Bit-identical to the host-side ``repro.core.codec.quantize_int8`` on the
same input (same scale rule: absmax/127 over all leading axes, scale 1.0
where a channel is all-zero).
"""

from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x):
    """x (..., C) -> (q int8 (..., C), scale f32 (C,))."""
    xf = x.astype(jnp.float32)
    red = tuple(range(xf.ndim - 1))
    absmax = jnp.max(jnp.abs(xf), axis=red)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale).astype(dtype)
