from .ops import dequantize, dequantize_ref, quantize, quantize_ref  # noqa: F401
