"""Pallas TPU kernel for the paper's batch codec (§3.4): per-channel
symmetric int8 quantization of KV-cache blocks before they are DMA'd to the
host / tensor log, and the matching dequantization on load.

Layout: blocks arrive flattened to (T, C) — T = tokens x heads rows,
C = channels (the quantization axis, matching the host codec).  Grid is 1-D
over C tiles; each program reads a (T, bc) tile resident in VMEM, reduces
absmax over rows (VPU), and emits the int8 tile plus the (1, bc) scale row.
C tiles are lane-aligned (128); T is the sublane dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (T, bc)
    absmax = jnp.max(jnp.abs(x), axis=0, keepdims=True)  # (1, bc)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s_ref[...]).astype(o_ref.dtype)


def quantize_kernel(x, *, block_c: int = 512, interpret: bool = False):
    """x (T, C) -> (q int8 (T, C), scale f32 (1, C)).  C % block_c == 0."""
    T, C = x.shape
    grid = (C // block_c,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((T, block_c), lambda c: (0, c))],
        out_specs=[
            pl.BlockSpec((T, block_c), lambda c: (0, c)),
            pl.BlockSpec((1, block_c), lambda c: (0, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, C), jnp.int8),
            jax.ShapeDtypeStruct((1, C), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)


def dequantize_kernel(q, scale, *, out_dtype=jnp.bfloat16, block_c: int = 512, interpret: bool = False):
    """q (T, C) int8, scale (1, C) f32 -> x (T, C) out_dtype."""
    T, C = q.shape
    grid = (C // block_c,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, block_c), lambda c: (0, c)),
            pl.BlockSpec((1, block_c), lambda c: (0, c)),
        ],
        out_specs=pl.BlockSpec((T, block_c), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((T, C), out_dtype),
        compiler_params=pltpu.CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q, scale)
