"""jit wrappers: arbitrary (..., C) tensors, channel padding to the lane
multiple, scale layout matching the host codec."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kv_codec import dequantize_kernel, quantize_kernel
from .ref import dequantize_ref, quantize_ref


def _plan(C: int, block_c: int):
    """Pad C up to a lane multiple and pick a dividing block size."""
    Cp = -(-C // 128) * 128
    bc = block_c if Cp % block_c == 0 else 128
    return Cp, bc


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def quantize(x, *, block_c: int = 512, interpret: bool = False):
    """x (..., C) -> (q int8 (..., C), scale f32 (C,))."""
    shape = x.shape
    C = shape[-1]
    Cp, bc = _plan(C, block_c)
    xf = x.reshape(-1, C)
    if Cp != C:
        xf = jnp.pad(xf, ((0, 0), (0, Cp - C)))
    q, s = quantize_kernel(xf, block_c=bc, interpret=interpret)
    return q[:, :C].reshape(shape), s[0, :C]


@functools.partial(jax.jit, static_argnames=("out_dtype", "block_c", "interpret"))
def dequantize(q, scale, *, out_dtype=jnp.bfloat16, block_c: int = 512, interpret: bool = False):
    shape = q.shape
    C = shape[-1]
    Cp, bc = _plan(C, block_c)
    qf = q.reshape(-1, C)
    sf = scale.reshape(1, C).astype(jnp.float32)
    if Cp != C:
        qf = jnp.pad(qf, ((0, 0), (0, Cp - C)))
        sf = jnp.pad(sf, ((0, 0), (0, Cp - C)), constant_values=1.0)
    x = dequantize_kernel(qf, sf, out_dtype=out_dtype, block_c=bc, interpret=interpret)
    return x[:, :C].reshape(shape)


__all__ = ["quantize", "dequantize", "quantize_ref", "dequantize_ref"]
