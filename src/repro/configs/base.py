"""Model/shape configuration system.

Every assigned architecture gets a module in this package defining
``CONFIG`` (the exact published dimensions) and ``SMOKE`` (a reduced
same-family config for CPU tests).  ``repro.configs.get_config`` resolves
them by id.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | rwkv6 | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # attention flavour
    attention: str = "gqa"  # gqa | mla | none (attention-free)
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True

    # MLA (DeepSeek/MiniCPM3 style latent KV compression)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM / RWKV / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    d_conv: int = 4
    expand: int = 2
    attn_every: int = 0  # hybrid: shared attention block period (in layers)

    # encoder-decoder (whisper): decoder uses the top-level dims
    n_enc_layers: int = 0
    enc_frames: int = 1500  # stub frontend output length (precomputed embeds)

    dtype: str = "bfloat16"
    notes: str = ""
    source: str = ""

    # execution knobs (set by step builders, not per-arch constants)
    remat: bool = False  # checkpoint each block in the layer scan (training)
    seq_shard: bool = False  # Megatron-style sequence parallelism between blocks

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(1, self.n_heads))

    @property
    def kv_bytes_per_token(self) -> int:
        """bf16 KV-cache bytes per token (the paper's per-model axis in
        Fig. 5: 40/60/120 KB per token across GLM/Llama)."""
        if self.attention == "mla":
            per_layer = self.kv_lora_rank + self.qk_rope_dim
        elif self.family == "rwkv6":
            return 0  # constant-size state, not per-token
        else:
            per_layer = 2 * self.n_kv_heads * self.d_head
        n_attn_layers = self.n_layers if self.attn_every == 0 else self.n_layers // self.attn_every
        return per_layer * n_attn_layers * 2  # bf16

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        emb = V * d * 2  # in + out embedding
        if self.family == "rwkv6":
            per = d * d * 4 + d * f * 2 + d * 64 * 8  # mixers + channel mix (approx lora)
            return emb + L * per
        if self.attention == "mla":
            attn = (
                self.d_model * (self.q_lora_rank or self.d_model)
                + (self.q_lora_rank or 0) * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                + self.d_model * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * self.d_model
            )
        else:
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head + self.n_heads * self.d_head * d
        if self.family == "moe":
            ff = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts  # experts + router
        else:
            ff = 3 * d * f
        per = attn + ff
        if self.family == "hybrid":
            # mamba2 blocks + one shared attention block
            d_in = self.expand * d
            per = 2 * d * d_in + d_in * d + d_in * self.d_conv  # in/out proj + conv
            shared = attn + 3 * d * f
            return emb + L * per + shared
        if self.family == "encdec":
            enc_per = attn + 3 * d * f
            dec_per = attn * 2 + 3 * d * f  # self + cross
            return emb + self.n_enc_layers * enc_per + L * dec_per
        return emb + L * per

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * self.n_experts * 3 * d * self.moe_d_ff
        return dense + self.n_layers * self.experts_per_token * 3 * d * self.moe_d_ff


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs that may run long_500k (sub-quadratic / constant-state decode);
# pure full-attention archs skip it (DESIGN.md §4)
LONG_CONTEXT_OK = {"rwkv6-1.6b", "zamba2-1.2b"}


def smoke_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    base = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)) if cfg.n_kv_heads else 0,
        d_head=16,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.family == "moe":
        base.update(n_experts=4, experts_per_token=2, moe_d_ff=64)
    if cfg.attention == "mla":
        base.update(q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8, qk_nope_dim=8, v_head_dim=16)
    if cfg.family in ("rwkv6", "hybrid"):
        base.update(ssm_state=8, ssm_heads=4 if cfg.family == "hybrid" else 0)
    if cfg.family == "hybrid":
        base.update(attn_every=2, expand=2)
    if cfg.family == "encdec":
        base.update(n_enc_layers=2, enc_frames=16)
    base.update(overrides)
    base["name"] = cfg.name + "-smoke"
    return dataclasses.replace(cfg, **base)
