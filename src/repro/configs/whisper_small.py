"""Whisper-small — encoder-decoder ASR backbone; conv frontend is a stub
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,  # decoder layers
    n_enc_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    enc_frames=1500,
    causal=True,
    source="arXiv:2212.04356",
    notes="modality frontend stubbed per assignment; decoder prefix reuse only",
)
