"""MiniCPM3-4B — dense with Multi-head Latent Attention (MLA)
[hf:openbmb/MiniCPM3-4B]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_rope_dim=32,
    qk_nope_dim=64,
    v_head_dim=64,
    source="hf:openbmb/MiniCPM3-4B",
    notes="MLA: disk store caches the compressed latent (kv_lora+rope) per token",
)
