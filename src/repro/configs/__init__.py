"""Architecture registry: the 10 assigned architectures + paper-eval models.

Each module defines CONFIG (exact published dims) and the registry maps
``--arch <id>`` to it.  ``get_config(id, smoke=True)`` returns the reduced
same-family variant used by CPU smoke tests.
"""

from __future__ import annotations

from .base import LONG_CONTEXT_OK, SHAPES, ModelConfig, ShapeConfig, smoke_variant

from . import (  # noqa: E402
    chameleon_34b,
    glm4_9b,
    kimi_k2_1t_a32b,
    minicpm3_4b,
    olmoe_1b_7b,
    qwen25_32b,
    qwen3_14b,
    rwkv6_1b6,
    whisper_small,
    zamba2_1b2,
)

REGISTRY = {
    "olmoe-1b-7b": olmoe_1b_7b.CONFIG,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b.CONFIG,
    "rwkv6-1.6b": rwkv6_1b6.CONFIG,
    "whisper-small": whisper_small.CONFIG,
    "minicpm3-4b": minicpm3_4b.CONFIG,
    "qwen2.5-32b": qwen25_32b.CONFIG,
    "qwen3-14b": qwen3_14b.CONFIG,
    "glm4-9b": glm4_9b.CONFIG,
    "chameleon-34b": chameleon_34b.CONFIG,
    "zamba2-1.2b": zamba2_1b2.CONFIG,
}

ARCH_IDS = list(REGISTRY)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    cfg = REGISTRY[arch]
    return smoke_variant(cfg) if smoke else cfg


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells with skip annotations (DESIGN.md §4)."""
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            skip = None
            if shape.name == "long_500k" and arch not in LONG_CONTEXT_OK:
                skip = "full-attention arch: 500k dense decode out of scope (DESIGN.md §4)"
            if skip is None or include_skipped:
                out.append((arch, shape.name, skip))
    return out


__all__ = [
    "REGISTRY",
    "ARCH_IDS",
    "SHAPES",
    "LONG_CONTEXT_OK",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "smoke_variant",
    "cells",
]
