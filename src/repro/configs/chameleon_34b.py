"""Chameleon-34B — early-fusion VLM backbone; VQ image tokens share the text
vocabulary, so the frontend stub is the token stream itself
[arXiv:2405.09818]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,  # chameleon stabilizes early fusion with qk-norm
    source="arXiv:2405.09818",
    notes="early fusion = unified token space; image tokenizer stubbed",
)
