"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 (paper-table dims)
[arXiv:2501.kimi2; unverified]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    n_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    capacity_factor=1.0,  # dropping dispatch at trillion scale
    source="arXiv:2501.kimi2 (assignment table; unverified)",
    notes="~1.03T total params, ~32B active; EP+FSDP mandatory",
)
