"""GLM-4-9B — dense, aggressive GQA (2 KV heads), RoPE [hf:THUDM/glm-4-9b]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    source="hf:THUDM/glm-4-9b",
    notes="smallest KV/token of the dense set -> paper Fig.5 sweet spot",
)
