"""Zamba2-1.2B — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_head=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=64,  # d_inner(4096) / 64
    expand=2,
    d_conv=4,
    attn_every=6,  # shared transformer block applied every 6 mamba layers
    source="arXiv:2411.15242; hf",
    notes="mamba2 state snapshots + shared-attn token KV both stored under prefix keys",
)
