"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060; hf]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    experts_per_token=8,
    moe_d_ff=1024,
    qk_norm=True,  # OLMoE uses QK-norm
    source="arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924",
)
