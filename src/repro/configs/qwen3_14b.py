"""Qwen3-14B — dense GQA with QK-norm [hf:Qwen/Qwen3-14B]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    source="hf:Qwen/Qwen3-14B (family config per assignment)",
)
