"""Hierarchical KV-cache manager: radix tree + device/host memory tiers +
a pluggable disk backend (any ``repro.core.backend.StorageBackend``:
``KVBlockStore``, ``ShardedKVBlockStore``, or one of the paper's
baselines).  This layer depends only on the protocol — backend choice is
a constructor argument.

This is the integration point the paper describes in §3.2: the in-memory
radix tree and RadixAttention logic are preserved; only the disk backend
behind it is swapped.  ``acquire`` implements the longest-prefix reuse path
(radix match, then a disk ``probe`` to extend the match, then ``get_batch``
promotion), and ``commit`` implements write-through population.

``acquire`` is factored into three phases so the serving engine can
pipeline them (paper §3.4 batch operations):

    plan(tokens)    -> AcquirePlan   radix match only; engine thread
    fetch(plan)     -> DiskFetch     backend probe + batched get_batch;
                                     touches ONLY the (thread-safe) store,
                                     so it can run on an I/O executor while
                                     the engine computes the previous batch
    fulfill(plan, fetch) -> Acquisition   install/promote; engine thread

``acquire`` = plan → fetch → fulfill, so the serial path is the same code.
``fulfill`` re-matches the radix tree rather than trusting the plan — a
batch committed between plan and fulfill may have grown the tree, and a
prefetch must never install stale state.

Compression tiers are invisible here: a block demoted to int8 or
int8+zlib (``core.tiering``) travels still-encoded through the store and
over the cluster wire (``LAYOUT_ENCODED`` / vlog chunks) and is decoded
at the fulfill boundary — locally by ``get_batch``, remotely by the
client's chunk decode as ``_StreamedBlocks`` drains — so ``fulfill``
always installs dense tensors and never sees a codec tag.  With a
streamed fetch that decode is lazy: a cold block still on the wire is
not decompressed until ``fulfill`` asks for its index.

``commit`` installs into device memory on the engine thread and, when a
``CommitQueue`` is attached, hands the disk write-through to the
write-behind drain thread instead of charging it to the request.
The radix tree itself is single-threaded by design: only the engine thread
ever mutates it (fetch closures capture token lists, never nodes).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.backend import StorageBackend
from ..obs.tracing import maybe_span
from ..runtime.writebehind import CommitQueue
from .radix import (
    TIER_DEVICE,
    TIER_DISK,
    TIER_HOST,
    TIER_NONE,
    RadixNode,
    RadixTree,
)


@dataclass
class CacheStats:
    requests: int = 0
    tokens_requested: int = 0
    tokens_hit_device: int = 0
    tokens_hit_host: int = 0
    tokens_hit_disk: int = 0
    tokens_missed: int = 0
    promote_s: float = 0.0  # disk -> memory I/O time
    streamed_fetches: int = 0  # fetches served over a streaming backend
    first_block_s: float = 0.0  # summed time-to-first-block of those fetches
    demotions: int = 0
    drops: int = 0
    writeback_blocks: int = 0  # commits handed to the write-behind queue
    plan_stale: int = 0  # prefetch plans that fulfill found outdated

    @property
    def hit_rate(self) -> float:
        hit = self.tokens_hit_device + self.tokens_hit_host + self.tokens_hit_disk
        return hit / max(1, self.tokens_requested)


@dataclass
class Acquisition:
    nodes: List[RadixNode]
    reuse_tokens: int  # tokens whose KV is now device-resident
    device_tokens: int
    host_tokens: int
    disk_tokens: int
    io_s: float  # measured promotion I/O time


@dataclass
class AcquirePlan:
    """Phase 1 of acquire: what the radix tree knew at plan time.  Carries
    only token lists and counts — never tree nodes — so the fetch phase can
    run on another thread without touching shared tree state."""

    tokens: List[int]
    chain_blocks: int  # radix-matched blocks at plan time
    disk_chain_depth: int  # deepest matched node whose data lives only on disk
    total_blocks: int

    @property
    def need_disk(self) -> bool:
        return self.disk_chain_depth > 0 or self.chain_blocks < self.total_blocks


@dataclass
class DiskFetch:
    """Phase 2 result: the contiguous disk prefix (blocks from index 0).

    ``blocks`` is either a plain list or a lazy ``_StreamedBlocks`` whose
    tail is still on the wire; ``fulfill`` touches it only through
    ascending indices and slices, so streamed blocks are consumed in
    arrival order.  ``first_block_s`` is the fetch-relative
    time-to-first-block (None when the backend doesn't stream or the
    fetch was empty)."""

    probed_tokens: int = 0
    blocks: Sequence[np.ndarray] = field(default_factory=list)
    io_s: float = 0.0
    first_block_s: Optional[float] = None


class _StreamedBlocks:
    """List-shaped view over a streaming get: blocks materialize as the
    wire delivers them, and indexing drains the stream only as far as
    asked — so ``fulfill`` installs block 0 while blocks 1..N are still
    in flight.  A transport failure mid-stream truncates the sequence
    (the hierarchy already treats a short disk read as a shorter hit);
    it never raises into the tree-mutation path."""

    def __init__(self, stream):
        self._it = iter(stream)
        self._got: List[np.ndarray] = []
        self._done = False

    def _pull_to(self, n: int) -> None:
        while not self._done and len(self._got) < n:
            try:
                blk = next(self._it)
            except StopIteration:
                self._done = True
            except (ConnectionError, OSError):
                self._done = True  # replicas exhausted: keep the prefix
            else:
                self._got.append(blk)

    def prime(self) -> bool:
        """Pull block 0 (the time-to-first-block moment)."""
        self._pull_to(1)
        return bool(self._got)

    def close(self) -> None:
        """Abort without draining — the consumer took what it needed;
        chunks still in flight are dropped by the transport."""
        self._done = True
        close = getattr(self._it, "close", None)
        if close is not None:
            close()

    def __len__(self) -> int:
        self._pull_to(1 << 62)
        return len(self._got)

    def __getitem__(self, i):
        if isinstance(i, slice):
            if i.stop is None or i.stop < 0 or (i.start or 0) < 0 or i.step not in (None, 1):
                self._pull_to(1 << 62)
            else:
                self._pull_to(i.stop)
            return self._got[i]
        if i < 0:
            self._pull_to(1 << 62)
        else:
            self._pull_to(i + 1)
        return self._got[i]


def _block_at(blocks: Sequence[np.ndarray], i: int) -> Optional[np.ndarray]:
    """``blocks[i]`` or None — without forcing a lazy sequence to drain
    to its end just to answer a bounds check."""
    if i < 0:
        return None
    try:
        return blocks[i]
    except IndexError:
        return None


class CacheHierarchy:
    def __init__(
        self,
        block_size: int,
        device_budget_blocks: int,
        host_budget_blocks: int,
        store: Optional[StorageBackend] = None,  # disk backend, or None (memory-only)
        write_through: bool = True,
        commit_queue: Optional[CommitQueue] = None,  # write-behind; None = inline
    ):
        self.tree = RadixTree(block_size)
        self.block_size = block_size
        self.device_budget = device_budget_blocks
        self.host_budget = host_budget_blocks
        self.store = store
        self.write_through = write_through
        self.commit_queue = commit_queue
        self.device_blocks = 0
        self.host_blocks = 0
        self.stats = CacheStats()

    # ------------------------------------------------------------ internals
    def _make_room(self, tier: int, need: int) -> None:
        """Demote LRU leaves until `need` blocks fit in `tier`."""
        if tier == TIER_DEVICE:
            budget, used = self.device_budget, self.device_blocks
        else:
            budget, used = self.host_budget, self.host_blocks
        overflow = used + need - budget
        while overflow > 0:
            leaves = self.tree.evictable_leaves(tier)
            if not leaves:
                break  # everything locked: admit over budget rather than stall
            # demote as many of this frontier as needed, then re-derive the
            # frontier (parents become evictable once children leave)
            for leaf in leaves[:overflow]:
                self._demote(leaf)
            overflow -= min(overflow, len(leaves))

    def _demote(self, node: RadixNode) -> None:
        if node.tier == TIER_DEVICE:
            self._make_room(TIER_HOST, 1)
            if self.host_blocks < self.host_budget:
                node.tier = TIER_HOST
                self.host_blocks += 1
            else:
                self._spill_to_disk(node)
            self.device_blocks -= 1
            self.stats.demotions += 1
        elif node.tier == TIER_HOST:
            self._spill_to_disk(node)
            self.host_blocks -= 1
            self.stats.demotions += 1

    def _spill_to_disk(self, node: RadixNode) -> None:
        if self.store is not None and not node.on_disk and node.data is not None:
            tokens = self._path_tokens(node)
            self.store.put_batch(tokens, [node.data], start_block=node.depth - 1)
            node.on_disk = True
        node.data = None
        if self.store is not None and node.on_disk:
            node.tier = TIER_DISK
        else:
            # no disk backend: block is lost (the memory-only baseline)
            node.tier = TIER_NONE
            self.stats.drops += 1
            self.tree.drop(node)

    @staticmethod
    def _path_tokens(node: RadixNode) -> List[int]:
        toks: List[int] = []
        chain = []
        cur = node
        while cur is not None and cur.parent is not None:
            chain.append(cur)
            cur = cur.parent
        for n in reversed(chain):
            toks.extend(n.block)
        return toks

    # ---------------------------------------------------------------- acquire
    def plan(self, tokens: Sequence[int]) -> AcquirePlan:
        """Phase 1 (engine thread): radix match; decide what disk I/O the
        fetch phase should issue.  Does not lock or mutate tier state."""
        with maybe_span("plan"):
            B = self.block_size
            chain = self.tree.match_prefix(tokens)
            disk_depth = max((n.depth for n in chain if n.tier == TIER_DISK), default=0)
            return AcquirePlan(
                tokens=list(tokens),
                chain_blocks=len(chain),
                disk_chain_depth=disk_depth,
                total_blocks=len(tokens) // B,
            )

    def fetch(self, plan: AcquirePlan) -> DiskFetch:
        """Phase 2 (any thread): backend probe + one batched get covering
        both the disk extension beyond the radix chain and the chain nodes
        whose payloads live only on disk.  Touches nothing but the
        thread-safe store.

        On a streaming backend (one exposing ``get_batch_stream``) this
        returns as soon as block 0 is on hand: the tail keeps arriving
        off the wire while ``fulfill`` installs the early blocks, and
        ``first_block_s`` records the time-to-first-block the serving
        layer reports.  ``io_s`` then covers only the streamed prefix —
        the drain happens under ``fulfill``'s own clock."""
        with maybe_span("fetch"):
            return self._fetch(plan)

    def _fetch(self, plan: AcquirePlan) -> DiskFetch:
        if self.store is None or not plan.need_disk:
            return DiskFetch()
        B = self.block_size
        t0 = time.perf_counter()
        probed = 0
        if plan.chain_blocks < plan.total_blocks:
            probed = self.store.probe(plan.tokens)
        upto = max(probed, plan.disk_chain_depth * B)
        if not upto:
            return DiskFetch(io_s=time.perf_counter() - t0)
        stream_fn = getattr(self.store, "get_batch_stream", None)
        if stream_fn is None:
            blocks = self.store.get_batch(plan.tokens, upto)
            return DiskFetch(
                probed_tokens=probed, blocks=blocks, io_s=time.perf_counter() - t0
            )
        try:
            streamed = _StreamedBlocks(stream_fn(plan.tokens, upto))
        except (ConnectionError, OSError):
            return DiskFetch(probed_tokens=probed, io_s=time.perf_counter() - t0)
        first = streamed.prime()  # block 0 lands here; the rest stays in flight
        now = time.perf_counter()
        return DiskFetch(
            probed_tokens=probed,
            blocks=streamed,
            io_s=now - t0,
            first_block_s=(now - t0) if first else None,
        )

    def fulfill(self, plan: AcquirePlan, fetched: Optional[DiskFetch] = None) -> Acquisition:
        """Phase 3 (engine thread): install fetched blocks and promote the
        usable chain to the device tier.  Re-matches the tree — commits that
        landed between plan and fulfill are honored, and fetched blocks are
        only used where they still extend the (fresh) chain.  The returned
        node path is locked until ``release``."""
        with maybe_span("fulfill"):
            return self._fulfill(plan, fetched)

    def _fulfill(self, plan: AcquirePlan, fetched: Optional[DiskFetch] = None) -> Acquisition:
        B = self.block_size
        tokens = plan.tokens
        fetched = fetched or DiskFetch()
        self.stats.requests += 1
        self.stats.tokens_requested += len(tokens)
        t0 = time.perf_counter()
        chain = self.tree.match_prefix(tokens)
        if len(chain) != plan.chain_blocks:
            self.stats.plan_stale += 1
        dev = host = disk = 0

        # classify memory-resident part
        for n in chain:
            if n.tier == TIER_DEVICE:
                dev += 1
            elif n.tier == TIER_HOST:
                host += 1
            elif n.tier == TIER_DISK:
                disk += 1

        # promote disk-resident chain nodes first, in ascending depth: on
        # a streamed fetch these are the earliest blocks off the wire, so
        # installation starts while the extension is still in flight
        for n in chain:
            if n.tier != TIER_DISK:
                continue
            blk = _block_at(fetched.blocks, n.depth - 1)
            if blk is not None:
                n.data = blk
            else:  # disk lost it (eviction) or the plan predates it: miss
                n.tier = TIER_NONE
                disk -= 1

        # extend the match past the in-memory chain with fetched disk
        # blocks (this slice drains the rest of a streamed fetch)
        disk_ext_blocks: List[np.ndarray] = []
        if fetched.probed_tokens > len(chain) * B:
            disk_ext_blocks = list(fetched.blocks[len(chain) :])
            disk += len(disk_ext_blocks)
        abort = getattr(fetched.blocks, "close", None)
        if abort is not None:
            abort()  # drop any streamed blocks fulfill didn't need

        # materialize the full usable chain on device
        nodes = list(chain)
        if disk_ext_blocks:
            ext_tokens = tokens[: (len(chain) + len(disk_ext_blocks)) * B]
            new_nodes = self.tree.insert_path(ext_tokens)[len(chain) :]
            for n, blk in zip(new_nodes, disk_ext_blocks):
                n.data = blk
                n.tier = TIER_HOST  # staged; promoted below
                n.on_disk = True
                self.host_blocks += 1
            nodes.extend(new_nodes)

        # cut the chain at the first unusable node
        usable: List[RadixNode] = []
        for n in nodes:
            if n.tier in (TIER_DEVICE, TIER_HOST) or (n.tier == TIER_DISK and n.data is not None):
                usable.append(n)
            else:
                break
        promote = [n for n in usable if n.tier != TIER_DEVICE]
        self._make_room(TIER_DEVICE, len(promote))
        for n in promote:
            if n.tier == TIER_HOST:
                self.host_blocks -= 1
            n.tier = TIER_DEVICE
            self.device_blocks += 1
        self.tree.lock_path(usable)

        io_s = fetched.io_s + (time.perf_counter() - t0)
        self.stats.promote_s += io_s
        if fetched.first_block_s is not None:
            self.stats.streamed_fetches += 1
            self.stats.first_block_s += fetched.first_block_s
        reuse = len(usable) * B
        self.stats.tokens_hit_device += dev * B
        self.stats.tokens_hit_host += host * B
        self.stats.tokens_hit_disk += disk * B
        self.stats.tokens_missed += max(0, len(tokens) - reuse)
        return Acquisition(
            nodes=usable,
            reuse_tokens=reuse,
            device_tokens=dev * B,
            host_tokens=host * B,
            disk_tokens=disk * B,
            io_s=io_s,
        )

    def acquire(self, tokens: Sequence[int]) -> Acquisition:
        """Longest-prefix reuse: radix match, disk-probe extension, and
        promotion of every matched block to the device tier — the serial
        composition of plan → fetch → fulfill.  The returned node path is
        locked until ``release``."""
        p = self.plan(tokens)
        return self.fulfill(p, self.fetch(p))

    # ----------------------------------------------------------------- commit
    def commit(self, tokens: Sequence[int], new_blocks: List[np.ndarray], acq: Acquisition) -> None:
        """Install freshly computed KV blocks (covering tokens beyond
        ``acq.reuse_tokens``) into the device tier, then populate the disk
        tier — inline write-through, or via the write-behind queue when one
        is attached (the request no longer pays the disk write)."""
        B = self.block_size
        start_block = acq.reuse_tokens // B
        total_blocks = len(tokens) // B
        n_new = min(len(new_blocks), total_blocks - start_block)
        if n_new <= 0:
            return
        self._make_room(TIER_DEVICE, n_new)
        path = self.tree.insert_path(tokens[: (start_block + n_new) * B])
        fresh = path[start_block:]
        for n, blk in zip(fresh, new_blocks):
            if n.tier == TIER_DEVICE:
                continue
            n.data = blk
            n.tier = TIER_DEVICE
            self.device_blocks += 1
        if self.write_through and self.store is not None:
            if self.commit_queue is not None:
                # write-behind: capture plain values (token list + arrays),
                # never tree nodes' mutable state.  ``on_disk`` is set at
                # enqueue time — the queue holds the payloads by reference
                # and owns the write, so a later demotion must not re-encode
                # the same blocks synchronously on the engine thread.  Known
                # window: a fetch racing the bounded queue can miss a block
                # whose write is still queued and transiently treat it as a
                # cache miss (recomputed, never corrupted); a failed
                # write-behind surfaces on the next flush/drain (the
                # standard write-back cache durability contract).
                toks = list(tokens[: (start_block + n_new) * B])
                blocks = [np.asarray(b) for b in new_blocks[:n_new]]
                store = self.store
                for n in fresh:
                    n.on_disk = True
                self.commit_queue.submit(
                    lambda: store.put_batch(toks, blocks, start_block=start_block),
                    nbytes=sum(b.nbytes for b in blocks),
                )
                self.stats.writeback_blocks += n_new
            else:
                self.store.put_batch(tokens, new_blocks[:n_new], start_block=start_block)
                for n in fresh:
                    n.on_disk = True

    def release(self, acq: Acquisition) -> None:
        self.tree.unlock_path(acq.nodes)

    # ----------------------------------------------------------------- misc
    def maintenance(self) -> dict:
        if self.store is not None:
            return self.store.maintenance()
        return {}

    @property
    def hit_rate(self) -> float:
        return self.stats.hit_rate
