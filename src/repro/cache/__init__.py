from .hierarchy import Acquisition, CacheHierarchy, CacheStats
from .radix import TIER_DEVICE, TIER_DISK, TIER_HOST, TIER_NONE, RadixNode, RadixTree

__all__ = [
    "CacheHierarchy",
    "CacheStats",
    "Acquisition",
    "RadixTree",
    "RadixNode",
    "TIER_DEVICE",
    "TIER_HOST",
    "TIER_DISK",
    "TIER_NONE",
]
