"""In-memory radix tree over token-block prefixes (RadixAttention-style,
paper §2.1).  Nodes are block-granular — one node per ``block_size`` tokens —
which matches the storage engine's block keys exactly, so a tree path maps
1:1 onto a run of LSM index keys.  (SGLang's byte-granular edge splitting is
unnecessary at block granularity; noted in DESIGN.md.)

Each node records which tier currently holds its KV block (DEVICE / HOST /
DISK / NONE) and an LRU timestamp; eviction walks unlocked leaves in LRU
order, demoting device→host→disk, exactly the hierarchy of §2.1.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

TIER_DEVICE = 2
TIER_HOST = 1
TIER_DISK = 0  # present on disk only (data evicted from memory tiers)
TIER_NONE = -1  # metadata-only node (data lost / never stored)

_clock = itertools.count(1)


@dataclass
class RadixNode:
    block: Tuple[int, ...]  # the tokens of this block (edge label)
    parent: Optional["RadixNode"]
    depth: int  # blocks from root (this node = block index depth-1)
    children: Dict[Tuple[int, ...], "RadixNode"] = field(default_factory=dict)
    tier: int = TIER_NONE
    data: object = None  # KV block payload when tier >= HOST
    on_disk: bool = False  # true once persisted by write-through
    last_access: int = 0
    lock: int = 0  # in-flight request refcount

    def touch(self) -> None:
        self.last_access = next(_clock)

    @property
    def is_leaf(self) -> bool:
        return not self.children


class RadixTree:
    def __init__(self, block_size: int):
        self.block_size = block_size
        self.root = RadixNode(block=(), parent=None, depth=0, tier=TIER_DEVICE)
        self.n_nodes = 0

    # ---------------------------------------------------------------- match
    def _blocks_of(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        B = self.block_size
        return [tuple(tokens[i * B : (i + 1) * B]) for i in range(len(tokens) // B)]

    def match_prefix(self, tokens: Sequence[int]) -> List[RadixNode]:
        """Longest path of existing nodes covering a prefix of ``tokens``.
        Returns the node chain (possibly empty); touches nodes (LRU)."""
        out: List[RadixNode] = []
        node = self.root
        for blk in self._blocks_of(tokens):
            child = node.children.get(blk)
            if child is None:
                break
            child.touch()
            out.append(child)
            node = child
        return out

    # --------------------------------------------------------------- insert
    def insert_path(self, tokens: Sequence[int]) -> List[RadixNode]:
        """Ensure nodes exist for every block of ``tokens``; returns the full
        chain.  Data/tier must be attached by the caller."""
        node = self.root
        out: List[RadixNode] = []
        for blk in self._blocks_of(tokens):
            child = node.children.get(blk)
            if child is None:
                child = RadixNode(block=blk, parent=node, depth=node.depth + 1)
                node.children[blk] = child
                self.n_nodes += 1
            child.touch()
            out.append(child)
            node = child
        return out

    # --------------------------------------------------------------evict
    def evictable_leaves(self, tier: int) -> List[RadixNode]:
        """Unlocked tier-frontier nodes, LRU-first: a node is evictable from
        ``tier`` iff none of its children still live in a tier >= ``tier``.
        This preserves the resident-path invariant (a usable KV block needs
        every ancestor block co-resident), while letting eviction cascade
        upward as children are demoted."""
        out = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if (
                n is not self.root
                and n.lock == 0
                and n.tier == tier
                and all(c.tier < tier for c in n.children.values())
            ):
                out.append(n)
        out.sort(key=lambda n: n.last_access)
        return out

    def drop(self, node: RadixNode) -> None:
        """Remove a metadata node entirely (data already off-memory)."""
        if node.children:
            raise ValueError("cannot drop an interior node")
        if node.parent is not None:
            node.parent.children.pop(node.block, None)
            self.n_nodes -= 1

    # --------------------------------------------------------------- stats
    def count_by_tier(self) -> Dict[int, int]:
        counts: Dict[int, int] = {TIER_DEVICE: 0, TIER_HOST: 0, TIER_DISK: 0, TIER_NONE: 0}
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            counts[n.tier] += 1
            stack.extend(n.children.values())
        return counts

    def lock_path(self, nodes: List[RadixNode]) -> None:
        for n in nodes:
            n.lock += 1

    def unlock_path(self, nodes: List[RadixNode]) -> None:
        for n in nodes:
            n.lock = max(0, n.lock - 1)
