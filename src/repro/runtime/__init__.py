"""Concurrent runtime layer (paper §3.4 "runtime services"): a bounded
I/O executor, a write-behind commit queue, and an off-path maintenance
service.  The storage backends are thread-safe (see ``core.backend``);
this package supplies the threads."""

from .executor import ExecutorStats, IOExecutor
from .maintenance import MaintenanceService, MaintenanceStats
from .services import RuntimeServices
from .writebehind import CommitQueue, CommitQueueStats

__all__ = [
    "IOExecutor",
    "ExecutorStats",
    "CommitQueue",
    "CommitQueueStats",
    "MaintenanceService",
    "MaintenanceStats",
    "RuntimeServices",
]
