"""``IOExecutor`` — the bounded thread pool every runtime service runs on.

The paper's runtime layer (§3.4) moves batch I/O and resource management
off the request path.  This executor is the shared substrate: a fixed pool
of I/O threads plus a *bounded* admission gate, so a burst of submissions
exerts backpressure on the caller instead of growing an unbounded queue
(the failure mode of a naive ``ThreadPoolExecutor``: memory blows up while
the disk falls behind).

Design points:

* ``max_workers == 0`` degenerates to synchronous inline execution — every
  ``submit`` runs the job on the calling thread and returns an
  already-resolved future.  Callers write one code path; serial mode stays
  available for deterministic tests and as the benchmark baseline.
* Admission control: at most ``max_pending`` jobs may be queued or running;
  beyond that ``submit`` blocks (stall time is accounted).  The bound keeps
  the write-behind queue and prefetcher from racing ahead of the disk.
* Observability: queue-depth high-water mark, jobs submitted/completed,
  stall seconds — all maintained under a lock so concurrent readers see
  consistent numbers (the ``EngineStats`` overlap accounting builds on
  these).
"""

from __future__ import annotations

import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, TypeVar

from ..obs.tracing import activate, current_trace

T = TypeVar("T")


@dataclass
class ExecutorStats:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    inline: int = 0  # jobs run synchronously (workers == 0)
    queue_depth_max: int = 0
    stall_s: float = 0.0  # time submitters spent blocked on admission

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class IOExecutor:
    """Bounded thread pool with futures, backpressure, and depth accounting.

    Worker count is capped at the host's CPU count: these "I/O" threads do
    real CPU between syscalls (zlib, dequantization, CRC), and
    oversubscribing cores just convoys Python's GIL — measured on a 2-core
    host, 4 workers run *slower* than 2.  The requested width is kept in
    ``requested_workers`` and surfaced by benchmarks, so a sweep over
    configured thread counts stays interpretable on any host.
    """

    def __init__(
        self,
        max_workers: int = 4,
        max_pending: Optional[int] = None,
        cap_to_cpu: bool = True,
    ):
        """``cap_to_cpu=False`` lifts the CPU-count cap for pools whose
        workers block on the *network* with the GIL released (the cluster
        client's RPC fan-out): those threads spend their time in
        ``recv``, not in zlib/numpy, so width beyond the core count buys
        in-flight RPCs instead of GIL convoy."""
        self.requested_workers = max(0, int(max_workers))
        cpu = os.cpu_count() or 1
        self.max_workers = (
            self.requested_workers
            if not cap_to_cpu
            else min(self.requested_workers, max(1, cpu))
        )
        self.max_pending = max_pending if max_pending is not None else 4 * max(1, self.max_workers)
        self.stats = ExecutorStats()
        self._lock = threading.Lock()
        self._in_flight = 0
        self._slot_free = threading.Condition(self._lock)
        self._pool: Optional[ThreadPoolExecutor] = (
            ThreadPoolExecutor(max_workers=self.max_workers, thread_name_prefix="repro-io")
            if self.max_workers > 0
            else None
        )
        self._closed = False

    # ------------------------------------------------------------------ core
    @property
    def serial(self) -> bool:
        return self._pool is None

    def submit(self, fn: Callable[..., T], *args, **kwargs) -> "Future[T]":
        """Run ``fn`` on the pool; blocks when ``max_pending`` jobs are
        already queued/running (backpressure)."""
        if self._closed:
            raise RuntimeError("IOExecutor is closed")
        if self._pool is None:
            fut: Future = Future()
            with self._lock:
                self.stats.submitted += 1
                self.stats.inline += 1
            try:
                fut.set_result(fn(*args, **kwargs))
                with self._lock:
                    self.stats.completed += 1
            except BaseException as e:  # noqa: BLE001 — future carries it
                fut.set_exception(e)
                with self._lock:
                    self.stats.failed += 1
            return fut

        with self._slot_free:
            if self._in_flight >= self.max_pending:
                t0 = time.perf_counter()
                while self._in_flight >= self.max_pending:
                    self._slot_free.wait(timeout=0.5)
                self.stats.stall_s += time.perf_counter() - t0
            self._in_flight += 1
            self.stats.submitted += 1
            self.stats.queue_depth_max = max(self.stats.queue_depth_max, self._in_flight)

        # the submitter's trace follows the job across the thread hop, so
        # spans recorded inside the worker land on the right request
        trace = current_trace()

        def _run():
            try:
                if trace is None:
                    return fn(*args, **kwargs)
                with activate(trace):
                    return fn(*args, **kwargs)
            finally:
                with self._slot_free:
                    self._in_flight -= 1
                    self._slot_free.notify()

        fut = self._pool.submit(_run)
        fut.add_done_callback(self._on_done)
        return fut

    def try_submit(self, fn: Callable[..., T], *args, **kwargs) -> "Optional[Future[T]]":
        """Like ``submit`` but never blocks: returns ``None`` instead of
        waiting when the admission gate is full.  This is the prefetcher's
        probe — checking ``in_flight`` and then calling ``submit`` would
        race other submitters into the very stall the check tried to
        avoid; here the slot is claimed under the same lock that counts
        it."""
        if self._closed:
            raise RuntimeError("IOExecutor is closed")
        if self._pool is None:
            return self.submit(fn, *args, **kwargs)
        with self._slot_free:
            if self._in_flight >= self.max_pending:
                return None
            self._in_flight += 1
            self.stats.submitted += 1
            self.stats.queue_depth_max = max(self.stats.queue_depth_max, self._in_flight)

        trace = current_trace()

        def _run():
            try:
                if trace is None:
                    return fn(*args, **kwargs)
                with activate(trace):
                    return fn(*args, **kwargs)
            finally:
                with self._slot_free:
                    self._in_flight -= 1
                    self._slot_free.notify()

        fut = self._pool.submit(_run)
        fut.add_done_callback(self._on_done)
        return fut

    def _on_done(self, fut: Future) -> None:
        with self._lock:
            if fut.cancelled() or fut.exception() is not None:
                self.stats.failed += 1
            else:
                self.stats.completed += 1

    def map_parallel(self, fn: Callable[..., T], items: Sequence) -> List[T]:
        """Apply ``fn`` to every item, in parallel when the pool exists,
        preserving input order.  Exceptions propagate (first one wins)."""
        if self._pool is None or len(items) <= 1:
            return [fn(it) for it in items]
        futs = [self.submit(fn, it) for it in items]
        return [f.result() for f in futs]

    @property
    def in_flight(self) -> int:
        with self._lock:
            return self._in_flight

    def close(self, wait: bool = True) -> None:
        self._closed = True
        if self._pool is not None:
            self._pool.shutdown(wait=wait)

    def __enter__(self) -> "IOExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
