"""``CommitQueue`` — write-behind population of the disk tier.

``CacheHierarchy.commit`` installs freshly computed KV blocks into device
memory and (until this layer existed) wrote them through to disk *inline*,
charging the disk's write latency to the request's TTFT.  The commit queue
moves that write off the request path: commits are enqueued and a single
drain thread applies them to the backend in FIFO order while the engine
moves on to the next batch.

Bounded, with two backpressure triggers:

* ``max_items`` — pending commit count; and
* ``max_bytes`` — pending payload bytes (the real resource: a queue of
  multi-megabyte KV slabs must not outrun the disk).

When either bound is hit, ``submit`` blocks the producer (stall time
accounted) — write-behind degrades gracefully into write-through under
sustained overload instead of growing without bound.

A single drain thread (not the shared read executor) so queued writes
never starve prefetch reads, and per-store FIFO ordering is preserved.
Failures are captured, counted, and re-raised on the next ``flush`` — a
lost write-behind is a durability event the caller must see.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Optional, Tuple


@dataclass
class CommitQueueStats:
    enqueued: int = 0
    completed: int = 0
    failed: int = 0
    enqueued_bytes: int = 0
    completed_bytes: int = 0
    depth_max: int = 0
    bytes_max: int = 0
    stall_s: float = 0.0  # producer time blocked on backpressure
    drain_s: float = 0.0  # worker time spent applying commits

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class CommitQueue:
    """Bounded FIFO write-behind queue with a dedicated drain thread."""

    def __init__(self, max_items: int = 64, max_bytes: int = 256 * 1024 * 1024):
        self.max_items = max(1, max_items)
        self.max_bytes = max(1, max_bytes)
        self.stats = CommitQueueStats()
        self._q: Deque[Tuple[Callable[[], None], int]] = deque()
        self._pending_bytes = 0
        self._in_flight = 0  # popped but not yet applied
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)
        self._errors: list = []
        self._closed = False
        self._worker = threading.Thread(target=self._drain, name="repro-writebehind", daemon=True)
        self._worker.start()

    # ---------------------------------------------------------------- produce
    def submit(self, fn: Callable[[], None], nbytes: int = 0) -> None:
        """Enqueue one commit closure (blocks under backpressure)."""
        with self._not_full:
            if self._closed:
                raise RuntimeError("CommitQueue is closed")
            if self._full():
                t0 = time.perf_counter()
                while self._full() and not self._closed:
                    self._not_full.wait(timeout=0.5)
                self.stats.stall_s += time.perf_counter() - t0
            self._q.append((fn, nbytes))
            self._pending_bytes += nbytes
            self.stats.enqueued += 1
            self.stats.enqueued_bytes += nbytes
            self.stats.depth_max = max(self.stats.depth_max, len(self._q) + self._in_flight)
            self.stats.bytes_max = max(self.stats.bytes_max, self._pending_bytes)
            self._not_empty.notify()

    def _full(self) -> bool:
        depth = len(self._q) + self._in_flight
        return depth >= self.max_items or self._pending_bytes >= self.max_bytes

    # ------------------------------------------------------------------ drain
    def _drain(self) -> None:
        while True:
            with self._not_empty:
                while not self._q and not self._closed:
                    self._not_empty.wait(timeout=0.5)
                if not self._q and self._closed:
                    return
                fn, nbytes = self._q.popleft()
                self._in_flight += 1
            t0 = time.perf_counter()
            try:
                fn()
                err = None
            except BaseException as e:  # noqa: BLE001 — surfaced via flush()
                err = e
            dt = time.perf_counter() - t0
            with self._lock:
                self._in_flight -= 1
                self._pending_bytes -= nbytes
                self.stats.drain_s += dt
                if err is None:
                    self.stats.completed += 1
                    self.stats.completed_bytes += nbytes
                else:
                    self.stats.failed += 1
                    self._errors.append(err)
                self._not_full.notify()
                if not self._q and self._in_flight == 0:
                    self._idle.notify_all()

    # ------------------------------------------------------------------ sync
    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._q) + self._in_flight

    def flush(self, timeout: Optional[float] = None) -> None:
        """Barrier: wait until every enqueued commit has been applied, then
        re-raise the first captured failure (if any)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._idle:
            while self._q or self._in_flight:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"CommitQueue.flush: {len(self._q)} pending after {timeout}s")
                self._idle.wait(timeout=0.2 if remaining is None else min(0.2, remaining))
            if self._errors:
                err = self._errors[0]
                self._errors.clear()
                raise err

    def close(self, flush: bool = True) -> None:
        if flush and not self._closed:
            self.flush()
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()
        self._worker.join(timeout=5.0)
