"""``MaintenanceService`` — compaction/merge/eviction cycles off the
request path.

The stores' ``maintenance()`` contract stays deterministic and
caller-scheduled; this service is the caller.  The serving engine used to
run ``hierarchy.maintenance()`` inline between batches, so a compaction
cascade or a tensor-file merge landed squarely on request latency.  Now the
engine calls ``maybe_schedule()`` — a non-blocking nudge — and the cycle
runs on the maintenance thread while the engine keeps serving (the backends
are thread-safe; see ``core.backend``).

At most one cycle is in flight at a time (maintenance is bounded work per
cycle by design; overlapping cycles would just contend on the same locks).
Reports are aggregated under a lock; ``harvest()`` hands the counters to
the engine's single-writer stats on the engine thread, so ``EngineStats``
stays race-free.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional


@dataclass
class MaintenanceStats:
    cycles: int = 0
    compactions: int = 0
    evicted_files: int = 0
    merged_files: int = 0
    demoted_blocks: int = 0  # blocks re-encoded down-tier (core.tiering)
    errors: int = 0
    busy_s: float = 0.0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class MaintenanceService:
    """Runs ``target()`` maintenance cycles on a background thread."""

    def __init__(self, target: Callable[[], dict]):
        self.target = target
        self.stats = MaintenanceStats()
        self._lock = threading.Lock()
        self._running = False
        self._pending = 0
        self._idle = threading.Condition(self._lock)
        self._last_error: Optional[BaseException] = None
        # counters not yet harvested by the engine thread
        self._unharvested = MaintenanceStats()

    # -------------------------------------------------------------- schedule
    def maybe_schedule(self) -> bool:
        """Start one cycle unless one is already in flight.  Returns True
        when a new cycle was scheduled."""
        with self._lock:
            if self._running:
                self._pending = 1  # coalesce: run once more after this cycle
                return False
            self._running = True
        t = threading.Thread(target=self._cycle, name="repro-maintenance", daemon=True)
        t.start()
        return True

    def run_inline(self) -> dict:
        """Synchronous cycle (serial mode / tests): same accounting path."""
        return self._run_once()

    def _cycle(self) -> None:
        while True:
            self._run_once()
            with self._lock:
                if self._pending:
                    self._pending = 0
                    continue
                self._running = False
                self._idle.notify_all()
                return

    def _run_once(self) -> dict:
        t0 = time.perf_counter()
        try:
            rep = self.target() or {}
            err = None
        except BaseException as e:  # noqa: BLE001 — counted, surfaced on drain
            rep, err = {}, e
        dt = time.perf_counter() - t0
        with self._lock:
            for agg in (self.stats, self._unharvested):
                agg.cycles += 1
                agg.busy_s += dt
                agg.compactions += int(rep.get("compactions", 0) or 0)
                agg.evicted_files += int(rep.get("evicted_files", 0) or 0)
                merge = rep.get("merge") or {}
                agg.merged_files += int(merge.get("files", 0) or 0)
                tiering = rep.get("tiering") or {}
                agg.demoted_blocks += int(tiering.get("demoted_blocks", 0) or 0)
                if err is not None:
                    agg.errors += 1
            if err is not None:
                self._last_error = err
        return rep

    # --------------------------------------------------------------- harvest
    def harvest(self) -> MaintenanceStats:
        """Return-and-reset the counters accumulated since the last harvest
        (called from the engine thread to fold into ``EngineStats``)."""
        with self._lock:
            out = self._unharvested
            self._unharvested = MaintenanceStats()
            return out

    def drain(self, timeout: float = 30.0) -> None:
        """Wait for the in-flight cycle (if any); re-raise its error."""
        deadline = time.monotonic() + timeout
        with self._idle:
            while self._running:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError("MaintenanceService.drain timed out")
                self._idle.wait(timeout=min(0.2, remaining))
            if self._last_error is not None:
                err = self._last_error
                self._last_error = None
                raise err
