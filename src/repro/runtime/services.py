"""``RuntimeServices`` — the bundle the serving layer plugs in.

One object wires the three runtime components together with sane defaults:

* ``executor``   — ``IOExecutor`` for prefetch fan-out and hedged reads;
* ``commits``    — ``CommitQueue`` write-behind for disk population;
* ``maintenance``— ``MaintenanceService`` (bound lazily to the hierarchy
                   by the engine, since the engine owns the hierarchy).

``io_threads == 0`` yields a fully synchronous runtime (inline executor,
no write-behind, inline maintenance) — the serial baseline every benchmark
compares against, through the *same* code paths.
"""

from __future__ import annotations

from typing import Callable, Optional

from .executor import IOExecutor
from .maintenance import MaintenanceService
from .writebehind import CommitQueue


class RuntimeServices:
    def __init__(
        self,
        io_threads: int = 4,
        max_pending: Optional[int] = None,
        commit_queue_items: int = 64,
        commit_queue_bytes: int = 256 * 1024 * 1024,
    ):
        self.io_threads = max(0, int(io_threads))
        if max_pending is None:
            # generous admission bound: prefetch-ahead submits a whole
            # batch of fetches before the engine starts serving — the gate
            # exists to stop runaway producers, not to throttle one batch
            # (a tight bound stalls the *engine thread* mid-step)
            max_pending = max(32, 8 * max(1, self.io_threads))
        self.executor = IOExecutor(max_workers=self.io_threads, max_pending=max_pending)
        self.commits: Optional[CommitQueue] = (
            CommitQueue(max_items=commit_queue_items, max_bytes=commit_queue_bytes)
            if self.io_threads > 0
            else None
        )
        self.maintenance: Optional[MaintenanceService] = None

    @property
    def async_mode(self) -> bool:
        return self.io_threads > 0

    def bind_maintenance(self, target: Callable[[], dict]) -> MaintenanceService:
        if self.maintenance is None:
            self.maintenance = MaintenanceService(target)
        return self.maintenance

    def report(self) -> dict:
        out = {"io_threads": self.io_threads, "executor": self.executor.stats.as_dict()}
        if self.commits is not None:
            out["commit_queue"] = self.commits.stats.as_dict()
        if self.maintenance is not None:
            out["maintenance"] = self.maintenance.stats.as_dict()
        return out

    def drain(self) -> None:
        """Quiesce: flush write-behind, wait out maintenance."""
        if self.commits is not None:
            self.commits.flush()
        if self.maintenance is not None:
            self.maintenance.drain()

    def close(self) -> None:
        if self.commits is not None:
            self.commits.close(flush=True)
        if self.maintenance is not None:
            self.maintenance.drain()
        self.executor.close()

    def __enter__(self) -> "RuntimeServices":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
