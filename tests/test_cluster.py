"""Cache-cluster layer: wire-format round trips (including oversized and
truncated frames -> clean errors, never hangs), node server/client
contract conformance, consistent-hash ring properties, replication
failover (kill the server mid-get), and rejoin rebalance.

In-process ``CacheNodeServer``s (real sockets, same process) keep most
tests fast; one test spawns a real child-process node to cover the
deployment path.  Everything runs under the suite-wide timeout guard, so
a protocol bug that would hang a reader fails fast instead.
"""

import socket
import threading

import numpy as np
import pytest
from cluster_harness import B, add_mem_node, close_all, mem_cluster
from cluster_harness import blocks as _blocks
from cluster_harness import seq as _seq
from cluster_harness import spawn_nodes
from hypothesis_compat import HealthCheck, given, settings, st

from repro.cluster import (
    CacheNodeServer,
    ClusterKVBlockStore,
    HashRing,
    NodeUnavailable,
    RemoteError,
    RemoteKVBlockStore,
    key_hash,
    spawn_local_node,
)
from repro.cluster import protocol as P
from repro.core.backend import StorageBackend
from repro.core.baselines import MemoryOnlyStore
from repro.core.store import KVBlockStore


# ============================================================ wire format
def _roundtrip_request(op, *args):
    payload = P.encode_request(op, *args)
    op2, args2 = P.decode_request(payload)
    assert op2 == op
    return args2


def test_request_roundtrip_all_ops():
    rng = np.random.default_rng(0)
    toks = _seq(rng, 3)
    blocks = _blocks(rng, 2, dtype=np.float16)

    assert _roundtrip_request(P.OP_PING) == ()
    assert _roundtrip_request(P.OP_PROBE, toks) == (toks,)
    assert _roundtrip_request(P.OP_PROBE_MANY, [toks, toks[:B]]) == ([toks, toks[:B]],)
    assert _roundtrip_request(P.OP_GET, toks, 8) == (toks, 8)
    assert _roundtrip_request(P.OP_GET_MANY, [(toks, 8), (toks[:B], 4)]) == (
        [(toks, 8), (toks[:B], 4)],
    )
    (t2, b2, s2, k2) = _roundtrip_request(P.OP_PUT, toks, blocks, 1, False)
    assert t2 == toks and s2 == 1 and k2 is False
    assert all(np.array_equal(x, y) and x.dtype == y.dtype for x, y in zip(b2, blocks))
    ((item,),) = _roundtrip_request(P.OP_PUT_MANY, [(toks, blocks, 2)])
    assert item[0] == toks and item[2] == 2
    assert _roundtrip_request(P.OP_MAINTENANCE, 7) == (7,)
    assert _roundtrip_request(P.OP_STATS) == ()
    assert _roundtrip_request(P.OP_FLUSH) == ()
    # elasticity ops: scan (cursor + arc ranges), pull, push
    ranges = [(0, 2**63), (2**64 - 5, 17)]
    assert _roundtrip_request(P.OP_SCAN, None, 256, ranges) == (None, 256, ranges)
    assert _roundtrip_request(P.OP_SCAN, b"cur", 1, []) == (b"cur", 1, [])
    keys = [b"k1", b"\x00" * 12, b"k3"]
    assert _roundtrip_request(P.OP_PULL, keys) == (keys,)
    records = [(b"k1", 0, b"payload"), (b"k2", 3, b"")]
    got_recs, skip = _roundtrip_request(P.OP_PUSH, records, False)
    assert got_recs == records and skip is False


def test_elasticity_response_roundtrips():
    keys = [b"a", b"bb", b"\xffccc"]
    got = P.decode_response(P.OP_SCAN, P.encode_ok(P.OP_SCAN, (keys, b"next")))
    assert got == (keys, b"next")
    got = P.decode_response(P.OP_SCAN, P.encode_ok(P.OP_SCAN, (keys, None)))
    assert got == (keys, None)
    recs = [(0, b"raw-payload"), None, (3, b"zl")]
    assert P.decode_response(P.OP_PULL, P.encode_ok(P.OP_PULL, recs)) == recs
    assert P.decode_response(P.OP_PUSH, P.encode_ok(P.OP_PUSH, 42)) == 42


def test_response_roundtrip_all_ops():
    rng = np.random.default_rng(1)
    blocks = _blocks(rng, 3)
    assert P.decode_response(P.OP_PROBE, P.encode_ok(P.OP_PROBE, 12)) == 12
    assert P.decode_response(P.OP_PROBE_MANY, P.encode_ok(P.OP_PROBE_MANY, [0, 4, 8])) == [0, 4, 8]
    got = P.decode_response(P.OP_GET, P.encode_ok(P.OP_GET, blocks))
    assert all(np.array_equal(x, y) for x, y in zip(got, blocks))
    many = P.decode_response(P.OP_GET_MANY, P.encode_ok(P.OP_GET_MANY, [blocks, []]))
    assert len(many) == 2 and len(many[1]) == 0
    stats = {"name": "lsm", "block_size": 4, "stats": {"put_blocks": 9}}
    assert P.decode_response(P.OP_STATS, P.encode_ok(P.OP_STATS, stats)) == stats
    with pytest.raises(RemoteError, match="boom"):
        P.decode_response(P.OP_PROBE, P.encode_error("boom"))


@given(
    seqs=st.lists(st.lists(st.integers(0, 2**32 - 1), min_size=0, max_size=32), max_size=8),
    steps=st.integers(0, 64),
)
@settings(max_examples=40, deadline=None)
def test_request_roundtrip_property(seqs, steps):
    assert _roundtrip_request(P.OP_PROBE_MANY, seqs) == (seqs,)
    assert _roundtrip_request(P.OP_MAINTENANCE, steps) == (steps,)
    items = [(s, len(s)) for s in seqs]
    assert _roundtrip_request(P.OP_GET_MANY, items) == (items,)


@given(
    dtype=st.sampled_from(["<f2", "<f4", "<i4", "|u1"]),
    shape=st.lists(st.integers(1, 5), min_size=1, max_size=3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_block_payload_roundtrip_property(dtype, shape, seed):
    rng = np.random.default_rng(seed)
    arr = (rng.standard_normal(tuple(shape)) * 10).astype(np.dtype(dtype))
    (got,) = P.decode_response(P.OP_GET, P.encode_ok(P.OP_GET, [arr]))
    assert got.dtype == arr.dtype and np.array_equal(got, arr)


def test_corrupt_body_raises_protocol_error_never_reads_oob():
    payload = P.encode_request(P.OP_PROBE, [1, 2, 3, 4])
    for cut in (1, 3, len(payload) - 1):
        with pytest.raises(P.ProtocolError):
            P.decode_request(payload[:cut])
    with pytest.raises(P.ProtocolError):
        P.decode_request(payload + b"trailing")
    with pytest.raises(P.ProtocolError):
        P.decode_request(bytes([99]))  # unknown opcode


def test_recv_frame_truncation_and_oversize():
    # clean EOF between frames -> None
    a, b = socket.socketpair()
    b.close()
    assert P.recv_frame(a) is None
    a.close()

    # peer dies mid-header and mid-body -> TruncatedFrame, not a hang
    for partial in (b"\x00\x00", b"\x00\x00\x00\x0ahalf"):
        a, b = socket.socketpair()
        b.sendall(partial)
        b.close()
        with pytest.raises(P.TruncatedFrame):
            P.recv_frame(a)
        a.close()

    # oversize length word -> FrameTooLarge before any body allocation
    a, b = socket.socketpair()
    b.sendall((2**31).to_bytes(4, "big"))
    with pytest.raises(P.FrameTooLarge):
        P.recv_frame(a, max_frame_bytes=1 << 20)
    a.close()
    b.close()


def test_server_rejects_oversized_frame_cleanly():
    """A corrupt length word must get an error frame + connection close —
    the node stays up and keeps serving other clients."""
    with CacheNodeServer(MemoryOnlyStore(1 << 20, block_size=B), io_threads=1) as srv:
        rogue = socket.create_connection(srv.address, timeout=5)
        rogue.sendall((2**30).to_bytes(4, "big"))
        payload = P.recv_frame(rogue)
        rid, kind, body = P.split_mux(payload)
        assert kind == P.KIND_RESPONSE
        with pytest.raises(RemoteError, match="exceeds cap"):
            P.decode_response(P.OP_PING, bytes(body))
        assert rogue.recv(1) == b""  # server closed the rogue connection
        rogue.close()
        assert RemoteKVBlockStore(srv.address).ping()  # node still healthy


# ======================================================= node server/client
def test_remote_store_satisfies_contract(tmp_path):
    """RemoteKVBlockStore over a real LSM node answers exactly like the
    local store would (the shim adds transport, never semantics)."""
    rng = np.random.default_rng(2)
    local = KVBlockStore(str(tmp_path / "local"), block_size=B, buffer_bytes=4096)
    with CacheNodeServer(
        KVBlockStore(str(tmp_path / "node"), block_size=B, buffer_bytes=4096),
        io_threads=2,
    ) as srv:
        remote = RemoteKVBlockStore(srv.address)
        assert isinstance(remote, StorageBackend)
        assert remote.block_size == B  # fetched from the node
        seqs = []
        for i in range(20):
            toks = _seq(rng, int(rng.integers(1, 5)))
            blocks = _blocks(rng, len(toks) // B)
            assert remote.put_batch(toks, blocks) == local.put_batch(toks, blocks)
            seqs.append(toks)
        assert remote.probe_many(seqs) == local.probe_many(seqs)
        items = [(t, local.probe(t)) for t in seqs]
        for got, want in zip(remote.get_many(items), local.get_many(items)):
            assert len(got) == len(want)
            assert all(np.array_equal(a, c) for a, c in zip(got, want))
        assert remote.stats.put_blocks == local.stats.put_blocks
        assert remote.maintenance(2).keys() == local.maintenance(2).keys()
        remote.flush()
        assert remote.disk_bytes > 0 and remote.file_count > 0
        remote.close()
        local.close()


def test_remote_errors_propagate_without_killing_connection():
    class BoomStore(MemoryOnlyStore):
        def maintenance(self, compact_steps: int = 0) -> dict:
            raise RuntimeError("boom")

    with CacheNodeServer(BoomStore(1 << 20, block_size=B), io_threads=1) as srv:
        remote = RemoteKVBlockStore(srv.address)
        rng = np.random.default_rng(3)
        # the backend raises -> the node reports it as a RemoteError (no
        # retry: the node is healthy) and the connection stays usable
        with pytest.raises(RemoteError, match="boom"):
            remote.maintenance()
        assert remote.rpc_stats.retries == 0
        assert remote.probe(_seq(rng, 1)) == 0  # pool connection survived
        remote.close()


def test_concurrent_clients_one_node(tmp_path):
    """N threads hammer one node over pooled connections: no lost writes,
    no torn payloads (the server serializes per connection, the backend
    carries the thread-safety contract)."""
    with CacheNodeServer(
        KVBlockStore(str(tmp_path / "node"), block_size=B, buffer_bytes=4096),
        io_threads=2,
    ) as srv:
        remote = RemoteKVBlockStore(srv.address, pool_size=4)
        rng = np.random.default_rng(4)
        per_thread = 8
        seqs = [[_seq(np.random.default_rng(100 + t * per_thread + i), 2)
                 for i in range(per_thread)] for t in range(4)]
        errors = []

        def worker(t):
            try:
                trng = np.random.default_rng(t)
                for toks in seqs[t]:
                    blocks = _blocks(trng, 2)
                    remote.put_batch(toks, blocks)
                    got = remote.get_batch(toks, 2 * B)
                    assert len(got) == 2
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errors
        assert remote.probe_many([s for g in seqs for s in g]) == [2 * B] * 32
        remote.close()


# ================================================================= ring
def test_ring_preference_is_stable_and_complete():
    ring = HashRing([f"n{i}" for i in range(5)], vnodes=32)
    for h in range(0, 2**64, 2**61):
        pref = ring.preference(h)
        assert sorted(pref) == list(range(5))
        assert pref == ring.preference(h)  # deterministic


def test_ring_removal_moves_only_the_removed_nodes_keys():
    """Consistent hashing's defining property: dropping node k leaves every
    other key's primary unchanged (filter(pref, -k) == pref of ring w/o k)."""
    ids = [f"node-{i}" for i in range(4)]
    full = HashRing(ids, vnodes=64)
    without = HashRing(ids[:2] + ids[3:], vnodes=64)  # drop node 2
    rng = np.random.default_rng(5)
    moved = kept = 0
    for _ in range(300):
        h = int(rng.integers(0, 2**63))
        pref_ids = [ids[i] for i in full.preference(h) if ids[i] != "node-2"]
        wo_ids = [without.node_ids[i] for i in without.preference(h)]
        assert pref_ids == wo_ids
        if ids[full.primary(h)] == "node-2":
            moved += 1
        else:
            kept += 1
    assert moved > 0 and kept > moved  # ~1/4 of keys move, never more


def test_ring_key_hash_prefix_stable():
    rng = np.random.default_rng(6)
    toks = _seq(rng, 2)
    ext = toks + _seq(rng, 1)
    assert key_hash(toks, B) == key_hash(ext, B)  # same first block


# ====================================================== cluster + failover
_mem_cluster = mem_cluster  # shared fixture factory (tests/cluster_harness.py)


def test_cluster_roundtrip_and_routing_locality():
    servers, cluster = _mem_cluster(3, replication=1)
    try:
        rng = np.random.default_rng(7)
        seqs = [_seq(rng, 2) for _ in range(24)]
        for toks in seqs:
            cluster.put_batch(toks, _blocks(rng, 2))
            ext = toks + _seq(rng, 1)
            # prefix extensions route to the same node set
            assert cluster.replicas_for(ext) == cluster.replicas_for(toks)
        assert cluster.probe_many(seqs) == [2 * B] * len(seqs)
        # exactly one copy exists cluster-wide with replication=1
        total = sum(s.backend.stats.put_blocks for s in servers)
        assert total == 2 * len(seqs)
        assert {len(g) for g in cluster.get_many([(t, 2 * B) for t in seqs])} == {2}
    finally:
        cluster.close()
        for s in servers:
            s.close()


def test_kill_server_mid_get_fails_over_with_zero_loss():
    """The ISSUE acceptance scenario at test scale: replication=2, kill a
    node's server between the puts and the reads — every committed block
    must still be served, by the surviving replica."""
    servers, cluster = _mem_cluster(3, replication=2)
    try:
        rng = np.random.default_rng(8)
        seqs = [_seq(rng, 2) for _ in range(30)]
        payloads = {}
        for i, toks in enumerate(seqs):
            blocks = _blocks(rng, 2)
            cluster.put_batch(toks, blocks)
            payloads[i] = blocks
        victim = cluster.replicas_for(seqs[0])[0]  # primary of seq 0
        servers[victim].close()  # hard kill mid-workload

        for i, toks in enumerate(seqs):
            assert cluster.probe(toks) == 2 * B, f"lost blocks of seq {i}"
            got = cluster.get_batch(toks, 2 * B)
            assert all(np.array_equal(a, b) for a, b in zip(got, payloads[i]))
        assert victim in cluster.down_nodes
        assert cluster.cluster_stats.failovers > 0
        # batched reads fail over too
        assert cluster.probe_many(seqs) == [2 * B] * len(seqs)
        # writes keep 2 live copies among survivors
        toks = _seq(rng, 2)
        cluster.put_batch(toks, _blocks(rng, 2))
        assert len(cluster.replicas_for(toks)) == 2
        assert victim not in cluster.replicas_for(toks)
    finally:
        cluster.close()
        for s in servers:
            s.close()


def test_rejoin_rebalances_back():
    """A node that comes back on the same address is revived by
    refresh_nodes (the maintenance cadence) and resumes its ring arcs."""
    servers, cluster = _mem_cluster(3, replication=2)
    try:
        rng = np.random.default_rng(9)
        seqs = [_seq(rng, 2) for _ in range(16)]
        for toks in seqs:
            cluster.put_batch(toks, _blocks(rng, 2))
        victim = cluster.replicas_for(seqs[0])[0]
        address = servers[victim].address
        servers[victim].close()
        assert cluster.probe(seqs[0]) == 2 * B  # triggers mark-down
        assert victim in cluster.down_nodes

        # restart on the same port with an empty (cold) store
        servers[victim] = CacheNodeServer(
            MemoryOnlyStore(1 << 26, block_size=B),
            port=address[1],
        ).start()
        report = cluster.maintenance(0)  # piggybacked rejoin check
        assert victim in report["revived"]
        assert cluster.down_nodes == []
        # the revived node resumes its ring arcs: some key must route to it
        # again (3 nodes, R=2 — over many keys the chance of never hitting
        # the victim is negligible, and the ring itself is deterministic)
        probe_keys = seqs + [_seq(rng, 2) for _ in range(64)]
        assert any(victim in cluster.replicas_for(t) for t in probe_keys)
        # the cold rejoined replica can't shorten answers: the surviving
        # replica's copy still wins via best-of-replicas reads
        assert cluster.probe_many(seqs) == [2 * B] * len(seqs)
    finally:
        cluster.close()
        for s in servers:
            s.close()


def test_hierarchy_and_engine_run_unchanged_over_cluster(tmp_path):
    """The protocol promise: CacheHierarchy works against a cluster with
    no code changes — acquire/commit round trip through remote nodes."""
    from repro.cache.hierarchy import CacheHierarchy

    servers, cluster = _mem_cluster(2, replication=1)
    try:
        h = CacheHierarchy(B, device_budget_blocks=4, host_budget_blocks=4, store=cluster)
        rng = np.random.default_rng(10)
        toks = _seq(rng, 4)
        acq = h.acquire(toks)
        assert acq.reuse_tokens == 0
        h.commit(toks, _blocks(rng, 4), acq)
        h.release(acq)
        # evict everything from memory tiers; data must come back from disk
        other = _seq(rng, 4)
        acq2 = h.acquire(other)
        h.commit(other, _blocks(rng, 4), acq2)
        h.release(acq2)
        assert cluster.probe(toks) == 4 * B
        assert h.maintenance()["compactions"] == 0  # memory nodes: no LSM work
    finally:
        cluster.close()
        for s in servers:
            s.close()


# =================================================== elastic membership
def test_backend_scan_export_import_roundtrip(tmp_path):
    """The elasticity trio on the LSM backend: stable-order paginated
    scans, aligned stored-encoding export (None for absent keys), and
    idempotent import into a twin store."""
    rng = np.random.default_rng(20)
    src = KVBlockStore(str(tmp_path / "src"), block_size=B, buffer_bytes=4096)
    dst = KVBlockStore(str(tmp_path / "dst"), block_size=B, buffer_bytes=4096)
    seqs = [_seq(rng, 3) for _ in range(7)]
    for toks in seqs:
        src.put_batch(toks, _blocks(rng, 3))
    # paginate the whole keyspace with a tiny limit
    keys, cursor, pages = [], None, 0
    while True:
        page, cursor = src.scan_keys(cursor, limit=4)
        keys.extend(page)
        pages += 1
        if cursor is None:
            break
    assert len(keys) == len(set(keys)) == 21 and pages >= 6
    recs = src.export_encoded(keys + [b"\x00" * 16])
    assert recs[-1] is None and all(r is not None for r in recs[:-1])
    wrote = dst.import_encoded(
        [(k, fl, pl) for k, (fl, pl) in zip(keys, recs[:-1])]
    )
    assert wrote == 21
    # idempotent: a second offer dedups to zero writes
    assert dst.import_encoded(
        [(k, fl, pl) for k, (fl, pl) in zip(keys, recs[:-1])]
    ) == 0
    for toks in seqs:
        assert dst.probe(toks) == 3 * B
        got, want = dst.get_batch(toks, 3 * B), src.get_batch(toks, 3 * B)
        assert all(np.array_equal(a, b) for a, b in zip(got, want))
    assert dst.stats.imported_blocks == 21 and src.stats.exported_blocks >= 21
    src.close()
    dst.close()


def test_add_node_rebalances_within_one_maintenance_cycle():
    """Scale-out 2 -> 4 mid-run: reads are served throughout the
    transition, one maintenance cycle drains the rebalance, a second
    cycle copies nothing (no duplicate fulfills), and every sequence is
    fully resident on its new-ring replica set."""
    servers, cluster = mem_cluster(2, replication=2,
                                   node_ids=["node-0", "node-1"])
    try:
        rng = np.random.default_rng(21)
        seqs = [_seq(rng, 3) for _ in range(32)]
        for toks in seqs:
            cluster.put_batch(toks, _blocks(rng, 3))
        for i in (2, 3):
            cluster.add_node(add_mem_node(servers).address, node_id=f"node-{i}")
        assert cluster.in_transition
        # mid-transition, before any migration: two-ring reads never miss
        assert cluster.probe_many(seqs) == [3 * B] * len(seqs)
        rep = cluster.maintenance(0)
        assert rep["migration"]["done"] and not cluster.in_transition
        ms = cluster.migrator.stats
        assert ms.migrations_completed == 1 and ms.blocks_copied > 0
        assert ms.rebalance_s > 0
        # steady state: every seq full on each of its new-ring replicas
        for toks in seqs:
            for idx in cluster.replicas_for(toks):
                assert cluster.nodes[idx].probe(toks) == 3 * B
        # no duplicate fulfills: the next cycle has nothing to move
        copied_before = ms.blocks_copied
        assert cluster.maintenance(0)["migration"] == {"active": False}
        assert ms.blocks_copied == copied_before
        assert cluster.probe_many(seqs) == [3 * B] * len(seqs)
    finally:
        close_all(cluster, servers)


def test_remove_node_drains_then_retires():
    """remove_node keeps the leaver serving as an old-ring owner until
    its arcs are copied off, then retires it from routing and scrapes it
    as retired."""
    servers, cluster = mem_cluster(3, replication=2,
                                   node_ids=[f"node-{i}" for i in range(3)])
    try:
        rng = np.random.default_rng(22)
        seqs = [_seq(rng, 2) for _ in range(24)]
        for toks in seqs:
            cluster.put_batch(toks, _blocks(rng, 2))
        cluster.remove_node("node-1")
        assert cluster.in_transition
        assert cluster.probe_many(seqs) == [2 * B] * len(seqs)
        rep = cluster.maintenance(0)
        assert rep["migration"]["done"] and not cluster.in_transition
        gone = 1
        assert gone in cluster.retired_nodes
        assert gone not in cluster.live_nodes
        assert cluster.probe_many(seqs) == [2 * B] * len(seqs)
        for toks in seqs:
            assert gone not in cluster.replicas_for(toks)
        assert cluster.scrape_cluster()["nodes"][gone] == {"retired": True}
    finally:
        close_all(cluster, servers)


def test_death_triggers_repair_back_to_full_replication():
    """R=2 and a node dies: reads keep serving (degraded, never failing)
    and the next maintenance cycle re-replicates the lost arcs from the
    survivors — every sequence ends fully resident on >= 2 live nodes,
    with the repair visible in the scrape_cluster gauges."""
    servers, cluster = mem_cluster(3, replication=2,
                                   node_ids=[f"node-{i}" for i in range(3)])
    try:
        rng = np.random.default_rng(23)
        seqs = [_seq(rng, 2) for _ in range(24)]
        for toks in seqs:
            cluster.put_batch(toks, _blocks(rng, 2))
        victim = cluster.replicas_for(seqs[0])[0]
        servers[victim].close()
        # reads served throughout, by the surviving replica
        assert cluster.probe_many(seqs) == [2 * B] * len(seqs)
        assert victim in cluster.down_nodes
        rep = cluster.maintenance(0)
        assert rep["migration"]["kind"] == "repair" and rep["migration"]["done"]
        ms = cluster.migrator.stats
        assert ms.repairs_completed == 1 and ms.repair_blocks > 0
        assert ms.repair_lag_s > 0
        for toks in seqs:
            full = sum(1 for i in cluster.live_nodes
                       if cluster.nodes[i].probe(toks) == 2 * B)
            assert full >= 2, "sequence not back at full replication"
        # repaired down-set is remembered: no repeated repair next cycle
        assert cluster.maintenance(0)["migration"] == {"active": False}
        g = cluster.scrape_cluster()["cluster"]["gauges"]
        assert g["repro_migration_repairs_completed"] == 1.0
        assert g["repro_migration_repair_blocks"] > 0
    finally:
        close_all(cluster, servers)


@pytest.mark.timeout(180)
def test_sigkill_mid_migration_loses_no_committed_blocks(tmp_path):
    """The fault-injection acceptance scenario on real child processes:
    SIGKILL a migration *source* between incremental migrator steps.
    Committed blocks must stay readable throughout (degraded, never
    failing), the rebalance must still complete from the surviving
    replicas, and repair must restore R copies — all verified through
    scrape_cluster() counters."""
    nodes = spawn_nodes(tmp_path, 4)
    cluster = ClusterKVBlockStore(
        [n.address for n in nodes[:3]], replication=2, retries=0,
        connect_timeout_s=2.0, node_ids=[f"node-{i}" for i in range(3)],
    )
    try:
        rng = np.random.default_rng(24)
        seqs = [_seq(rng, 2) for _ in range(24)]
        for toks in seqs:
            cluster.put_batch(toks, _blocks(rng, 2))
        cluster.add_node(nodes[3].address, node_id="node-3")
        # migrate incrementally so there is a mid-migration window
        step = cluster.migrate_step(max_pages=1)
        assert step["active"] or step["done"]
        assert cluster.probe_many(seqs) == [2 * B] * len(seqs)
        # SIGKILL a source mid-migration (never the just-joined node)
        victim = cluster.replicas_for(seqs[0])[0]
        nodes[victim].kill()
        # reads stay served across the kill — degraded, never failing
        assert cluster.probe_many(seqs) == [2 * B] * len(seqs)
        # drive maintenance until rebalance + repair have both completed
        for _ in range(20):
            cluster.maintenance(0)
            ms = cluster.migrator.stats
            if (not cluster.in_transition and not cluster.migrator.active
                    and ms.repairs_completed >= 1):
                break
            assert cluster.probe_many(seqs) == [2 * B] * len(seqs)
        ms = cluster.migrator.stats
        assert not cluster.in_transition
        assert ms.migrations_completed >= 1 and ms.repairs_completed >= 1
        # zero lost committed blocks, full replication among survivors
        assert cluster.probe_many(seqs) == [2 * B] * len(seqs)
        for toks in seqs:
            full = sum(1 for i in cluster.live_nodes
                       if cluster.nodes[i].probe(toks) == 2 * B)
            assert full >= 2
        g = cluster.scrape_cluster()["cluster"]["gauges"]
        assert g["repro_migration_migrations_completed"] >= 1.0
        assert g["repro_migration_repairs_completed"] >= 1.0
        assert g["repro_migration_blocks_copied"] > 0
        # import-side dedup: offers can exceed writes, never the reverse
        assert g["repro_migration_blocks_pulled"] >= g["repro_migration_blocks_copied"]
    finally:
        cluster.close()
        for n in nodes:
            n.close()


@pytest.mark.timeout(120)
def test_child_process_node_spawn_kill(tmp_path):
    """Deployment path: a real child-process node serves a real LSM store;
    SIGKILL surfaces as NodeUnavailable at the client."""
    node = spawn_local_node(str(tmp_path / "n0"), block_size=B, codec="raw",
                            io_threads=1)
    try:
        remote = RemoteKVBlockStore(node.address, retries=1, timeout_s=10.0)
        rng = np.random.default_rng(11)
        toks = _seq(rng, 2)
        blocks = _blocks(rng, 2)
        assert remote.put_batch(toks, blocks) == 2
        got = remote.get_batch(toks, 2 * B)
        assert all(np.array_equal(a, b) for a, b in zip(got, blocks))
        node.kill()
        with pytest.raises(NodeUnavailable):
            remote.probe(toks)
        remote.close()
    finally:
        node.close()
