"""Distribution layer: sharding rules, spec filtering, gradient
compression, and a subprocess smoke of the lowering pipeline (the full
production-mesh proof lives in the dry-run artifacts)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


class FakeMesh:
    """Duck-typed mesh for rule tests (no devices needed)."""

    def __init__(self, axes):
        self.axis_names = tuple(axes)
        self._shape = tuple(axes.values())

    @property
    def devices(self):
        return np.empty(self._shape, dtype=object)


def test_param_spec_rules():
    fs = ("data",)
    assert shd.param_spec_for("embed", 2, False, fs) == P("model", "data")
    assert shd.param_spec_for("lm_head", 2, False, fs) == P("data", "model")
    assert shd.param_spec_for("blocks/attn/wq", 3, True, fs) == P(None, "data", "model")
    assert shd.param_spec_for("blocks/attn/wo", 3, True, fs) == P(None, "model", "data")
    assert shd.param_spec_for("blocks/moe/w_gate", 4, True, fs) == P(None, "model", "data", None)
    assert shd.param_spec_for("blocks/mlp/w_down", 3, True, fs) == P(None, "model", "data")
    assert shd.param_spec_for("blocks/attn_norm", 2, True, fs) == P()  # replicated
    # multi-axis fsdp (kimi-k2 ZeRO over pod+data)
    spec = shd.param_spec_for("blocks/moe/w_gate", 4, True, ("pod", "data"))
    assert spec == P(None, "model", ("pod", "data"), None)


def test_filter_spec_drops_missing_and_indivisible():
    mesh = FakeMesh({"data": 4, "model": 8})
    assert shd.filter_spec(P("pod", "model"), mesh) == P(None, "model")
    # 10 % 8 != 0 -> model dropped from that dim
    assert shd.filter_spec(P("data", "model"), mesh, (8, 10)) == P("data", None)
    # composite axes keep the dividing prefix
    assert shd.filter_spec(P(("data", "model"),), mesh, (4,)) == P("data")


def test_cache_sharding_never_seq_for_attn():
    # kvh divides TP -> head sharding
    spec, _ = shd.cache_spec_for("k", (4, 16, 128, 8, 64), model=8)
    assert spec[3] == "model" and spec[2] is None
    # kvh doesn't divide -> head-dim (contraction) sharding, never seq
    spec, _ = shd.cache_spec_for("v", (4, 16, 128, 2, 64), model=8)
    assert spec[3] is None and spec[4] == "model" and spec[2] is None
    # MLA latent prefers the latent dim (same seq-DUS hazard)
    spec, _ = shd.cache_spec_for("c", (4, 16, 128, 32), model=8)
    assert spec[3] == "model" and spec[2] is None
    spec, _ = shd.cache_spec_for("c", (4, 16, 128, 30), model=8)
    assert spec[2] == "model"  # fallback when latent doesn't divide


def test_grad_compression_error_feedback_converges():
    from repro.distributed import compression as cmp

    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)}
    res = cmp.init_residuals(g_true)
    acc = jnp.zeros_like(g_true["w"])
    n = 50
    for _ in range(n):
        q, s, res = cmp.compress_grads(g_true, res)
        acc = acc + cmp.dequantize_tensor(q["w"], s["w"])
    # error feedback keeps the long-run mean unbiased
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g_true["w"]), atol=0.02)


def test_quantize_tensor_range():
    from repro.distributed.compression import dequantize_tensor, quantize_tensor

    x = jnp.asarray([[-3.0, 0.0, 3.0]])
    q, s = quantize_tensor(x)
    assert q.dtype == jnp.int8 and int(q.max()) == 127
    np.testing.assert_allclose(np.asarray(dequantize_tensor(q, s)), np.asarray(x), atol=0.03)


def test_elastic_restore_across_real_mesh_shapes_subprocess():
    """Checkpoint sharded on a (2,2) mesh, restore onto (4,1) — leaves
    placed under the new shardings must match bit-for-bit."""
    script = textwrap.dedent(
        """
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training import checkpoint as ckpt

        d = tempfile.mkdtemp()
        m1 = jax.make_mesh((2, 2), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
        tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((8,), jnp.bfloat16)}
        tree = {
            "w": jax.device_put(tree["w"], NamedSharding(m1, P("data", "model"))),
            "b": jax.device_put(tree["b"], NamedSharding(m1, P("model"))),
        }
        ckpt.save(d, 3, tree)

        m2 = jax.make_mesh((4, 1), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
        sh2 = {
            "w": NamedSharding(m2, P("model", "data")),  # different layout too
            "b": NamedSharding(m2, P("data")),
        }
        restored, manifest = ckpt.restore(d, 3, tree, sh2)
        assert manifest["step"] == 3
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(64.0).reshape(8, 8))
        assert restored["w"].sharding.is_equivalent_to(sh2["w"], 2)
        assert restored["b"].dtype == jnp.bfloat16
        print("ELASTIC_OK")
        """
    )
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo",
    )
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]


def test_lowering_pipeline_smoke_subprocess():
    """lower+compile two smoke cells on a 2x2 host mesh in a subprocess
    (device count must be set before jax import)."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.launch import steps
        from repro.launch.mesh import make_smoke_mesh
        from repro.launch.roofline import extract

        mesh = make_smoke_mesh(2, 2)
        cfg = get_config("qwen3-14b", smoke=True)
        for shape in [ShapeConfig("t", 64, 8, "train"), ShapeConfig("d", 64, 8, "decode")]:
            compiled = steps.lower_cell(mesh, cfg, shape).compile()
            rl, coll = extract(compiled, cfg, shape, 4)
            assert rl.flops > 0 and rl.hbm_bytes > 0, shape
        print("LOWER_OK")
        """
    )
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=560,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo",
    )
    assert "LOWER_OK" in r.stdout, r.stderr[-2000:]
