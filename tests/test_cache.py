"""Tests for the radix tree + tier hierarchy (paper §2.1 integration)."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.cache import (
    TIER_DEVICE,
    TIER_DISK,
    TIER_HOST,
    CacheHierarchy,
    RadixTree,
)
from repro.core import CODEC_RAW, BatchCodec, KVBlockStore

B = 4


def _blocks(rng, n):
    return [rng.standard_normal((2, B, 4), dtype=np.float32) for _ in range(n)]


def _hier(tmp_path, dev=8, host=8, store=True, **kw):
    st_ = None
    if store:
        st_ = KVBlockStore(str(tmp_path / "kvs"), block_size=B, buffer_bytes=1 << 16,
                           codec=BatchCodec(CODEC_RAW, use_zlib=False))
    return CacheHierarchy(B, dev, host, store=st_, **kw)


# ------------------------------------------------------------------ radix
def test_radix_match_and_insert():
    t = RadixTree(B)
    toks = list(range(16))
    assert t.match_prefix(toks) == []
    path = t.insert_path(toks)
    assert len(path) == 4
    assert [n.depth for n in path] == [1, 2, 3, 4]
    # shared prefix
    other = toks[:8] + [99] * 8
    m = t.match_prefix(other)
    assert len(m) == 2
    path2 = t.insert_path(other)
    assert path2[:2] == m
    assert t.n_nodes == 6


@given(st.lists(st.lists(st.integers(0, 5), min_size=B, max_size=6 * B), min_size=1, max_size=20))
@settings(max_examples=25, deadline=None)
def test_radix_matches_oracle(seqs):
    """match_prefix == longest stored block-prefix (dict oracle)."""
    t = RadixTree(B)
    oracle = set()
    for toks in seqs:
        t.insert_path(toks)
        for i in range(len(toks) // B):
            oracle.add(tuple(toks[: (i + 1) * B]))
        m = t.match_prefix(toks)
        want = 0
        for i in range(len(toks) // B, 0, -1):
            if tuple(toks[: i * B]) in oracle:
                want = i
                break
        assert len(m) == want


def test_radix_eviction_order_lru():
    t = RadixTree(B)
    a = t.insert_path(list(range(4)))[-1]
    b = t.insert_path(list(range(100, 104)))[-1]
    for n in (a, b):
        n.tier = TIER_DEVICE
    a.touch()  # a is now most recent
    leaves = t.evictable_leaves(TIER_DEVICE)
    assert leaves[0] is b and leaves[1] is a
    b.lock += 1
    assert t.evictable_leaves(TIER_DEVICE) == [a]


# -------------------------------------------------------------- hierarchy
def test_acquire_commit_roundtrip(tmp_path):
    h = _hier(tmp_path)
    rng = np.random.default_rng(0)
    toks = list(range(16))
    acq = h.acquire(toks)
    assert acq.reuse_tokens == 0
    h.commit(toks, _blocks(rng, 4), acq)
    h.release(acq)
    acq2 = h.acquire(toks)
    assert acq2.reuse_tokens == 16
    assert acq2.device_tokens == 16  # still hot
    h.release(acq2)
    assert h.hit_rate > 0


def test_demotion_to_host_then_disk(tmp_path):
    h = _hier(tmp_path, dev=2, host=2)
    rng = np.random.default_rng(1)
    seqs = [list(range(i * 100, i * 100 + 8)) for i in range(4)]
    for s in seqs:
        acq = h.acquire(s)
        h.commit(s, _blocks(rng, 2), acq)
        h.release(acq)
    counts = h.tree.count_by_tier()
    assert counts[TIER_DEVICE] <= 2
    assert counts[TIER_HOST] <= 2
    assert counts[TIER_DISK] >= 1  # overflow hit the disk tier
    # oldest sequence must still be reusable via disk
    acq = h.acquire(seqs[0])
    assert acq.reuse_tokens == 8
    assert acq.disk_tokens > 0 or acq.host_tokens > 0
    h.release(acq)


def test_disk_extension_beyond_memory(tmp_path):
    """Blocks that never entered this tree instance (e.g. from a previous
    process) are found via store.probe — the drop-in integration of §3.2."""
    store = KVBlockStore(str(tmp_path / "kvs"), block_size=B, buffer_bytes=1 << 16,
                         codec=BatchCodec(CODEC_RAW, use_zlib=False))
    rng = np.random.default_rng(2)
    toks = list(range(32))
    store.put_batch(toks, _blocks(rng, 8))
    h = CacheHierarchy(B, 16, 16, store=store)
    acq = h.acquire(toks)
    assert acq.reuse_tokens == 32  # all from disk, promoted
    assert acq.disk_tokens == 32
    h.release(acq)
    acq2 = h.acquire(toks)
    assert acq2.device_tokens == 32  # now hot
    h.release(acq2)


def test_memory_only_drops_blocks(tmp_path):
    h = _hier(tmp_path, dev=2, host=2, store=False)
    rng = np.random.default_rng(3)
    seqs = [list(range(i * 100, i * 100 + 8)) for i in range(4)]
    for s in seqs:
        acq = h.acquire(s)
        h.commit(s, _blocks(rng, 2), acq)
        h.release(acq)
    assert h.stats.drops > 0
    acq = h.acquire(seqs[0])
    assert acq.reuse_tokens < 8  # (partially) lost without a disk tier
    h.release(acq)


def test_locked_paths_survive_pressure(tmp_path):
    h = _hier(tmp_path, dev=2, host=1)
    rng = np.random.default_rng(4)
    t1 = list(range(8))
    acq1 = h.acquire(t1)
    h.commit(t1, _blocks(rng, 2), acq1)
    # do NOT release; pressure from another sequence
    acq1b = h.acquire(t1)  # locks the path
    t2 = list(range(100, 108))
    acq2 = h.acquire(t2)
    h.commit(t2, _blocks(rng, 2), acq2)
    # locked path must still be device-resident
    assert all(n.tier == TIER_DEVICE for n in acq1b.nodes)
    h.release(acq1b)
    h.release(acq1)
    h.release(acq2)


def test_write_through_persists_across_restart(tmp_path):
    rng = np.random.default_rng(5)
    toks = list(range(16))
    h = _hier(tmp_path, write_through=True)
    acq = h.acquire(toks)
    h.commit(toks, _blocks(rng, 4), acq)
    h.release(acq)
    h.store.close()
    # new process: fresh tree, same disk
    store2 = KVBlockStore(str(tmp_path / "kvs"), block_size=B, buffer_bytes=1 << 16,
                          codec=BatchCodec(CODEC_RAW, use_zlib=False))
    h2 = CacheHierarchy(B, 8, 8, store=store2)
    acq2 = h2.acquire(toks)
    assert acq2.reuse_tokens == 16
    h2.release(acq2)
    store2.close()
