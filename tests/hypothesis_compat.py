"""Optional-import shim for ``hypothesis``.

Property tests use hypothesis when it is installed (declared in
``requirements-dev.txt``); when it is absent the decorated tests are
collected but skip with a clear reason instead of failing the whole
suite at import time.  Test modules import ``given / settings / st /
HealthCheck`` from here rather than from ``hypothesis`` directly.
"""

from __future__ import annotations

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False
    _SKIP_REASON = "hypothesis not installed (see requirements-dev.txt); property test skipped"

    class _Strategy:
        """Inert stand-in for a hypothesis strategy: absorbs any call."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()

    class HealthCheck:
        def __getattr__(self, name):
            return None

    HealthCheck = HealthCheck()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # zero-arg replacement: pytest must not try to resolve the
            # strategy parameters as fixtures
            def skipper():
                pytest.skip(_SKIP_REASON)

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco
