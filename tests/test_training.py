"""Training runtime: optimizer math, checkpoint atomicity, crash-resume
determinism, elastic restore across mesh shapes."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.training import checkpoint as ckpt
from repro.training import optim
from repro.training.data import DataConfig, SyntheticLM
from repro.training.loop import TrainConfig, train


# ------------------------------------------------------------------- optim
def test_adamw_reduces_loss_quadratic():
    ocfg = optim.OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = optim.init_state(ocfg, params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = optim.apply_updates(ocfg, params, g, state)
    assert float(loss(params)) < 0.05


def test_factored_second_moment_shapes():
    ocfg = optim.OptimizerConfig(factored_second_moment=True, moment_dtype="bfloat16")
    params = {"m": jnp.zeros((8, 16)), "v1d": jnp.zeros((5,))}
    st = optim.init_state(ocfg, params)
    assert st["v"]["m"]["vr"].shape == (8,)
    assert st["v"]["m"]["vc"].shape == (16,)
    assert st["v"]["v1d"]["v"].shape == (5,)  # 1-D params stay unfactored
    assert st["m"]["m"].dtype == jnp.bfloat16
    # state_specs mirrors init_state
    specs = optim.state_specs(ocfg, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))
    assert specs["v"]["m"]["vr"].shape == (8,)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, nrm = optim.clip_by_global_norm(g, 1.0)
    assert float(nrm) == pytest.approx(200.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


# -------------------------------------------------------------------- data
def test_data_deterministic_replay():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=3)
    a = SyntheticLM(cfg).batch_at(17)
    b = SyntheticLM(cfg).batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg).batch_at(18)
    assert not np.array_equal(a["tokens"], c["tokens"])


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.float32)}}
    ckpt.save(str(tmp_path), 5, tree)
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored, manifest = ckpt.restore(str(tmp_path), 5, tree)
    assert manifest["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["a"], np.float32), np.asarray(tree["a"], np.float32))
    assert restored["a"].dtype == jnp.bfloat16


def test_checkpoint_tmp_dirs_invisible(tmp_path):
    tree = {"a": jnp.zeros(3)}
    ckpt.save(str(tmp_path), 1, tree)
    os.makedirs(str(tmp_path / "step_000000002.tmp"))  # simulated crash
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_prune(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree)
    ckpt.prune(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    assert sorted(os.listdir(str(tmp_path)))[0] == "step_000000004"


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Checkpoint under a (2,) layout restores onto other shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(str(tmp_path), 1, tree)
    mesh = jax.make_mesh((1,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = ckpt.restore(str(tmp_path), 1, tree, sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)


# --------------------------------------------------------------- train loop
def test_train_crash_resume_identical_trajectory(tmp_path):
    cfg = get_config("qwen3-14b", smoke=True)
    base = TrainConfig(steps=12, ckpt_every=4, ckpt_dir=str(tmp_path / "a"), log_every=100)
    full = train(cfg, base, log=lambda *_: None)

    crash_dir = str(tmp_path / "b")
    c1 = train(cfg, TrainConfig(steps=12, ckpt_every=4, ckpt_dir=crash_dir, log_every=100),
               crash_after=6, log=lambda *_: None)
    assert c1["crashed"]
    c2 = train(cfg, TrainConfig(steps=12, ckpt_every=4, ckpt_dir=crash_dir, log_every=100),
               log=lambda *_: None)
    assert c2["resumed_from"] == 4  # newest committed checkpoint before the crash
    # post-resume losses replay the uninterrupted run exactly
    np.testing.assert_allclose(c2["losses"], full["losses"][4:], rtol=1e-5, atol=1e-6)
