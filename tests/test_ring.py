"""Property suite for the consistent-hash ring and the two-ring
transition machinery (elastic membership).

Follows the ``test_codec_policy.py`` pattern: every property has a
deterministic grid twin that always runs (a fixed low-discrepancy sweep
of the 64-bit keyspace plus the adversarial boundary points — the ring
points themselves and their neighbours), and a hypothesis-driven variant
that explores random memberships and hashes when hypothesis is
installed.

The properties are the ones the cluster layer's correctness rests on:

* **minimal movement** — adding one node to an N-node ring remaps at
  most ~c/N of the keyspace (consistent hashing's defining bound),
* **prefix stability** — the preference list with node k filtered out
  equals the preference list of the ring built without k (failover
  lands where re-routed writes land, with no coordination),
* **transition completeness** — ``TransitionView.read_ids`` always
  contains every old r-owner and every new r-owner, so no key is
  unreachable mid-migration; a key outside the moved arcs has its new
  owners already among its old owners,
* **arc algebra** — ``moved_arcs`` / ``affected_arcs`` agree exactly
  with the per-key owner-set definitions they summarize, including at
  ring-point boundaries where the bisect-side convention bites.
"""

from hypothesis_compat import given, settings, st

from repro.cluster.ring import (
    HashRing,
    TransitionView,
    affected_arcs,
    in_arc,
    moved_arcs,
)

U64 = 2**64
# low-discrepancy sweep (Weyl sequence on the golden ratio) — a fixed,
# deterministic sample of the keyspace used by every grid twin
GRID = [(i * 0x9E3779B97F4A7C15) % U64 for i in range(512)]


def _ids(n, prefix="node"):
    return [f"{prefix}-{i}" for i in range(n)]


def _boundary_hashes(*rings):
    """The adversarial sample: every ring point, its predecessor, and its
    successor — where the half-open ``(lo, hi]`` convention matters."""
    out = set()
    for ring in rings:
        for p in ring._points:
            out.update(((p - 1) % U64, p, (p + 1) % U64))
    return sorted(out)


def _owner_sets(old, new, r, h):
    return set(old.preference_ids(h)[:r]), set(new.preference_ids(h)[:r])


# ------------------------------------------------------------ in_arc algebra
def test_in_arc_wrap_and_degenerate():
    assert in_arc(5, 5, 0) and in_arc(5, 5, U64 - 1)  # lo == hi: full ring
    assert in_arc(10, 20, 11) and in_arc(10, 20, 20)
    assert not in_arc(10, 20, 10)  # half-open low side
    assert not in_arc(10, 20, 21)
    # wrapping arc (lo > hi)
    assert in_arc(U64 - 5, 3, U64 - 1) and in_arc(U64 - 5, 3, 0)
    assert in_arc(U64 - 5, 3, 3) and not in_arc(U64 - 5, 3, U64 - 5)
    assert not in_arc(U64 - 5, 3, 1000)


# -------------------------------------------------------- minimal movement
def _movement_fraction(n, vnodes=64, samples=GRID):
    old = HashRing(_ids(n), vnodes=vnodes)
    new = HashRing(_ids(n + 1), vnodes=vnodes)
    # compare primaries by id, not ring-local index
    moved = sum(
        1 for h in samples
        if old.preference_ids(h)[0] != new.preference_ids(h)[0]
    )
    return moved / len(samples)


def test_one_node_add_remaps_bounded_fraction_grid():
    """Adding node N+1 moves ~1/(N+1) of keys; c=2.5 absorbs vnode
    placement variance at 64 vnodes over the 512-sample grid."""
    for n in (2, 3, 5, 8):
        frac = _movement_fraction(n)
        assert 0 < frac <= 2.5 / (n + 1), (n, frac)


@given(n=st.integers(2, 10), seed=st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_one_node_add_remaps_bounded_fraction_property(n, seed):
    import numpy as np

    rng = np.random.default_rng(seed)
    samples = [int(x) for x in rng.integers(0, U64, 512, dtype=np.uint64)]
    frac = _movement_fraction(n, samples=samples)
    assert 0 < frac <= 2.5 / (n + 1), (n, frac)


# --------------------------------------------------------- prefix stability
def _check_prefix_stability(ids, drop, hashes):
    full = HashRing(ids, vnodes=64)
    without = HashRing([i for i in ids if i != drop], vnodes=64)
    for h in hashes:
        filtered = [nid for nid in full.preference_ids(h) if nid != drop]
        assert filtered == without.preference_ids(h), (drop, h)


def test_preference_prefix_stable_under_down_filtering_grid():
    """Filtering a dead node out of the full ring's preference list gives
    exactly the without-ring's list — for every node, at grid hashes AND
    at every ring-point boundary."""
    ids = _ids(5)
    full = HashRing(ids, vnodes=64)
    hashes = GRID[:128] + _boundary_hashes(full)[: 4 * 64]
    for drop in ids:
        _check_prefix_stability(ids, drop, hashes)


@given(
    n=st.integers(2, 8),
    drop=st.integers(0, 7),
    hashes=st.lists(st.integers(0, U64 - 1), min_size=1, max_size=64),
)
@settings(max_examples=25, deadline=None)
def test_preference_prefix_stable_property(n, drop, hashes):
    ids = _ids(n)
    _check_prefix_stability(ids, ids[drop % n], hashes)


# --------------------------------------------------- transition completeness
def _check_transition(old_ids, new_ids, r, hashes):
    old = HashRing(old_ids, vnodes=64)
    new = HashRing(new_ids, vnodes=64)
    view = TransitionView(old, new, r)
    for h in hashes:
        old_set, new_set = _owner_sets(old, new, view.replicas, h)
        reads = view.read_ids(h)
        # never loses a key: wherever it lives (old owners) and wherever
        # writes now land (new owners) are both consulted
        assert old_set <= set(reads) and new_set <= set(reads), h
        # new owners come first (the steady-state answer)
        assert reads[: len(new_set)] == new.preference_ids(h)[: view.replicas]
        # arc summary agrees with the per-key definition
        assert view.key_moved(h) == (not new_set <= old_set), h


def test_transition_view_never_loses_a_key_grid():
    """Grow, shrink, and swap memberships: at grid hashes and at every
    boundary point of either ring, reads cover old and new owners and
    ``moved_arcs`` matches the owner-set definition exactly."""
    cases = [
        (_ids(2), _ids(4), 2),     # scale out 2 -> 4
        (_ids(4), _ids(3), 2),     # drain one node
        (_ids(3), _ids(3)[:2] + ["node-9"], 2),  # replace a member
        (_ids(1), _ids(2), 1),     # degenerate: single node grows
        (_ids(5), _ids(6), 3),     # r=3
    ]
    for old_ids, new_ids, r in cases:
        old = HashRing(old_ids, vnodes=64)
        new = HashRing(new_ids, vnodes=64)
        hashes = GRID[:128] + _boundary_hashes(old, new)[: 6 * 64]
        _check_transition(old_ids, new_ids, r, hashes)


@given(
    n_old=st.integers(1, 6),
    n_new=st.integers(1, 6),
    r=st.integers(1, 3),
    hashes=st.lists(st.integers(0, U64 - 1), min_size=1, max_size=48),
)
@settings(max_examples=25, deadline=None)
def test_transition_view_never_loses_a_key_property(n_old, n_new, r, hashes):
    # overlap the memberships so there is something to keep AND move
    old_ids = _ids(n_old)
    new_ids = _ids(max(1, n_new - 1)) + ([f"joiner-{n_new}"] if n_new > 1 else [])
    _check_transition(old_ids, new_ids, r, hashes)


def test_unmoved_keys_need_no_copy_grid():
    """A key outside the moved arcs already has all its new owners among
    its old owners — migration can skip it entirely."""
    old = HashRing(_ids(3), vnodes=64)
    new = HashRing(_ids(4), vnodes=64)
    view = TransitionView(old, new, 2)
    unmoved = 0
    for h in GRID:
        if not view.key_moved(h):
            old_set, new_set = _owner_sets(old, new, 2, h)
            assert new_set <= old_set, h
            unmoved += 1
    assert unmoved > 0  # the sweep must actually exercise the branch


# ------------------------------------------------------------- repair arcs
def test_affected_arcs_match_owner_sets_grid():
    """A hash lies in ``affected_arcs(ring, down, r)`` iff its r-owner
    set intersects the down set — at grid hashes and ring boundaries."""
    ring = HashRing(_ids(5), vnodes=64)
    hashes = GRID[:128] + _boundary_hashes(ring)[: 5 * 64]
    for down in (["node-0"], ["node-2", "node-4"]):
        arcs = affected_arcs(ring, down, 2)
        for h in hashes:
            hit = any(in_arc(lo, hi, h) for lo, hi in arcs)
            owners = set(ring.preference_ids(h)[:2])
            assert hit == bool(owners & set(down)), (down, h)


def test_moved_arcs_full_ring_degenerate():
    """Replacing every member moves the whole keyspace: the summary
    collapses to the full-ring arc and every key reads as moved."""
    old = HashRing(["a"], vnodes=8)
    new = HashRing(["b"], vnodes=8)
    arcs = moved_arcs(old, new, 1)
    assert len(arcs) == 1 and arcs[0][0] == arcs[0][1]
    view = TransitionView(old, new, 1)
    assert all(view.key_moved(h) for h in GRID[:64])
