"""Runtime layer units: IOExecutor, CommitQueue, MaintenanceService, the
plan/fetch/fulfill acquire split, parallel shard fan-out, and the pipelined
engine end-to-end."""

import threading
import time

import numpy as np
import pytest

from repro.cache.hierarchy import CacheHierarchy
from repro.configs import get_config
from repro.core.sharded_store import ShardedKVBlockStore
from repro.core.store import KVBlockStore
from repro.runtime import CommitQueue, IOExecutor, MaintenanceService, RuntimeServices
from repro.serving import ComputeModel, ServingEngine
from repro.workload import StagedWorkload


# ------------------------------------------------------------- IOExecutor
def test_executor_parallel_and_order():
    with IOExecutor(max_workers=4) as ex:
        out = ex.map_parallel(lambda x: x * x, list(range(20)))
        assert out == [x * x for x in range(20)]
        assert ex.stats.submitted >= 20
        assert ex.stats.completed >= 20


def test_executor_serial_mode_runs_inline():
    ex = IOExecutor(max_workers=0)
    tid = threading.get_ident()
    fut = ex.submit(lambda: threading.get_ident())
    assert fut.result() == tid  # ran on the calling thread
    assert ex.stats.inline == 1
    ex.close()


def test_executor_propagates_exceptions():
    with IOExecutor(max_workers=2) as ex:
        fut = ex.submit(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            fut.result(timeout=5)
        with pytest.raises(ZeroDivisionError):
            ex.map_parallel(lambda x: 1 / x, [1, 0, 2])


def test_executor_backpressure_bounds_in_flight():
    ex = IOExecutor(max_workers=2, max_pending=2)
    gate = threading.Event()
    futs = [ex.submit(gate.wait, 5) for _ in range(2)]
    t = threading.Thread(target=lambda: ex.submit(lambda: None))
    t.start()
    time.sleep(0.05)
    assert ex.in_flight <= 2  # third submit is blocked, not queued
    gate.set()
    t.join(timeout=5)
    assert not t.is_alive()
    for f in futs:
        f.result(timeout=5)
    ex.close()
    assert ex.stats.queue_depth_max <= 2


# ------------------------------------------------------------- CommitQueue
def test_commit_queue_fifo_and_flush():
    q = CommitQueue(max_items=8)
    seen = []
    for i in range(16):
        q.submit(lambda i=i: seen.append(i), nbytes=1)
    q.flush()
    assert seen == list(range(16))  # FIFO order preserved
    assert q.stats.completed == 16
    assert q.stats.enqueued_bytes == 16
    q.close()
    assert q.depth == 0


def test_commit_queue_surfaces_failures_on_flush():
    q = CommitQueue()
    q.submit(lambda: (_ for _ in ()).throw(RuntimeError("disk full")))
    with pytest.raises(RuntimeError, match="disk full"):
        q.flush()
    # the error is consumed; subsequent flushes are clean
    q.submit(lambda: None)
    q.flush()
    assert q.stats.failed == 1
    q.close()


def test_commit_queue_backpressure_blocks_producer():
    q = CommitQueue(max_items=2)
    gate = threading.Event()
    q.submit(lambda: gate.wait(5))
    q.submit(lambda: None)
    t0 = time.perf_counter()

    def unblock():
        time.sleep(0.05)
        gate.set()

    threading.Thread(target=unblock).start()
    q.submit(lambda: None)  # must block until the drain catches up
    assert time.perf_counter() - t0 > 0.02
    q.flush()
    assert q.stats.stall_s > 0
    q.close()


# ------------------------------------------------------- MaintenanceService
def test_maintenance_service_runs_and_harvests():
    calls = []

    def cycle():
        calls.append(1)
        return {"compactions": 2, "evicted_files": 1}

    svc = MaintenanceService(cycle)
    assert svc.maybe_schedule()
    svc.drain()
    assert calls
    got = svc.harvest()
    assert got.compactions == 2 * len(calls)
    assert got.evicted_files == len(calls)
    # harvest resets
    assert svc.harvest().compactions == 0
    assert svc.stats.cycles == len(calls)


def test_maintenance_service_coalesces_overlapping_schedules():
    gate = threading.Event()
    n = []

    def cycle():
        n.append(1)
        gate.wait(2)
        return {}

    svc = MaintenanceService(cycle)
    assert svc.maybe_schedule()
    assert not svc.maybe_schedule()  # coalesced into the running cycle
    assert not svc.maybe_schedule()
    gate.set()
    svc.drain()
    assert len(n) == 2  # one running + one coalesced rerun


def test_maintenance_service_surfaces_errors_on_drain():
    svc = MaintenanceService(lambda: (_ for _ in ()).throw(ValueError("boom")))
    svc.maybe_schedule()
    with pytest.raises(ValueError, match="boom"):
        svc.drain()
    assert svc.stats.errors == 1


# ------------------------------------------------- plan / fetch / fulfill
def _mk_blocks(n, B=16, width=32, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((B, width)).astype(np.float16) for _ in range(n)]


def test_acquire_equals_plan_fetch_fulfill(tmp_path):
    store = KVBlockStore(str(tmp_path / "s"), block_size=16)
    h = CacheHierarchy(16, 64, 64, store=store)
    tokens = list(range(64))
    store.put_batch(tokens, _mk_blocks(4))
    plan = h.plan(tokens)
    assert plan.need_disk
    fetched = h.fetch(plan)
    assert fetched.probed_tokens == 64
    assert len(fetched.blocks) == 4
    acq = h.fulfill(plan, fetched)
    assert acq.reuse_tokens == 64
    assert acq.disk_tokens == 64
    h.release(acq)
    # second acquire: all device-resident, no disk I/O needed
    acq2 = h.acquire(tokens)
    assert acq2.device_tokens == 64
    h.release(acq2)
    store.close()


def test_fulfill_honors_commits_landed_after_plan(tmp_path):
    """A plan staged before a commit must not clobber the fresher tree."""
    store = KVBlockStore(str(tmp_path / "s"), block_size=16)
    h = CacheHierarchy(16, 64, 64, store=store)
    tokens = list(range(64))
    plan = h.plan(tokens)  # tree is empty at plan time
    fetched = h.fetch(plan)
    # meanwhile the engine commits the same prompt (batch k finishing)
    acq0 = h.acquire(tokens)
    h.commit(tokens, _mk_blocks(4), acq0)
    h.release(acq0)
    acq = h.fulfill(plan, fetched)
    assert acq.reuse_tokens == 64  # re-match saw the committed chain
    assert h.stats.plan_stale >= 1
    h.release(acq)
    store.close()


def test_write_behind_commit_populates_disk(tmp_path):
    q = CommitQueue()
    store = KVBlockStore(str(tmp_path / "s"), block_size=16)
    h = CacheHierarchy(16, 64, 64, store=store, commit_queue=q)
    tokens = list(range(64))
    acq = h.acquire(tokens)
    h.commit(tokens, _mk_blocks(4), acq)
    h.release(acq)
    assert h.stats.writeback_blocks == 4
    q.flush()
    assert store.probe(tokens) == 64  # the drain thread wrote it through
    q.close()
    store.close()


# ------------------------------------------------------- parallel fan-out
def _routed_streams(n_seqs, block=16, blocks_per_seq=4, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 50000, size=block * blocks_per_seq).tolist() for _ in range(n_seqs)]


@pytest.mark.parametrize("io_threads", [0, 4])
def test_sharded_many_ops_match_serial(tmp_path, io_threads):
    store = ShardedKVBlockStore(
        str(tmp_path / f"s{io_threads}"), n_shards=4, block_size=16, io_threads=io_threads
    )
    seqs = _routed_streams(12)
    blocks = _mk_blocks(4)
    wrote = store.put_many([(t, blocks, 0) for t in seqs])
    assert all(w == 4 for w in wrote)
    probes = store.probe_many(seqs)
    assert probes == [64] * len(seqs)
    got = store.get_many([(t, p) for t, p in zip(seqs, probes)])
    for g in got:
        assert len(g) == 4
        np.testing.assert_allclose(g[0], blocks[0], rtol=0.02, atol=0.05)
    # positional mapping: mutate one sequence, results stay aligned
    assert store.probe_many([seqs[3], [1, 2, 3] * 16, seqs[5]])[1] == 0
    assert store.stats.put_blocks == 4 * len(seqs)
    store.close()


# ---------------------------------------------------------- engine pipeline
def _mk_engine(tmp_path, io_threads, device_blocks=8, host_blocks=8):
    cfg = get_config("glm4-9b")
    rt = RuntimeServices(io_threads=io_threads) if io_threads else None
    store = ShardedKVBlockStore(
        str(tmp_path / f"eng{io_threads}"), n_shards=4, block_size=16, io_threads=io_threads
    )
    h = CacheHierarchy(16, device_blocks, host_blocks, store=store)
    eng = ServingEngine(
        h, ComputeModel(cfg), kv_bytes_per_token=256, max_batch_tokens=1024, runtime=rt
    )
    return eng, store


def test_pipelined_engine_prefetches_and_matches_serial_hits(tmp_path):
    wl_kwargs = dict(
        prompt_len=128, requests_per_stage=12, stages=(0.9,), block_size=16, corpus_size=4, seed=5
    )
    hits = {}
    for io_threads in (0, 4):
        eng, store = _mk_engine(tmp_path, io_threads)
        wl = StagedWorkload(**wl_kwargs)
        for p in wl.warmup_prompts(4 * 128):
            eng.submit(type("R", (), {"tokens": p, "rid": -1, "stage": -1})())
        eng.run()
        eng.drain()  # write-behind settled: both modes start from the same disk state
        recs = []
        for r in wl.stage_requests(0):
            eng.submit(r)
        recs = eng.run()
        eng.drain()
        hits[io_threads] = float(np.mean([r.reused_tokens / r.prompt_len for r in recs]))
        if io_threads:
            assert eng.pipeline
            assert eng.stats.prefetched_requests > 0
            rep = eng.runtime_report()
            assert rep["runtime"]["executor"]["submitted"] > 0
        eng.close()
        store.close()
    # pipelining must not change what the cache returns
    assert hits[4] == pytest.approx(hits[0], abs=0.12)


def test_hedged_fetch_reissued_on_executor(tmp_path):
    """A stalled prefetch future is hedged with a second executor fetch and
    the faster attempt wins."""
    from repro.cache.hierarchy import DiskFetch

    eng, store = _mk_engine(tmp_path, io_threads=2)
    calls = {"n": 0}

    def slow_then_fast(plan):
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.5)
        return DiskFetch(probed_tokens=0, blocks=[], io_s=0.001)

    eng.h.fetch = slow_then_fast
    eng._ewma_read_s = 1e-3  # 0.5s >> 4 x 1ms -> hedge trips
    tokens = list(range(64))
    plan = eng.h.plan(tokens)
    plan.total_blocks = 4  # force need_disk so a future is created
    from repro.serving.engine import _Staged

    fut = eng.runtime.executor.submit(eng.h.fetch, plan)
    fetched, wait_s, hedged = eng._resolve_fetch(_Staged(req=None, plan=plan, future=fut))
    assert hedged
    assert eng.stats.hedged_reads == 1
    assert calls["n"] == 2
    if eng.runtime.executor.max_workers >= 2:
        # with a real second worker the hedge wins; on a 1-core host the
        # CPU cap leaves one worker and the hedge queues behind the
        # straggler — re-issue accounting above is the portable assertion
        assert wait_s < 0.5
    eng.close()
    store.close()
