"""Tests for the KVBlockStore facade, codec, merge service, controller, and
baseline backends (paper §3.2–§3.4, App. B/C)."""

import os
import shutil

import numpy as np
import pytest
from hypothesis_compat import HealthCheck, given, settings, st

from repro.core import (
    CODEC_INT8,
    CODEC_RAW,
    BatchCodec,
    FilePerObjectStore,
    KVBlockStore,
    MemoryOnlyStore,
    ShardedKVBlockStore,
    StorageBackend,
)
from repro.core.baselines import fs_footprint
from repro.core.controller import OP_EMPTY, OP_RANGE, OP_READ, OP_WRITE, AdaptiveController
from repro.core.tiering import TieringPolicy

# The store contract suite runs against both the monolithic LSM store and
# the 4-way sharded store: the sharded backend inherits every behavioral
# guarantee (put/probe/get, crash recovery, budget eviction).
STORE_KINDS = ["lsm", "sharded"]

# ... and across codec policies: the default store-wide int8+zlib codec,
# lossless raw, and the adaptive tiering policy (raw hot puts, demotion
# at the next maintenance cycle) — the contract must hold under each.
CODEC_POLICIES = ["int8-zlib", "raw", "tiered"]


def _policy_kwargs(policy):
    if policy == "raw":
        return {"codec": BatchCodec(CODEC_RAW, use_zlib=False)}
    if policy == "tiered":
        return {"tiering": TieringPolicy(warm_after_s=0.0, cold_after_s=0.0)}
    return {}


def _mk_store(kind, root, **kw):
    if kind == "sharded":
        return ShardedKVBlockStore(root, n_shards=4, **kw)
    return KVBlockStore(root, **kw)


# ------------------------------------------------------------------- codec
@given(
    shape=st.tuples(st.integers(1, 4), st.integers(1, 8), st.integers(1, 16)),
    seed=st.integers(0, 2**31 - 1),
    codec=st.sampled_from([CODEC_RAW, CODEC_INT8]),
    use_zlib=st.booleans(),
)
@settings(max_examples=40, deadline=None)
def test_codec_roundtrip(shape, seed, codec, use_zlib):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32)
    c = BatchCodec(codec, use_zlib=use_zlib)
    y = BatchCodec.decode(c.encode(x))
    assert y.shape == x.shape and y.dtype == x.dtype
    if codec == CODEC_RAW:
        np.testing.assert_array_equal(x, y)
    else:
        # int8 per-channel: error bounded by scale/2 = absmax/254 per channel
        absmax = np.abs(x).reshape(-1, shape[-1]).max(axis=0)
        bound = absmax / 254 + 1e-7
        assert (np.abs(x - y).reshape(-1, shape[-1]).max(axis=0) <= bound + 1e-6).all()


def test_codec_bf16_and_compression():
    import ml_dtypes

    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 64, 128)).astype(ml_dtypes.bfloat16)
    c = BatchCodec(CODEC_INT8, use_zlib=True)
    enc = c.encode(x)
    y = BatchCodec.decode(enc)
    assert y.dtype == x.dtype and y.shape == x.shape
    # paper §3.4 cites 50-75% reduction; int8 alone is 50% vs bf16
    assert len(enc) < x.nbytes * 0.75


# ------------------------------------------------------------------- store
def _mk_blocks(rng, n, block, kvdim=(2, 4)):
    return [rng.standard_normal((kvdim[0], block, kvdim[1]), dtype=np.float32) for _ in range(n)]


@pytest.fixture(params=[(k, p) for k in STORE_KINDS for p in CODEC_POLICIES],
                ids=lambda kp: f"{kp[0]}-{kp[1]}")
def store(tmp_path, request):
    kind, policy = request.param
    s = _mk_store(kind, str(tmp_path / "kvs"), block_size=4, buffer_bytes=4096,
                  **_policy_kwargs(policy))
    yield s
    s.close()


def test_probe_get_put_roundtrip(store):
    rng = np.random.default_rng(0)
    tokens = list(range(10, 42))  # 32 tokens, 8 blocks
    blocks = _mk_blocks(rng, 8, 4)
    assert store.put_batch(tokens, blocks) == 8
    assert store.probe(tokens) == 32
    got = store.get_batch(tokens, 32)
    assert len(got) == 8
    for g, b in zip(got, blocks):
        np.testing.assert_allclose(g, b, atol=np.abs(b).max() / 100)


def test_probe_partial_prefix(store):
    rng = np.random.default_rng(1)
    tokens = list(range(100, 132))
    store.put_batch(tokens, _mk_blocks(rng, 8, 4))
    # diverging continuation after 16 tokens
    other = tokens[:16] + [9999] * 16
    assert store.probe(other) == 16
    assert len(store.get_batch(other, 16)) == 4
    # completely cold request
    assert store.probe([1, 2, 3, 4, 5, 6, 7, 8]) == 0
    assert store.stats.probe_empty >= 1


def test_probe_never_overreports_after_eviction_hole(store):
    """FIFO file eviction tombstones whole files regardless of prefix
    position; probe must report only the contiguous prefix get_batch can
    actually return (regression: binary search alone over-reported)."""
    rng = np.random.default_rng(11)
    tokens = list(range(500, 532))  # 8 blocks of 4
    blocks = _mk_blocks(rng, 8, 4)
    target = store.shard_for(tokens) if isinstance(store, ShardedKVBlockStore) else store
    # write block 3 alone into the first log file, then seal it so the
    # eviction below removes exactly that mid-prefix block
    store.put_batch(tokens, [blocks[2]], start_block=2)
    target.log._files[target.log._active_id]["size"] = target.log.max_file_bytes
    target.log._open_active()  # rotate: block 3's file is now the oldest
    store.put_batch(tokens, blocks[:2], start_block=0)
    store.put_batch(tokens, blocks[3:], start_block=3)
    assert store.probe(tokens) == 32
    assert target.evict_oldest_file()  # real eviction path: hole at block 3
    n = store.probe(tokens)
    got = store.get_batch(tokens, 32)
    assert n == len(got) * 4 == 8  # promises exactly what get_batch delivers


def test_backends_satisfy_storage_protocol(tmp_path):
    backends = [
        KVBlockStore(str(tmp_path / "a"), block_size=4),
        ShardedKVBlockStore(str(tmp_path / "b"), n_shards=2, block_size=4),
        FilePerObjectStore(str(tmp_path / "c"), block_size=4),
        MemoryOnlyStore(budget_bytes=1 << 20, block_size=4),
    ]
    for b in backends:
        assert isinstance(b, StorageBackend), b
        b.close()


def test_put_skips_existing(store):
    rng = np.random.default_rng(2)
    tokens = list(range(200, 216))
    blocks = _mk_blocks(rng, 4, 4)
    assert store.put_batch(tokens, blocks) == 4
    assert store.put_batch(tokens, blocks) == 0  # dedup
    # extension writes only new blocks
    ext = tokens + [7, 8, 9, 10]
    assert store.put_batch(ext, _mk_blocks(rng, 5, 4)) == 1


@given(seed=st.integers(0, 1000), nseq=st.integers(1, 12))
@settings(max_examples=10, deadline=None, suppress_health_check=[HealthCheck.function_scoped_fixture])
def test_store_matches_oracle(tmp_path_factory, seed, nseq):
    """Property: probe == longest stored prefix; get_batch returns exactly
    the stored arrays (modulo int8 codec error)."""
    root = str(tmp_path_factory.mktemp("kvs"))
    B = 4
    s = KVBlockStore(root, block_size=B, buffer_bytes=2048, codec=BatchCodec(CODEC_RAW, use_zlib=True))
    rng = np.random.default_rng(seed)
    oracle = {}  # key bytes -> array
    seqs = []
    for _ in range(nseq):
        # build sequences sharing random prefixes to exercise the radix keyspace
        if seqs and rng.random() < 0.5:
            parent = seqs[rng.integers(0, len(seqs))]
            cut = int(rng.integers(0, len(parent) // B)) * B
            toks = parent[:cut] + [int(x) for x in rng.integers(0, 50, int(rng.integers(1, 5)) * B)]
        else:
            toks = [int(x) for x in rng.integers(0, 50, int(rng.integers(1, 6)) * B)]
        blocks = _mk_blocks(rng, len(toks) // B, B)
        s.put_batch(toks, blocks)
        for i in range(len(toks) // B):
            # first-write-wins, matching skip_existing dedup (KV content for
            # an identical token prefix is identical in a real serving stack)
            oracle.setdefault(tuple(toks[: (i + 1) * B]), blocks[i])
        seqs.append(toks)
        s.maintenance(compact_steps=2)
    for toks in seqs:
        n = s.probe(toks)
        # oracle longest prefix
        want = 0
        for i in range(len(toks) // B, 0, -1):
            if tuple(toks[: i * B]) in oracle:
                want = i * B
                break
        assert n == want
        got = s.get_batch(toks, n)
        assert len(got) == n // B
        for i, g in enumerate(got):
            np.testing.assert_array_equal(g, oracle[tuple(toks[: (i + 1) * B])])
    s.close()


@pytest.mark.parametrize("kind", STORE_KINDS)
def test_store_crash_recovery(tmp_path, kind):
    root = str(tmp_path / "kvs")
    s = _mk_store(kind, root, block_size=4, buffer_bytes=1 << 20, fsync=False)
    rng = np.random.default_rng(3)
    tokens = list(range(300, 332))
    blocks = _mk_blocks(rng, 8, 4)
    s.put_batch(tokens, blocks)
    s.sync_wal()
    del s  # crash: no close, memtable never flushed to SST
    s2 = _mk_store(kind, root, block_size=4, buffer_bytes=1 << 20)
    assert s2.probe(tokens) == 32
    got = s2.get_batch(tokens, 32)
    assert len(got) == 8
    s2.close()


def test_two_phase_write_orphan_is_garbage_collected(tmp_path):
    """Crash between tensor-log append and index insert leaves an orphan log
    record; the merge service must reclaim it."""
    root = str(tmp_path / "kvs")
    s = KVBlockStore(root, block_size=4, buffer_bytes=4096, max_log_files=1, garbage_threshold=0.1)
    rng = np.random.default_rng(4)
    tokens = list(range(400, 416))
    s.put_batch(tokens, _mk_blocks(rng, 4, 4))
    # orphan record: phase-1 only (no index entry)
    s.log.append(b"\x00\x00\x00\x99", b"orphan-payload" * 100)
    # force rotation so the orphan's file becomes a merge candidate
    orphan_file = s.log._active_id
    s.log._files[orphan_file]["size"] = s.log.max_file_bytes
    s.log._open_active()  # rotates: orphan's file is no longer active
    before = s.log.file_count
    s.maintenance()
    assert orphan_file not in s.log.file_ids()  # orphan's file reclaimed
    assert s.log.file_count <= before
    assert s.probe(tokens) == 16  # live data survived the merge
    assert len(s.get_batch(tokens, 16)) == 4
    s.close()


def test_tensor_file_merging_bounds_file_count(tmp_path):
    s = KVBlockStore(
        str(tmp_path / "kvs"), block_size=4, buffer_bytes=1 << 20,
        vlog_file_bytes=4096, max_log_files=3,
    )
    rng = np.random.default_rng(5)
    for i in range(30):
        toks = [int(x) for x in rng.integers(0, 10000, 16)]
        s.put_batch(toks, _mk_blocks(rng, 4, 4))
        s.maintenance()
    assert s.log.file_count <= 4  # threshold + active file
    s.close()


@pytest.mark.parametrize("kind", STORE_KINDS)
def test_budget_eviction(tmp_path, kind):
    s = _mk_store(
        kind, str(tmp_path / "kvs"), block_size=4, buffer_bytes=8192,
        vlog_file_bytes=8192, budget_bytes=100_000,
    )
    rng = np.random.default_rng(6)
    for i in range(60):
        toks = [int(x) for x in rng.integers(0, 100000, 32)]
        s.put_batch(toks, _mk_blocks(rng, 8, 4, kvdim=(2, 16)))
        s.maintenance()
    assert s.disk_bytes <= 150_000  # budget enforced (active file slack)
    assert s.stats.evicted_blocks > 0
    s.close()


# -------------------------------------------------------------- controller
class _FakeLSM:
    buffer_bytes = 1 << 20
    n_entries = 1_000_000

    def __init__(self):
        self.targets = []

    def set_targets(self, T, K):
        self.targets.append((T, K))


def test_controller_adapts_to_phase_shift():
    lsm = _FakeLSM()
    c = AdaptiveController(lsm, window=512, min_ops_between_tunings=128, threshold=0.1)
    for _ in range(600):
        c.record(OP_WRITE)
    assert lsm.targets, "controller never tuned"
    k_write = lsm.targets[-1][1]
    for _ in range(900):
        c.record(OP_READ)
        c.record(OP_RANGE)
    k_read = lsm.targets[-1][1]
    assert k_write > k_read  # write phase -> tiering-like, read -> leveling
    assert k_read == 1


def test_controller_window_slides():
    lsm = _FakeLSM()
    c = AdaptiveController(lsm, window=100, min_ops_between_tunings=10**9)
    for _ in range(150):
        c.record(OP_WRITE)
    for _ in range(100):
        c.record(OP_EMPTY)
    mix = c.mix()
    assert mix[OP_EMPTY] == 1.0 and mix[OP_WRITE] == 0.0  # old ops aged out


def test_store_controller_integration(tmp_path):
    """End-to-end: write-heavy phase then read-heavy phase actually moves the
    LSM targets (Fig. 5c mechanism)."""
    s = KVBlockStore(
        str(tmp_path / "kvs"), block_size=4, buffer_bytes=2048,
        controller_window=256, adaptive=True,
    )
    s.controller.min_ops_between_tunings = 64
    rng = np.random.default_rng(7)
    seqs = []
    for i in range(40):
        toks = [int(x) for x in rng.integers(0, 1000, 16)]
        s.put_batch(toks, _mk_blocks(rng, 4, 4))
        seqs.append(toks)
    k_after_writes = s.index.target_K
    for _ in range(15):
        for toks in seqs:
            n = s.probe(toks)
            if n:
                s.get_batch(toks, n)
    assert s.index.target_K <= k_after_writes
    assert s.index.target_K == 1
    assert len(s.controller.history) >= 2
    s.close()


# --------------------------------------------------------------- baselines
def test_file_backend_fs_overhead_vs_lsm(tmp_path):
    """Same payloads: file-per-object must cost strictly more physical bytes
    (block rounding + inode) — the mechanism behind the paper's hit-rate
    gap under a shared budget."""
    rng = np.random.default_rng(8)
    B = 4
    lsm = KVBlockStore(str(tmp_path / "lsm"), block_size=B, buffer_bytes=1 << 20)
    fb = FilePerObjectStore(str(tmp_path / "file"), block_size=B)
    for i in range(20):
        toks = [int(x) for x in rng.integers(0, 5000, 16)]
        blocks = _mk_blocks(rng, 4, B)
        lsm.put_batch(toks, blocks)
        fb.put_batch(toks, blocks)
    lsm.flush()
    assert fb.disk_bytes > 2 * lsm.disk_bytes
    lsm.close()


def test_file_backend_max_files_wall(tmp_path):
    fb = FilePerObjectStore(str(tmp_path / "file"), block_size=4, max_files=10)
    rng = np.random.default_rng(9)
    for i in range(10):
        toks = [int(x) for x in rng.integers(0, 5000, 8)]
        fb.put_batch(toks, _mk_blocks(rng, 2, 4))
    assert fb.file_count <= 10  # writes refused past the wall (§4.2)


def test_memory_store_lru_eviction():
    mb = MemoryOnlyStore(budget_bytes=300, block_size=4)  # ~4 64B blocks
    rng = np.random.default_rng(10)
    t1 = list(range(0, 16))
    t2 = list(range(100, 116))
    mb.put_batch(t1, _mk_blocks(rng, 4, 4, kvdim=(1, 4)))
    mb.put_batch(t2, _mk_blocks(rng, 4, 4, kvdim=(1, 4)))
    assert mb.probe(t2) == 16  # newest survives
    assert mb.probe(t1) < 16  # oldest evicted
    assert mb.stats.evicted_blocks > 0


def test_fs_footprint():
    assert fs_footprint(1) == 4096 + 256
    assert fs_footprint(4096) == 4096 + 256
    assert fs_footprint(4097) == 8192 + 256
