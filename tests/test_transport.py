"""Multiplexed streaming transport: the properties the mux rewrite must
hold under fire.

* out-of-order completion — a slow request must not head-of-line block a
  fast one sharing the connection,
* independent streams — one node stalling its stream must not stall a
  stream from another node on the same ``MuxLoop``,
* truncated mid-chunk frames -> ``NodeUnavailable`` (transport error),
  while malformed-but-whole bodies -> ``ProtocolError`` with **zero**
  retries and a connection that stays usable,
* mid-stream node death -> replica failover that stitches the exact
  block sequence, and — at the hierarchy level — a partial stream is
  committed only as the prefix that actually arrived,
* the sendfile zero-copy path serves bit-identical payloads.

Fake nodes are raw listening sockets speaking just enough of the frame
protocol to inject the failure; real ``CacheNodeServer``s cover the
honest paths.
"""

import threading
import time

import numpy as np
import pytest
from cluster_harness import B, FakeNode as _FakeNode
from cluster_harness import blocks as _blocks
from cluster_harness import mux_frame as _mux_frame
from cluster_harness import seq as _seq

from repro.cache.hierarchy import CacheHierarchy
from repro.cluster import (
    CacheNodeServer,
    ClusterKVBlockStore,
    NodeUnavailable,
    RemoteKVBlockStore,
)
from repro.cluster import protocol as P
from repro.core.baselines import MemoryOnlyStore
from repro.core.store import KVBlockStore


# ===================================================== out-of-order muxing
class _SlowFirstStore(MemoryOnlyStore):
    """Marks the FIRST get slow: it must not delay a later fast get that
    shares the connection."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.slow_done = threading.Event()
        self._first = True

    def get_batch(self, tokens, n_tokens):
        if self._first:
            self._first = False
            time.sleep(0.4)
            self.slow_done.set()
        return super().get_batch(tokens, n_tokens)


def test_responses_interleave_out_of_order_on_one_connection():
    store = _SlowFirstStore(1 << 24, block_size=B)
    rng = np.random.default_rng(0)
    slow_toks, fast_toks = _seq(rng, 2), _seq(rng, 2)
    with CacheNodeServer(store, io_threads=2) as srv:
        remote = RemoteKVBlockStore(srv.address, retries=0)
        remote.put_batch(slow_toks, _blocks(rng, 2))
        remote.put_batch(fast_toks, _blocks(rng, 2))
        store._first = True  # arm the slow path for the race below
        done = {}

        def get(name, toks):
            got = remote.get_batch(toks, 2 * B)
            done[name] = (time.perf_counter(), len(got))

        t_slow = threading.Thread(target=get, args=("slow", slow_toks))
        t_slow.start()
        time.sleep(0.05)  # slow get is in flight on the shared connection
        get("fast", fast_toks)
        t_slow.join()
        assert done["slow"][1] == done["fast"][1] == 2
        # the fast response overtook the slow one on the same socket
        assert done["fast"][0] < done["slow"][0]
        assert store.slow_done.is_set()
        assert remote.rpc_stats.retries == 0
        remote.close()


def test_one_stalled_stream_does_not_stall_another_node():
    """Two node clients on one shared MuxLoop: a node sleeping mid-stream
    must not delay another node's stream (the loop thread never decodes)."""
    from repro.cluster import MuxLoop

    class _StallStore(MemoryOnlyStore):
        def get_batch(self, tokens, n_tokens):
            time.sleep(0.5)
            return super().get_batch(tokens, n_tokens)

    rng = np.random.default_rng(1)
    toks = _seq(rng, 2)
    blocks = _blocks(rng, 2)
    loop = MuxLoop()
    slow_store = _StallStore(1 << 24, block_size=B)
    fast_store = MemoryOnlyStore(1 << 24, block_size=B)
    with CacheNodeServer(slow_store, io_threads=1) as slow_srv, CacheNodeServer(
        fast_store, io_threads=1
    ) as fast_srv:
        slow = RemoteKVBlockStore(slow_srv.address, mux_loop=loop, retries=0)
        fast = RemoteKVBlockStore(fast_srv.address, mux_loop=loop, retries=0)
        MemoryOnlyStore.put_batch(slow_store, toks, blocks)  # skip the stall
        fast.put_batch(toks, blocks)
        t0 = time.perf_counter()
        results = {}

        def drain(name, client):
            results[name] = (list(client.get_batch_stream(toks, 2 * B)),
                             time.perf_counter() - t0)

        ts = threading.Thread(target=drain, args=("slow", slow))
        ts.start()
        time.sleep(0.05)
        drain("fast", fast)
        ts.join()
        assert len(results["fast"][0]) == len(results["slow"][0]) == 2
        assert results["fast"][1] < 0.4 < results["slow"][1]
        slow.close()
        fast.close()
    loop.close()


# ================================================== error taxonomy on wire
def test_truncated_mid_chunk_frame_raises_node_unavailable():
    """A stream that dies inside a chunk is a *transport* failure: the
    client yields the blocks that arrived whole, then raises
    NodeUnavailable (the failover signal) — never a hang, never a retry
    that would silently re-pull the prefix."""
    rng = np.random.default_rng(2)
    blocks = _blocks(rng, 2)

    def handler(conn, rid, op, args):
        if op == P.OP_STATS:
            return _mux_frame(rid, P.KIND_RESPONSE,
                              [P.encode_ok(op, {"name": "fake", "block_size": B,
                                                "stats": {}})])
        assert op == P.OP_GET_STREAM
        conn.sendall(_mux_frame(rid, P.KIND_CHUNK,
                                P.encode_stream_chunk(0, 0, [blocks[0]])))
        # second chunk: advertise a length, deliver half, die
        whole = _mux_frame(rid, P.KIND_CHUNK,
                           P.encode_stream_chunk(0, 1, [blocks[1]]))
        conn.sendall(whole[: len(whole) // 2])
        return None  # close mid-frame

    fake = _FakeNode(handler)
    try:
        remote = RemoteKVBlockStore(fake.address, retries=2, timeout_s=5.0)
        got = []
        with pytest.raises(NodeUnavailable):
            for b in remote.get_batch_stream([1, 2, 3, 4], 2 * B):
                got.append(b)
        assert len(got) == 1 and np.array_equal(got[0], blocks[0])
        remote.close()
    finally:
        fake.close()


def test_malformed_body_raises_protocol_error_without_retry():
    """A whole-but-garbage RESPONSE body is an application error: raised
    immediately (zero retries — retrying corruption hides bugs) and the
    connection survives for the next call."""
    calls = {"n": 0}

    def handler(conn, rid, op, args):
        if op == P.OP_STATS:
            return _mux_frame(rid, P.KIND_RESPONSE,
                              [P.encode_ok(op, {"name": "fake", "block_size": B,
                                                "stats": {}})])
        calls["n"] += 1
        if calls["n"] == 1:
            return _mux_frame(rid, P.KIND_RESPONSE, [b"\x63garbage-not-a-response"])
        return _mux_frame(rid, P.KIND_RESPONSE, [P.encode_ok(P.OP_PROBE, 8)])

    fake = _FakeNode(handler)
    try:
        remote = RemoteKVBlockStore(fake.address, retries=2, timeout_s=5.0)
        with pytest.raises(P.ProtocolError):
            remote.probe([1, 2, 3, 4])
        assert remote.rpc_stats.retries == 0
        assert remote.rpc_stats.connects == 1
        # same connection answers the next call (not poisoned, not redialed)
        assert remote.probe([1, 2, 3, 4]) == 8
        assert remote.rpc_stats.connects == 1
        remote.close()
    finally:
        fake.close()


# ===================================================== mid-stream failover
def test_mid_stream_death_fails_over_and_stitches_exact_blocks():
    """R=2: the primary dies after streaming one block; the cluster
    stream resumes from the replica, skipping what was already yielded —
    the stitched sequence is bit-identical to the committed blocks."""
    rng = np.random.default_rng(3)
    n_blocks = 4
    blocks = _blocks(rng, n_blocks)

    def dying_handler(conn, rid, op, args):
        if op == P.OP_STATS:
            return _mux_frame(rid, P.KIND_RESPONSE,
                              [P.encode_ok(op, {"name": "fake", "block_size": B,
                                                "stats": {}})])
        if op == P.OP_GET_STREAM:
            conn.sendall(_mux_frame(rid, P.KIND_CHUNK,
                                    P.encode_stream_chunk(0, 0, blocks[:1])))
            return None  # die mid-stream
        if op == P.OP_PING:
            return None  # stay "down" for refresh_nodes
        return _mux_frame(rid, P.KIND_RESPONSE, [P.encode_error("unsupported")])

    fake = _FakeNode(dying_handler)
    healthy = CacheNodeServer(MemoryOnlyStore(1 << 24, block_size=B), io_threads=1).start()
    try:
        cluster = ClusterKVBlockStore(
            [fake.address, healthy.address], replication=2, block_size=B,
            retries=0, connect_timeout_s=2.0,
        )
        # find tokens whose primary is the fake node
        toks = None
        for _ in range(200):
            cand = _seq(rng, n_blocks)
            if cluster.replicas_for(cand)[0] == 0:
                toks = cand
                break
        assert toks is not None
        healthy.backend.put_batch(toks, blocks)  # replica holds the data

        stream = cluster.get_batch_stream(toks, n_blocks * B)
        got = list(stream)
        assert len(got) == n_blocks
        assert all(np.array_equal(a, b) for a, b in zip(got, blocks))
        assert stream.failovers == 1
        assert stream.first_block_s is not None
        assert cluster.cluster_stats.failovers >= 1
        assert 0 in cluster.down_nodes  # the dead primary was marked down
        cluster.close()
    finally:
        healthy.close()
        fake.close()


def test_partial_stream_commits_only_the_arrived_prefix():
    """Hierarchy-level guarantee: when every replica dies mid-stream, the
    fetch truncates and fulfill installs exactly the blocks that arrived
    — a partial batch is a shorter hit, never a hole or a phantom."""

    class _DyingStreamStore:
        """Single 'node' whose stream always dies after 2 blocks."""

        block_size = B

        def __init__(self, blocks):
            self._blocks = blocks

        def probe(self, tokens):
            return len(self._blocks) * B  # promises all 4

        def get_batch_stream(self, tokens, n_tokens):
            def gen():
                yield self._blocks[0]
                yield self._blocks[1]
                raise NodeUnavailable("replicas exhausted")

            return gen()

        def get_batch(self, tokens, n_tokens):  # pragma: no cover - not used
            raise AssertionError("streaming path must be taken")

        def put_batch(self, tokens, blocks, start_block=0, skip_existing=True):
            return 0

    rng = np.random.default_rng(4)
    blocks = _blocks(rng, 4)
    h = CacheHierarchy(B, device_budget_blocks=16, host_budget_blocks=16,
                       store=_DyingStreamStore(blocks))
    toks = _seq(rng, 4)
    plan = h.plan(toks)
    fetched = h.fetch(plan)
    assert fetched.first_block_s is not None  # block 0 arrived at fetch time
    acq = h.fulfill(plan, fetched)
    assert acq.reuse_tokens == 2 * B  # exactly the arrived prefix
    assert acq.disk_tokens == 2 * B
    assert all(n.data is not None for n in acq.nodes)
    assert h.stats.streamed_fetches == 1
    h.release(acq)


# ======================================================== zero-copy serving
@pytest.mark.parametrize("policy", ["raw", "int8-zlib", "tiered"])
def test_sendfile_stream_matches_buffered_stream(tmp_path, policy):
    """The sendfile fast path must be invisible to the client — under
    every codec policy: bytes off the zero-copy stream equal the buffered
    path's (which for compressed stores ships still-encoded payloads),
    and the server accounts the raw extents it shipped.  For ``tiered``
    the store is demoted to the cold tier first, so the wire carries
    int8+zlib payloads both ways."""
    from repro.core.codec import CODEC_INT8, CODEC_RAW, BatchCodec
    from repro.core.tiering import TieringPolicy

    rng = np.random.default_rng(5)
    toks = _seq(rng, 4)
    blocks = _blocks(rng, 4)
    kwargs = {
        "raw": {"codec": BatchCodec(CODEC_RAW, use_zlib=False)},
        "int8-zlib": {"codec": BatchCodec(CODEC_INT8, use_zlib=True)},
        # small log roll: puts land in sealed files the recoder can demote
        "tiered": {"tiering": TieringPolicy(warm_after_s=0.0, cold_after_s=0.0),
                   "vlog_file_bytes": 256},
    }[policy]

    def fill(root):
        store = KVBlockStore(root, block_size=B, buffer_bytes=256, **kwargs)
        if policy == "tiered":
            # one put per block: the log rolls between appends, sealing
            # files the recoder can demote (a single batch stays active)
            for i, blk in enumerate(blocks):
                store.put_batch(toks[: (i + 1) * B], [blk], start_block=i)
            store.flush()
            for _ in range(8):
                rep = store.maintenance()
                if not (rep.get("tiering") or {}).get("demoted_blocks"):
                    break
            assert store.stats.tier_cold_blocks > 0
        else:
            store.put_batch(toks, blocks)
            store.flush()
        return store

    def check(got, want):
        if policy == "raw":
            assert np.array_equal(got, want) and got.dtype == want.dtype
        else:  # int8 per-channel quantization error bound
            np.testing.assert_allclose(got, want, atol=0.05, rtol=0.05)

    with CacheNodeServer(fill(str(tmp_path / "zc")), io_threads=1,
                         zero_copy=True) as zc_srv, CacheNodeServer(
        fill(str(tmp_path / "buf")), io_threads=1, zero_copy=False
    ) as buf_srv:
        zc = RemoteKVBlockStore(zc_srv.address, retries=0)
        buf = RemoteKVBlockStore(buf_srv.address, retries=0)
        got_zc = list(zc.get_batch_stream(toks, 4 * B))
        got_buf = list(buf.get_batch_stream(toks, 4 * B))
        assert len(got_zc) == len(got_buf) == 4
        for a, b, want in zip(got_zc, got_buf, blocks):
            check(a, want)
            check(b, want)
            assert np.array_equal(a, b)  # paths decode identical payloads
        assert zc_srv.stats.sendfile_bytes > 0
        assert zc_srv.stats.raw_extents > 0
        assert buf_srv.stats.sendfile_bytes == 0
        zc.close()
        buf.close()


def test_compressed_mid_stream_failover_stitches_within_quant_bound(tmp_path):
    """R=2 with compressed payloads on the wire: the primary dies after
    one LAYOUT_ENCODED chunk, the stream resumes from a real int8+zlib
    replica, and the stitched blocks all decode within the quantization
    bound — failover must work when what crosses the wire is compressed
    bytes, not decoded tensors."""
    from repro.core.codec import CODEC_INT8, BatchCodec

    rng = np.random.default_rng(6)
    n_blocks = 4
    blocks = _blocks(rng, n_blocks)
    codec = BatchCodec(CODEC_INT8, use_zlib=True)

    def dying_handler(conn, rid, op, args):
        if op == P.OP_STATS:
            return _mux_frame(rid, P.KIND_RESPONSE,
                              [P.encode_ok(op, {"name": "fake", "block_size": B,
                                                "stats": {}})])
        if op == P.OP_GET_STREAM:
            # one compressed chunk (layout 3: still-encoded payloads)...
            conn.sendall(_mux_frame(
                rid, P.KIND_CHUNK,
                P.encode_stream_chunk(0, 0, [codec.encode(blocks[0])])))
            return None  # ... then die mid-stream
        if op == P.OP_PING:
            return None
        return _mux_frame(rid, P.KIND_RESPONSE, [P.encode_error("unsupported")])

    fake = _FakeNode(dying_handler)
    replica_store = KVBlockStore(str(tmp_path / "replica"), block_size=B,
                                 codec=codec)
    healthy = CacheNodeServer(replica_store, io_threads=1).start()
    try:
        cluster = ClusterKVBlockStore(
            [fake.address, healthy.address], replication=2, block_size=B,
            retries=0, connect_timeout_s=2.0,
        )
        toks = None
        for _ in range(200):
            cand = _seq(rng, n_blocks)
            if cluster.replicas_for(cand)[0] == 0:
                toks = cand
                break
        assert toks is not None
        replica_store.put_batch(toks, blocks)
        replica_store.flush()

        stream = cluster.get_batch_stream(toks, n_blocks * B)
        got = list(stream)
        assert len(got) == n_blocks
        for want, have in zip(blocks, got):
            np.testing.assert_allclose(have, want, atol=0.05, rtol=0.05)
        assert stream.failovers == 1
        assert 0 in cluster.down_nodes
        cluster.close()
    finally:
        healthy.close()
        fake.close()
