"""Two-phase write durability: fsync ordering (tensor log before the
WAL-backed index commit) and crash recovery between the phases (§3.2 —
the merge service garbage-collects unreferenced log records)."""

import os

import numpy as np
import pytest

from repro.core.codec import CODEC_RAW, BatchCodec
from repro.core.sharded_store import ShardedKVBlockStore
from repro.core.store import KVBlockStore

B = 16


def _blocks(n, seed=0, width=16):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((B, width)).astype(np.float16) for _ in range(n)]


def _fd_path(fd: int) -> str:
    try:
        return os.readlink(f"/proc/self/fd/{fd}")
    except OSError:  # pragma: no cover — non-procfs platforms
        return f"fd:{fd}"


def test_fsync_orders_log_before_index_commit(tmp_path, monkeypatch):
    """With fsync_writes on, the tensor-log append must be durable before
    the index insert's WAL sync — the ordering the §3.2 crash argument
    (only *unreferenced* records can be orphaned) depends on."""
    synced = []
    real_fsync = os.fsync

    def recording_fsync(fd):
        synced.append(_fd_path(fd))
        return real_fsync(fd)

    monkeypatch.setattr(os, "fsync", recording_fsync)
    store = KVBlockStore(str(tmp_path / "s"), block_size=B, fsync_writes=True)
    synced.clear()
    tokens = list(range(2 * B))
    assert store.put_batch(tokens, _blocks(2)) == 2

    vlog_syncs = [i for i, p in enumerate(synced) if "vlog_" in p]
    wal_syncs = [i for i, p in enumerate(synced) if p.endswith("wal.log")]
    assert vlog_syncs, f"tensor log never fsynced: {synced}"
    assert wal_syncs, f"index WAL never fsynced: {synced}"
    assert max(vlog_syncs) < min(wal_syncs), (
        f"durability ordering violated: WAL commit before log sync in {synced}"
    )
    store.close()


def test_fsync_writes_plumbs_through_sharded_store(tmp_path):
    store = ShardedKVBlockStore(
        str(tmp_path / "s"), n_shards=2, block_size=B, fsync_writes=True
    )
    assert store.fsync_writes
    for shard in store.shards:
        assert shard.fsync_writes
        assert shard.log.fsync_writes
        assert shard.index.fsync
    store.close()
    # default stays off (benchmarks measure non-durable ingest)
    store2 = ShardedKVBlockStore(str(tmp_path / "s2"), n_shards=2, block_size=B)
    assert not store2.shards[0].fsync_writes
    store2.close()


def _mk_store(root) -> KVBlockStore:
    return KVBlockStore(
        str(root),
        block_size=B,
        codec=BatchCodec(CODEC_RAW, use_zlib=False),
        fsync_writes=True,
        vlog_file_bytes=8 * 1024,  # small files => quick rotation
    )


def test_crash_between_append_and_index_insert_is_gcd(tmp_path):
    """Kill the store after the tensor-log append but before the index
    insert; on reopen the orphaned record is unreferenced, and the merge
    service garbage-collects it while preserving every committed block."""
    root = tmp_path / "s"
    store = _mk_store(root)
    committed = [list(range(i * 100, i * 100 + 2 * B)) for i in range(6)]
    for i, tokens in enumerate(committed):
        assert store.put_batch(tokens, _blocks(2, seed=i)) == 2

    # crash window: phase 1 (log append) succeeds, phase 2 (index) never runs
    crash_tokens = list(range(9000, 9000 + 2 * B))

    def crash(items):
        raise RuntimeError("simulated crash before index insert")

    store.index.put_batch = crash
    with pytest.raises(RuntimeError, match="simulated crash"):
        store.put_batch(crash_tokens, _blocks(2, seed=99))
    del store  # no close(): the crash killed the process

    # ---- recovery
    store = _mk_store(root)
    assert store.probe(crash_tokens) == 0  # never committed
    for i, tokens in enumerate(committed):
        assert store.probe(tokens) == 2 * B  # durable (WAL + fsync ordering)

    # post-recovery traffic rolls the active log file so the orphan sits in
    # a sealed file (the merger never touches the active one)
    post = [list(range(20000 + i * 100, 20000 + i * 100 + 2 * B)) for i in range(8)]
    for i, tokens in enumerate(post):
        assert store.put_batch(tokens, _blocks(2, seed=200 + i)) == 2
    assert store.log.file_count > 1

    # count live records referencing the orphan payloads: none may be indexed
    orphan_keys = set()
    for fid in store.log.file_ids():
        for _ptr, key, _payload in store.log.scan_file(fid):
            found, _ = store.index.get(key)
            if not found:
                orphan_keys.add(key)
    assert orphan_keys, "crash left no orphan to collect (test setup broken)"

    # ---- merge service GC: apply file-count pressure so every sealed file
    # (the orphan's included — it predates the post-recovery traffic, so it
    # is among the oldest) cycles through the merger.  Live records are
    # re-appended; the unreferenced orphan is dropped on the floor.
    store.merger.max_files = 2
    live_bytes_before_gc = store.log.total_bytes
    for _ in range(16):
        if not store.merger.needed():
            break
        store.maintenance()

    def keys_on_disk():
        return {
            key
            for fid in store.log.file_ids()
            for _ptr, key, _payload in store.log.scan_file(fid)
        }

    assert not (orphan_keys & keys_on_disk()), "orphaned records survived the merge GC"
    assert store.log.total_bytes < live_bytes_before_gc  # orphan bytes reclaimed

    # committed data still fully readable after GC relocation
    for i, tokens in enumerate(committed):
        got = store.get_batch(tokens, store.probe(tokens))
        assert len(got) == 2
        np.testing.assert_array_equal(got[0], _blocks(2, seed=i)[0])
    store.close()
