"""Observability layer: concurrent metric correctness, histogram bucket
properties, exposition well-formedness, and cross-process trace
propagation over a real spawned node."""

import threading
import urllib.request

import pytest

from repro.obs import (MetricsRegistry, TraceContext, activate,
                       current_trace, maybe_span, render_prometheus)
from repro.obs.metrics import Histogram
from repro.obs.tracing import TRACE_ID_BYTES


# ------------------------------------------------------------- concurrency
def test_concurrent_counters_exact():
    """8 threads hammer one counter, one gauge, and one histogram; totals
    must be exact — a lost update is a data race in the striped locks."""
    reg = MetricsRegistry()
    n_threads, n_iter = 8, 2_000
    c = reg.counter("repro_test_hits_total")
    g = reg.gauge("repro_test_depth")
    h = reg.histogram("repro_test_latency_seconds")
    barrier = threading.Barrier(n_threads)

    def hammer(tid: int):
        barrier.wait()
        for i in range(n_iter):
            c.inc()
            g.inc(2.0)
            g.dec(1.0)
            h.observe(1e-5 * (1 + (i + tid) % 7))

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    total = n_threads * n_iter
    assert c.value == total
    assert g.value == total  # +2 -1 per iteration
    snap = h.snapshot()
    assert snap["count"] == total
    assert snap["buckets"][-1][1] == total  # +Inf bucket is cumulative total


def test_concurrent_get_or_create_same_instrument():
    """Racing get-or-create must converge on one instrument per name."""
    reg = MetricsRegistry()
    got = []
    barrier = threading.Barrier(8)

    def create():
        barrier.wait()
        got.append(reg.counter("repro_test_races_total"))

    threads = [threading.Thread(target=create) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(c is got[0] for c in got)
    got[0].inc()
    assert reg.snapshot()["counters"]["repro_test_races_total"] == 1.0


# -------------------------------------------------------------- histograms
def test_histogram_bucket_boundaries():
    """le semantics: a value exactly on a bound lands in that bound's
    bucket; one ulp above goes to the next; above the top bound -> +Inf."""
    h = Histogram("repro_test_h", start=1e-3, factor=2.0, buckets=4)
    bounds = h.bounds
    assert bounds == (1e-3, 2e-3, 4e-3, 8e-3)
    assert h.bucket_index(1e-3) == 0  # v <= le inclusive
    assert h.bucket_index(1e-3 * 1.0000001) == 1
    assert h.bucket_index(2e-3) == 1
    assert h.bucket_index(5e-3) == 3
    assert h.bucket_index(8e-3) == 3
    assert h.bucket_index(9e-3) == 4  # +Inf slot
    assert h.bucket_index(0.0) == 0


def test_histogram_quantiles_bounded_by_observations():
    h = Histogram("repro_test_h2")
    for v in (0.001, 0.002, 0.004, 0.100):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 4
    assert s["min"] == pytest.approx(0.001)
    assert s["max"] == pytest.approx(0.100)
    # interpolated quantiles stay inside the observed range and are ordered
    assert s["min"] <= s["p50"] <= s["p95"] <= s["p99"] <= s["max"]
    # cumulative buckets are monotone and end at the total
    cums = [c for _, c in s["buckets"]]
    assert cums == sorted(cums) and cums[-1] == 4


def test_registry_type_clash_raises():
    reg = MetricsRegistry()
    reg.counter("repro_test_name")
    with pytest.raises(ValueError):
        reg.gauge("repro_test_name")
    with pytest.raises(ValueError):
        reg.histogram("repro_test_name")


# -------------------------------------------------------------- exposition
def test_zero_metrics_scrape_well_formed():
    """A scrape before any traffic must still be valid exposition: every
    registered instrument appears with zero values, no crash on empty
    histograms."""
    reg = MetricsRegistry()
    reg.counter("repro_test_zero_total")
    reg.gauge("repro_test_zero_depth")
    reg.histogram("repro_test_zero_seconds")
    text = reg.render_prometheus()
    assert "# TYPE repro_test_zero_total counter" in text
    assert "repro_test_zero_total 0" in text
    assert "repro_test_zero_depth 0" in text
    assert '_bucket{le="+Inf"} 0' in text
    assert "repro_test_zero_seconds_count 0" in text
    assert text.endswith("\n")
    # every non-comment line is "name{labels} value"
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        parts = line.rsplit(" ", 1)
        assert len(parts) == 2 and parts[0]
        float(parts[1].replace("+Inf", "inf"))


def test_render_prometheus_formats_values():
    snap = {"counters": {"c_total": 3.0}, "gauges": {"g": 1.5},
            "histograms": {}}
    text = render_prometheus(snap)
    assert "c_total 3\n" in text  # integral floats render as ints
    assert "g 1.5" in text


def test_broken_collector_never_breaks_scrape():
    reg = MetricsRegistry()
    reg.counter("repro_test_ok_total").inc()

    def broken():
        raise RuntimeError("collector bug")

    reg.register_collector(broken)
    snap = reg.snapshot()
    assert snap["counters"]["repro_test_ok_total"] == 1.0


# ----------------------------------------------------------------- tracing
def test_trace_context_spans_and_ids():
    tr = TraceContext()
    assert len(tr.id_bytes()) == TRACE_ID_BYTES
    assert current_trace() is None
    with activate(tr):
        assert current_trace() is tr
        with maybe_span("work"):
            pass
        with maybe_span("work"):
            pass
    assert current_trace() is None
    totals = tr.span_totals()
    assert set(totals) == {"work"} and totals["work"] >= 0.0
    assert len(tr.spans) == 2
    # maybe_span with no active trace is a no-op, not an error
    with maybe_span("orphan"):
        pass
    assert len(tr.spans) == 2


def test_trace_propagates_across_executor():
    """IOExecutor workers must inherit the submitter's trace — the engine
    relies on this for prefetch spans."""
    from repro.runtime.executor import IOExecutor

    tr = TraceContext()
    with IOExecutor(max_workers=2) as ex:
        with activate(tr):
            fut = ex.submit(lambda: current_trace())
        assert fut.result(timeout=10) is tr
        # no active trace at submit time -> worker sees none
        fut2 = ex.submit(lambda: current_trace())
        assert fut2.result(timeout=10) is None


# ------------------------------------------------- cross-process (real node)
@pytest.fixture(scope="module")
def local_node(tmp_path_factory):
    from cluster_harness import spawn_nodes

    # generous ready deadline (cluster_harness default): under a
    # full-suite run on a loaded shared container the child interpreter
    # can take >30s just to import jax
    (node,) = spawn_nodes(tmp_path_factory.mktemp("obsnode"), 1,
                          block_size=16, backend="lsm", metrics_port=0)
    yield node
    node.close()


def test_trace_id_propagates_to_node_scrape(local_node):
    """A trace activated around client RPCs must cross the wire: the
    node's OP_METRICS report carries the trace id and a server-side span
    observation."""
    import numpy as np

    from repro.cluster import ClusterKVBlockStore

    store = ClusterKVBlockStore([local_node.address], block_size=16)
    try:
        tr = TraceContext()
        tokens = list(range(32))
        blocks = [np.ones((16, 8), dtype=np.float32)] * 2
        with activate(tr):
            store.put_batch(tokens, blocks, start_block=0)
            store.flush()
            got = store.get_batch(tokens, 32)
        assert len(got) == 2
        m = store.nodes[0].metrics()
        assert tr.trace_id in m["traces"]
        span = m["metrics"]["histograms"]["repro_node_trace_server_span_seconds"]
        assert span["count"] >= 3  # put + flush + get all carried the trace
        assert m["metrics"]["counters"]["repro_node_trace_requests_total"] >= 3
        # untraced RPCs don't count as traced
        untraced_before = m["metrics"]["counters"]["repro_node_trace_requests_total"]
        store.probe(tokens)
        m2 = store.nodes[0].metrics()
        assert m2["metrics"]["counters"]["repro_node_trace_requests_total"] == untraced_before
    finally:
        store.close()


def test_node_http_exposition(local_node):
    """--metrics-port serves Prometheus text over HTTP with per-op
    latency histograms present."""
    assert local_node.metrics_port
    url = f"http://127.0.0.1:{local_node.metrics_port}/metrics"
    with urllib.request.urlopen(url, timeout=10) as resp:
        assert resp.headers["Content-Type"].startswith("text/plain")
        text = resp.read().decode()
    assert "# TYPE repro_node_request_seconds histogram" in text
    assert "repro_server_requests" in text
    assert "repro_node_request_seconds_p99" in text


def test_scrape_cluster_reports_dead_node_unreachable(tmp_path):
    """scrape_cluster must flag a killed node as unreachable and keep
    returning live nodes' metrics — never hang on the corpse."""
    from cluster_harness import kill_node, spawn_nodes

    from repro.cluster import ClusterKVBlockStore

    nodes = spawn_nodes(tmp_path, 2, block_size=16, backend="lsm")
    store = ClusterKVBlockStore([n.address for n in nodes], block_size=16,
                                retries=0, timeout_s=10.0)
    try:
        kill_node(nodes[1])
        scrape = store.scrape_cluster()
        assert scrape["nodes"][1].get("unreachable")
        assert not scrape["nodes"][0].get("unreachable")
        assert scrape["nodes"][0]["metrics"]["gauges"]["repro_server_requests"] >= 0
        assert 1 in scrape["down"] and 0 in scrape["live"]
        # second scrape: the dead node is already marked down, no RPC retry
        scrape2 = store.scrape_cluster()
        assert scrape2["nodes"][1].get("unreachable")
    finally:
        store.close()
        for n in nodes:
            n.close()
