"""Shared cluster-test fixtures: workload generators, in-process
mem-backed clusters, child-process node management, and the fake-node
frame-level failure injector.

``test_cluster.py``, ``test_transport.py``, ``test_obs.py``, and
``test_ring.py`` all build their topologies from here so the idioms
(block shape, sequence shape, server/client wiring, spawn/kill/restart
lifecycle) stay in one place.
"""

import socket
import threading
from typing import List, Optional, Tuple

import numpy as np

from repro.cluster import (
    CacheNodeServer,
    ClusterKVBlockStore,
    NodeProcess,
    RemoteKVBlockStore,
    spawn_local_node,
)
from repro.cluster import protocol as P
from repro.core.baselines import MemoryOnlyStore

B = 4  # tokens per block used across the cluster suites


# ------------------------------------------------------------- workloads
def blocks(rng, n, dtype=np.float32):
    return [rng.standard_normal((2, B, 4)).astype(dtype) for _ in range(n)]


def seq(rng, nblocks):
    return [int(x) for x in rng.integers(0, 50_000, nblocks * B)]


# ------------------------------------------------- in-process mem cluster
def mem_cluster(
    n: int, replication: int, **kw
) -> Tuple[List[CacheNodeServer], ClusterKVBlockStore]:
    """N in-process memory-backed node servers (real sockets) plus a
    connected cluster client with fail-fast retry settings.  Caller
    closes both (``close_all``)."""
    servers = [
        CacheNodeServer(MemoryOnlyStore(1 << 26, block_size=B), io_threads=1).start()
        for _ in range(n)
    ]
    cluster = ClusterKVBlockStore(
        [s.address for s in servers], replication=replication, retries=0,
        connect_timeout_s=2.0, **kw,
    )
    return servers, cluster


def add_mem_node(servers: List[CacheNodeServer]) -> CacheNodeServer:
    """Start one more in-process memory node (joining it to a cluster is
    the caller's ``cluster.add_node`` call)."""
    srv = CacheNodeServer(MemoryOnlyStore(1 << 26, block_size=B), io_threads=1).start()
    servers.append(srv)
    return srv


def close_all(cluster: Optional[ClusterKVBlockStore], servers) -> None:
    """Best-effort teardown: close the client first, then every server
    (some may already be dead — that's the point of the fault tests)."""
    if cluster is not None:
        try:
            cluster.close()
        except Exception:  # noqa: BLE001
            pass
    for s in servers:
        try:
            s.close()
        except Exception:  # noqa: BLE001
            pass


# ------------------------------------------------- child-process nodes
def spawn_nodes(root, n: int, *, block_size: int = B, backend: str = "memory",
                codec: str = "raw", io_threads: int = 1,
                ready_timeout_s: float = 120.0, **kw) -> List[NodeProcess]:
    """Spawn N real child-process nodes under ``root`` and wait for each
    READY line.  The generous default deadline covers a loaded shared
    container where the child interpreter can take >30s to import."""
    return [
        spawn_local_node(str(root / f"n{i}"), block_size=block_size,
                         backend=backend, codec=codec, io_threads=io_threads,
                         ready_timeout_s=ready_timeout_s, **kw)
        for i in range(n)
    ]


def kill_node(node: NodeProcess) -> None:
    """SIGKILL — the hard-death path (no flush, no goodbye frame)."""
    node.kill()


def restart_node(root, node: NodeProcess, *, block_size: int = B,
                 backend: str = "memory", codec: str = "raw",
                 ready_timeout_s: float = 120.0, **kw) -> NodeProcess:
    """Restart a killed node on its old port (same address, cold or warm
    store depending on backend) and wait for READY."""
    return spawn_local_node(str(root), block_size=block_size, backend=backend,
                            codec=codec, port=node.address[1],
                            ready_timeout_s=ready_timeout_s, **kw)


def wait_ready(node: NodeProcess, timeout_s: float = 30.0) -> bool:
    """Poll the node with pings until it answers (spawn_local_node already
    blocks on READY; this is for nodes restarted out-of-band)."""
    import time
    client = RemoteKVBlockStore(node.address, retries=0,
                                connect_timeout_s=2.0, block_size=B)
    try:
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            try:
                if client.ping():
                    return True
            except Exception:  # noqa: BLE001
                pass
            time.sleep(0.1)
        return False
    finally:
        client.close()


# ------------------------------------------------ frame-level fault node
def mux_frame(rid: int, kind: int, parts) -> bytes:
    """A complete wire frame: u32 len | u32 rid | u8 kind | body."""
    body = b"".join(bytes(p) for p in parts)
    payload = P.pack_mux(rid, kind) + body
    return len(payload).to_bytes(4, "big") + payload


class FakeNode:
    """A listening socket + a per-connection handler run on a thread.
    ``handler(conn, rid, op, args)`` is called once per request frame and
    returns raw bytes to send (or None to close the connection)."""

    def __init__(self, handler):
        self.handler = handler
        self.sock = socket.socket()
        self.sock.bind(("127.0.0.1", 0))
        self.sock.listen(8)
        self.address = self.sock.getsockname()
        self._stop = False
        self._thread = threading.Thread(target=self._serve, daemon=True)
        self._thread.start()

    def _serve(self):
        while not self._stop:
            try:
                conn, _ = self.sock.accept()
            except OSError:
                return
            try:
                while True:
                    frame = P.recv_frame(conn)
                    if frame is None:
                        break
                    rid, kind, body = P.split_mux(frame)
                    op, args = P.decode_request(bytes(body))
                    out = self.handler(conn, rid, op, args)
                    if out is None:
                        break
                    conn.sendall(out)
            except (OSError, P.ProtocolError):
                pass
            finally:
                conn.close()

    def close(self):
        self._stop = True
        try:
            self.sock.close()
        except OSError:
            pass
        self._thread.join(timeout=5)
