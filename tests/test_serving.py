"""Serving engine + workload: staged hit rates realized, TTFT accounting,
hedged reads, LSM-vs-baseline ordering on a miniature workload."""

import numpy as np
import pytest

from repro.cache.hierarchy import CacheHierarchy
from repro.configs import get_config
from repro.core.baselines import FilePerObjectStore, MemoryOnlyStore
from repro.core.store import KVBlockStore
from repro.serving import ComputeModel, ServingEngine
from repro.workload import PAPER_STAGES, StagedWorkload


def make_engine(tmp_path, backend: str, device_blocks=32, host_blocks=64, budget=None):
    cfg = get_config("glm4-9b")
    if backend == "lsm":
        store = KVBlockStore(str(tmp_path / "lsm"), block_size=16, budget_bytes=budget)
    elif backend == "file":
        store = FilePerObjectStore(str(tmp_path / "file"), block_size=16, budget_bytes=budget)
    else:
        store = None
    h = CacheHierarchy(16, device_blocks, host_blocks, store=store)
    eng = ServingEngine(h, ComputeModel(cfg), kv_bytes_per_token=512, max_batch_tokens=4096)
    return eng


def test_workload_stage_hit_expectations():
    wl = StagedWorkload(prompt_len=256, requests_per_stage=20, stages=(0.0, 0.5, 1.0), block_size=16, seed=1)
    reqs = list(wl.requests())
    assert len(reqs) == 60
    for r in reqs:
        assert len(r.tokens) == 256
    # stage 2 requests share their full prefix with a corpus root
    r2 = [r for r in reqs if r.stage == 2][0]
    assert any(r2.tokens == root[:256] for root in wl.corpus)


def test_engine_hit_rate_tracks_expected(tmp_path):
    wl = StagedWorkload(prompt_len=256, requests_per_stage=12, stages=(0.5,), block_size=16,
                        corpus_size=4, seed=2)
    eng = make_engine(tmp_path, "lsm", device_blocks=4096, host_blocks=4096)
    # warm the corpus so shared prefixes can hit
    for p in wl.warmup_prompts(4 * 256):
        eng.submit(type("R", (), {"tokens": p, "rid": -1, "stage": -1})())
    eng.run()
    recs = []
    for r in wl.stage_requests(0):
        eng.submit(r)
    recs = eng.run()
    hits = np.mean([r.reused_tokens / r.prompt_len for r in recs])
    assert hits >= 0.4  # expected 0.5, block-rounding tolerated


def test_ttft_decomposition(tmp_path):
    eng = make_engine(tmp_path, "lsm")
    wl = StagedWorkload(prompt_len=128, requests_per_stage=3, stages=(0.0,), block_size=16, seed=3)
    for r in wl.stage_requests(0):
        eng.submit(r)
    recs = eng.run()
    for r in recs:
        assert r.ttft_s == pytest.approx(r.io_s + r.compute_s)
        assert r.compute_s > 0


def test_lsm_beats_memory_only_under_pressure(tmp_path):
    """With device+host budgets far below the working set, the disk-backed
    hierarchy must retain (and re-hit) more than memory-only — the paper's
    core claim at miniature scale."""
    wl_kwargs = dict(prompt_len=256, requests_per_stage=10, stages=(0.7, 0.7),
                     block_size=16, corpus_size=6, seed=4)
    results = {}
    for backend in ("lsm", "none"):
        eng = make_engine(tmp_path, backend, device_blocks=8, host_blocks=16)
        wl = StagedWorkload(**wl_kwargs)
        for p in wl.warmup_prompts(6 * 256):
            eng.submit(type("R", (), {"tokens": p, "rid": -1, "stage": -1})())
        eng.run()
        recs = []
        for r in wl.requests():
            eng.submit(r)
        recs = eng.run()
        results[backend] = np.mean([r.reused_tokens / r.prompt_len for r in recs])
    assert results["lsm"] > results["none"]


def test_hedged_read_retries_straggler(tmp_path):
    """A promotion slower than hedge_factor x EWMA is re-issued and the
    faster attempt wins (straggler mitigation)."""
    import time as _time

    from repro.cache.hierarchy import Acquisition

    eng = make_engine(tmp_path, "lsm")
    calls = {"n": 0}

    def fake_acquire(tokens):
        calls["n"] += 1
        if calls["n"] == 1:
            _time.sleep(0.02)  # straggling first read
        return Acquisition(nodes=[], reuse_tokens=32, device_tokens=0,
                           host_tokens=0, disk_tokens=32, io_s=0.0)

    eng.h.acquire = fake_acquire
    eng.h.release = lambda acq: None
    eng._ewma_read_s = 1e-4  # 0.02s >> 4 x 1e-4 -> hedge trips
    acq, dt, hedged = eng._acquire_hedged(list(range(64)))
    assert hedged
    assert calls["n"] == 2
    assert eng.stats.hedged_reads == 1
    assert dt < 0.02  # the retry won
