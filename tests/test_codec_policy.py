"""Codec contract + adaptive-tiering property suite (PR 8).

Four layers, matching the tentpole's claim chain:

1. ``BatchCodec`` round-trip properties over every supported dtype and
   shape — raw/zlib bit-exact, int8 within the per-channel quantization
   bound — plus bit-identity against the Pallas kernel's oracle
   (``kernels/kv_codec/ref.py``), so the host codec and the device codec
   can never drift apart silently.
2. Malformed payloads: every corruption raises typed ``CodecError``
   (a ``ValueError`` so protocol-level guards keep working), including
   arbitrary hypothesis-driven truncation.
3. ``transcode`` — the demotion primitive: zlib-layer changes are
   bit-stable (int8 -> int8+zlib never re-quantizes), idempotent at the
   target, and a codec change round-trips through decode.
4. The tiering policy end to end: ``TierRecoder`` demotion through a
   real ``KVBlockStore`` (gauges, bytes saved, settled convergence,
   concurrent readers), the maintenance-service harvest, and the
   length-prefixed ``LAYOUT_ENCODED`` wire path.
"""

import subprocess
import sys
import threading
import time

import numpy as np
import pytest
from hypothesis_compat import HealthCheck, given, settings, st

from repro.core.codec import (
    CODEC_INT8,
    CODEC_RAW,
    HAVE_BFLOAT16,
    BatchCodec,
    CodecError,
    header_info,
    quantize_int8,
    transcode,
)
from repro.core.sharded_store import ShardedKVBlockStore
from repro.core.store import KVBlockStore
from repro.core.tiering import (
    TIER_COLD,
    TIER_HOT,
    TIER_WARM,
    TieringPolicy,
    tier_of_codec,
)

RAW = BatchCodec(CODEC_RAW, use_zlib=False)
RAW_Z = BatchCodec(CODEC_RAW, use_zlib=True)
WARM = BatchCodec(CODEC_INT8, use_zlib=False)
COLD = BatchCodec(CODEC_INT8, use_zlib=True)


def _arr(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32)
    if dtype == "bfloat16":
        import ml_dtypes

        return x.astype(ml_dtypes.bfloat16)
    return x.astype(dtype)


_DTYPES = ["float32", "float16", "int8"] + (["bfloat16"] if HAVE_BFLOAT16 else [])

_shapes = st.lists(st.integers(1, 6), min_size=1, max_size=4).map(tuple)


# ================================================== 1. round-trip properties
@given(shape=_shapes, seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from(_DTYPES), use_zlib=st.booleans())
@settings(max_examples=60, deadline=None)
def test_raw_roundtrip_bit_exact(shape, seed, dtype, use_zlib):
    """Raw (and raw+zlib) is lossless for every dtype and shape."""
    x = _arr(shape, dtype, seed)
    enc = BatchCodec(CODEC_RAW, use_zlib=use_zlib).encode(x)
    y = BatchCodec.decode(enc)
    assert y.dtype == x.dtype and y.shape == x.shape
    np.testing.assert_array_equal(
        y.view(np.uint8) if dtype == "bfloat16" else y,
        x.view(np.uint8) if dtype == "bfloat16" else x)


@given(shape=_shapes, seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from(["float32", "float16"]), use_zlib=st.booleans())
@settings(max_examples=60, deadline=None)
def test_int8_roundtrip_within_quantization_bound(shape, seed, dtype, use_zlib):
    """int8 error is bounded per channel by scale/2 = absmax/254 (plus the
    target dtype's own rounding); zlib on top changes nothing (lossless)."""
    x = _arr(shape, dtype, seed)
    y = BatchCodec.decode(BatchCodec(CODEC_INT8, use_zlib=use_zlib).encode(x))
    assert y.dtype == x.dtype and y.shape == x.shape
    xf = x.astype(np.float32).reshape(-1, shape[-1])
    yf = y.astype(np.float32).reshape(-1, shape[-1])
    absmax = np.abs(xf).max(axis=0)
    eps = np.finfo(dtype).eps
    bound = absmax / 254 + absmax * eps + 1e-6
    assert (np.abs(xf - yf).max(axis=0) <= bound).all()


@given(shape=_shapes, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_int8_zlib_layer_is_lossless(shape, seed):
    """int8 and int8+zlib decode to identical values: the zlib layer is
    transparent, only the quantization step loses information."""
    x = _arr(shape, "float32", seed)
    np.testing.assert_array_equal(BatchCodec.decode(WARM.encode(x)),
                                  BatchCodec.decode(COLD.encode(x)))


# Deterministic grid twins of the properties above: hypothesis is a dev
# dependency (the @given tests skip without it — see hypothesis_compat),
# so the contract is also pinned by an always-on seeded sweep.
_GRID_SHAPES = [(3,), (1, 1), (2, 5), (4, 3, 2), (2, 1, 3, 4)]


@pytest.mark.parametrize("dtype", _DTYPES)
@pytest.mark.parametrize("use_zlib", [False, True], ids=["plain", "zlib"])
def test_raw_roundtrip_grid(dtype, use_zlib):
    for seed, shape in enumerate(_GRID_SHAPES):
        x = _arr(shape, dtype, seed)
        y = BatchCodec.decode(BatchCodec(CODEC_RAW, use_zlib=use_zlib).encode(x))
        assert y.dtype == x.dtype and y.shape == x.shape
        np.testing.assert_array_equal(y.view(np.uint8), x.view(np.uint8))


@pytest.mark.parametrize("dtype", ["float32", "float16"])
@pytest.mark.parametrize("use_zlib", [False, True], ids=["plain", "zlib"])
def test_int8_roundtrip_grid(dtype, use_zlib):
    for seed, shape in enumerate(_GRID_SHAPES):
        x = _arr(shape, dtype, seed)
        y = BatchCodec.decode(BatchCodec(CODEC_INT8, use_zlib=use_zlib).encode(x))
        assert y.dtype == x.dtype and y.shape == x.shape
        xf = x.astype(np.float32).reshape(-1, shape[-1])
        yf = y.astype(np.float32).reshape(-1, shape[-1])
        absmax = np.abs(xf).max(axis=0)
        bound = absmax / 254 + absmax * np.finfo(dtype).eps + 1e-6
        assert (np.abs(xf - yf).max(axis=0) <= bound).all()
        np.testing.assert_array_equal(  # the zlib layer is lossless
            y, BatchCodec.decode(WARM.encode(x)))


@pytest.mark.parametrize(
    "codec", [RAW, RAW_Z, WARM, COLD],
    ids=["raw", "raw-zlib", "int8", "int8-zlib"])
def test_every_truncation_raises_grid(codec):
    """Exhaustive: every strict prefix of a valid payload fails with
    CodecError — no internal struct/zlib/numpy error ever escapes."""
    enc = codec.encode(_arr((3, 4, 5), "float32", 7))
    for k in range(len(enc)):
        with pytest.raises(CodecError):
            BatchCodec.decode(enc[:k])


def test_transcode_bit_stable_grid():
    for seed, shape in enumerate(_GRID_SHAPES):
        warm = WARM.encode(_arr(shape, "float32", seed))
        cold = transcode(warm, COLD)
        np.testing.assert_array_equal(BatchCodec.decode(cold),
                                      BatchCodec.decode(warm))
        assert transcode(cold, COLD) is None


def test_quantizer_matches_kernel_oracle():
    """Host-side quantize_int8 must be bit-identical to the Pallas
    kernel's jnp oracle — same scale rule, same clipping, same rounding —
    including the all-zero-channel scale=1.0 case."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.kv_codec.ref import quantize_ref

    rng = np.random.default_rng(11)
    x = (3.0 * rng.standard_normal((4, 16, 32))).astype(np.float32)
    x[..., 5] = 0.0  # all-zero channel: scale must be exactly 1.0
    q, scale = quantize_int8(x)
    q_ref, scale_ref = quantize_ref(jnp.asarray(x))
    np.testing.assert_array_equal(q, np.asarray(q_ref))
    np.testing.assert_array_equal(scale, np.asarray(scale_ref))
    assert scale[5] == 1.0


# ===================================================== 2. malformed payloads
def test_typed_errors_on_malformed_headers():
    x = np.ones((2, 3), dtype=np.float32)
    good = RAW.encode(x)
    bad_codec = bytes([7]) + good[1:]
    bad_zlib = good[:1] + bytes([9]) + good[2:]
    bad_ndim = good[:2] + (0).to_bytes(2, "little") + good[4:]
    huge_ndim = good[:2] + (65535).to_bytes(2, "little") + good[4:]
    bad_dtype = bytearray(good)
    bad_dtype[4 + 4 * x.ndim] = 250
    for raw in (b"", b"\x00", bad_codec, bad_zlib, bad_ndim, huge_ndim,
                bytes(bad_dtype)):
        with pytest.raises(CodecError):
            BatchCodec.decode(raw)
    with pytest.raises(CodecError):
        BatchCodec(codec=42)
    with pytest.raises(CodecError):
        RAW.encode(np.ones((2, 2), dtype=np.float64))  # unsupported dtype
    with pytest.raises(CodecError):
        RAW.encode(np.ones((1,) * 17, dtype=np.float32))  # ndim > bound
    assert issubclass(CodecError, ValueError)  # protocol guards rely on this


@given(shape=_shapes, seed=st.integers(0, 2**31 - 1),
       codec=st.sampled_from([RAW, RAW_Z, WARM, COLD]),
       cut=st.floats(0.0, 1.0, exclude_max=True))
@settings(max_examples=60, deadline=None)
def test_any_truncation_raises_codec_error(shape, seed, codec, cut):
    """Every strict prefix of a valid payload fails decode with
    CodecError — truncated header, truncated dims, short body, or a
    truncated deflate stream — never an np/struct/zlib internal error."""
    enc = codec.encode(_arr(shape, "float32", seed))
    with pytest.raises(CodecError):
        BatchCodec.decode(enc[: int(cut * len(enc))])


def test_trailing_garbage_raises_codec_error():
    enc = WARM.encode(np.ones((2, 4), dtype=np.float32))
    with pytest.raises(CodecError):
        BatchCodec.decode(enc + b"\x00\x00")


def test_corrupt_zlib_body_raises_codec_error():
    enc = bytearray(COLD.encode(np.ones((4, 8), dtype=np.float32)))
    enc[-1] ^= 0xFF
    with pytest.raises(CodecError, match="zlib"):
        BatchCodec.decode(bytes(enc))


# ------------------------------------------------------- bfloat16 two worlds
@pytest.mark.skipif(not HAVE_BFLOAT16, reason="bfloat16 dtype unavailable")
def test_bf16_payload_without_mldtypes_raises_codec_error():
    """A host without ml_dtypes must fail a bf16 payload with CodecError
    (not a silent wrong dtype): the registration probe takes the fallback
    import path in a subprocess where ml_dtypes is blocked."""
    import ml_dtypes

    enc = RAW.encode(np.ones((2, 2), dtype=ml_dtypes.bfloat16))
    prog = (
        "import sys; sys.modules['ml_dtypes'] = None\n"
        "from repro.core.codec import BatchCodec, CodecError, HAVE_BFLOAT16\n"
        f"enc = bytes.fromhex('{enc.hex()}')\n"
        "try:\n"
        "    BatchCodec.decode(enc)\n"
        "except CodecError:\n"
        "    print('OK', HAVE_BFLOAT16)\n"
    )
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=60)
    assert res.returncode == 0, res.stderr
    assert res.stdout.strip() == "OK False", (res.stdout, res.stderr)


@pytest.mark.skipif(not HAVE_BFLOAT16, reason="bfloat16 dtype unavailable")
def test_bf16_int8_roundtrip():
    import ml_dtypes

    x = _arr((3, 4, 8), "bfloat16", 5)
    y = BatchCodec.decode(COLD.encode(x))
    assert y.dtype == np.dtype(ml_dtypes.bfloat16) and y.shape == x.shape
    np.testing.assert_allclose(y.astype(np.float32), x.astype(np.float32),
                               atol=0.1, rtol=0.1)


# ============================================================== 3. transcode
@given(shape=_shapes, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_transcode_warm_to_cold_is_bit_stable(shape, seed):
    """int8 -> int8+zlib must not re-quantize: the decoded values are
    exactly the warm payload's, and a second transcode is a no-op."""
    warm = WARM.encode(_arr(shape, "float32", seed))
    cold = transcode(warm, COLD)
    assert cold is not None
    np.testing.assert_array_equal(BatchCodec.decode(cold),
                                  BatchCodec.decode(warm))
    assert header_info(cold)[:2] == (CODEC_INT8, True)
    assert transcode(cold, COLD) is None  # already at target
    back = transcode(cold, WARM)  # strip the zlib layer: still bit-stable
    np.testing.assert_array_equal(BatchCodec.decode(back),
                                  BatchCodec.decode(warm))


def test_transcode_raw_to_int8_quantizes_once():
    x = _arr((4, 16), "float32", 9)
    raw = RAW.encode(x)
    assert transcode(raw, RAW) is None
    warm = transcode(raw, WARM)
    np.testing.assert_array_equal(BatchCodec.decode(warm),
                                  BatchCodec.decode(WARM.encode(x)))
    with pytest.raises(CodecError):
        transcode(b"\x07junk", COLD)


# ======================================================== 4. tiering policy
def test_tiering_policy_thresholds_and_codecs():
    p = TieringPolicy(warm_after_s=10.0, cold_after_s=60.0)
    assert p.target_tier(0.0) == TIER_HOT
    assert p.target_tier(10.0) == TIER_WARM
    assert p.target_tier(60.0) == TIER_COLD
    assert p.codec_for(TIER_HOT).codec == CODEC_RAW
    assert p.codec_for(TIER_WARM).codec == CODEC_INT8
    assert not p.codec_for(TIER_WARM).use_zlib
    assert p.codec_for(TIER_COLD).use_zlib
    with pytest.raises(ValueError):
        TieringPolicy(warm_after_s=5.0, cold_after_s=1.0)
    assert tier_of_codec(RAW) == TIER_HOT
    assert tier_of_codec(WARM) == TIER_WARM
    assert tier_of_codec(COLD) == TIER_COLD


def _fill(store, n_seqs=6, blocks_per_seq=4, block=4, feat=64, seed=0):
    rng = np.random.default_rng(seed)
    seqs, payloads = [], []
    for _ in range(n_seqs):
        toks = rng.integers(1, 50000, size=blocks_per_seq * block).tolist()
        blocks = [rng.standard_normal((block, feat)).astype(np.float32)
                  for _ in range(blocks_per_seq)]
        store.put_batch(toks, blocks)
        seqs.append(toks)
        payloads.append(blocks)
    store.flush()
    return seqs, payloads


def _settle(store, rounds=12):
    """Maintenance until the recoder stops demoting; returns total."""
    total = 0
    for _ in range(rounds):
        rep = store.maintenance()
        tiering = rep.get("tiering") or {}
        d = int(tiering.get("demoted_blocks", 0) or 0)
        total += d
        if d == 0:
            break
    return total


def test_store_demotes_hot_blocks_and_keeps_serving(tmp_path):
    """End-to-end demotion: raw puts, maintenance re-encodes sealed files
    to the cold tier, gauges and bytes-saved move, and every read still
    returns the data (within the int8 bound) from repointed entries."""
    store = KVBlockStore(str(tmp_path / "kvs"), block_size=4,
                         vlog_file_bytes=4096,
                         tiering=TieringPolicy(warm_after_s=0.0,
                                               cold_after_s=0.0))
    try:
        seqs, payloads = _fill(store)
        total = sum(len(p) for p in payloads)
        assert store.stats.tier_hot_blocks == total  # puts are raw
        disk_hot = store.disk_bytes
        demoted = _settle(store)
        assert demoted > 0
        s = store.stats
        assert s.demoted_blocks == demoted
        assert s.tier_cold_blocks == demoted
        assert s.tier_hot_blocks == total - demoted  # active file stays hot
        assert s.demote_bytes_saved > 0
        assert s.demote_s > 0
        assert store.disk_bytes < disk_hot
        for toks, blocks in zip(seqs, payloads):
            assert store.probe(toks) == len(toks)
            got = store.get_batch(toks, len(toks))
            assert len(got) == len(blocks)
            for want, have in zip(blocks, got):
                np.testing.assert_allclose(have, want, atol=0.05, rtol=0.05)
        # demoted payloads ship already-encoded: cold headers on the wire
        enc = store.get_batch_encoded(seqs[0], len(seqs[0]))
        assert all(isinstance(p, bytes) for p in enc)
        assert any(header_info(p)[:2] == (CODEC_INT8, True) for p in enc)
        # settled: further cycles find nothing to demote
        assert _settle(store, rounds=2) == 0
    finally:
        store.close()


def test_static_codec_store_has_no_recoder(tmp_path):
    store = KVBlockStore(str(tmp_path / "kvs"), block_size=4, codec=COLD)
    try:
        _fill(store, n_seqs=2)
        assert store.recoder is None
        assert store.stats.tier_cold_blocks > 0  # static codec == cold tier
        assert "tiering" not in store.maintenance()
    finally:
        store.close()


def test_sharded_store_aggregates_tiering(tmp_path):
    store = ShardedKVBlockStore(
        str(tmp_path / "kvs"), n_shards=2, block_size=4,
        vlog_file_bytes=4096,
        tiering=TieringPolicy(warm_after_s=0.0, cold_after_s=0.0))
    try:
        seqs, payloads = _fill(store, n_seqs=8)
        demoted, rounds = 0, 0
        while rounds < 12:
            rep = store.maintenance()
            d = int((rep.get("tiering") or {}).get("demoted_blocks", 0) or 0)
            demoted += d
            rounds += 1
            if d == 0:
                break
        assert demoted > 0
        assert store.stats.tier_cold_blocks == demoted
        enc = store.get_batch_encoded(seqs[0], len(seqs[0]))
        assert all(isinstance(p, bytes) for p in enc)
    finally:
        store.close()


def test_concurrent_readers_during_demotion(tmp_path):
    """Lock-free readers racing the recoder's append/repoint/remove must
    never see an error or a wrong value — the merge/evict retry contract
    extends to demotion."""
    store = KVBlockStore(str(tmp_path / "kvs"), block_size=4,
                         vlog_file_bytes=2048,
                         tiering=TieringPolicy(warm_after_s=0.0,
                                               cold_after_s=0.0))
    try:
        seqs, payloads = _fill(store, n_seqs=10, seed=3)
        errors = []
        stop = threading.Event()

        def reader(idx):
            while not stop.is_set():
                toks, blocks = seqs[idx % len(seqs)], payloads[idx % len(seqs)]
                try:
                    got = store.get_batch(toks, store.probe(toks))
                    for want, have in zip(blocks, got):
                        np.testing.assert_allclose(have, want,
                                                   atol=0.05, rtol=0.05)
                except Exception as e:  # noqa: BLE001 — the assertion target
                    errors.append(e)
                    return
                idx += 1

        threads = [threading.Thread(target=reader, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        demoted = _settle(store)
        stop.set()
        for t in threads:
            t.join(timeout=30)
        assert not errors, errors[0]
        assert demoted > 0
    finally:
        store.close()


def test_maintenance_service_harvests_demotions(tmp_path):
    from repro.runtime.maintenance import MaintenanceService

    store = KVBlockStore(str(tmp_path / "kvs"), block_size=4,
                         vlog_file_bytes=4096,
                         tiering=TieringPolicy(warm_after_s=0.0,
                                               cold_after_s=0.0))
    try:
        _fill(store)
        svc = MaintenanceService(store.maintenance)
        for _ in range(12):
            if not (svc.run_inline().get("tiering") or {}).get("demoted_blocks"):
                break
        assert svc.stats.demoted_blocks > 0
        assert svc.harvest().demoted_blocks == svc.stats.demoted_blocks
        assert svc.harvest().demoted_blocks == 0  # harvest resets
    finally:
        store.close()


def test_demotion_respects_read_recency(tmp_path):
    """A file whose blocks keep being read stays hot: reads refresh the
    log file's access time, so only idle files are victims."""
    store = KVBlockStore(str(tmp_path / "kvs"), block_size=4,
                         vlog_file_bytes=2048,
                         tiering=TieringPolicy(warm_after_s=3600.0,
                                               cold_after_s=7200.0))
    try:
        seqs, _ = _fill(store)
        fids = store.log.file_ids()
        assert len(fids) >= 2
        assert all(store.log.idle_s(fid) < 60 for fid in fids)
        assert not store.recoder.needed()  # nothing idle long enough
        # inject idleness: far-future "now" makes every sealed file cold
        now = time.monotonic() + 10_000.0
        assert store.recoder.needed(now=now)
        rep = store.recoder.run(now=now)
        assert rep.demoted_blocks > 0
        assert set(rep.transitions) == {"hot->cold"}
        store.get_batch(seqs[0], store.probe(seqs[0]))  # read touches files
        assert all(store.log.idle_s(fid) < 60 for fid in store.log.file_ids())
    finally:
        store.close()


# ===================================================== 5. encoded wire path
def test_layout_encoded_roundtrip_and_errors():
    """OP_GET responses carrying still-encoded payloads (LAYOUT_ENCODED)
    decode to the same arrays, and corrupt payloads surface as
    ProtocolError, not raw zlib/struct errors."""
    from repro.cluster import protocol as P

    rng = np.random.default_rng(21)
    blocks = [rng.standard_normal((4, 16)).astype(np.float32) for _ in range(3)]
    payloads = [COLD.encode(b) for b in blocks]
    body = P.encode_ok(P.OP_GET, payloads)
    got = P.decode_response(P.OP_GET, body)
    assert len(got) == 3
    for want, have in zip(blocks, got):
        np.testing.assert_array_equal(have, BatchCodec.decode(COLD.encode(want)))

    corrupt = bytearray(body)
    corrupt[-1] ^= 0xFF  # flip the tail of the last zlib stream
    with pytest.raises(P.ProtocolError, match="encoded block"):
        P.decode_response(P.OP_GET, bytes(corrupt))
    with pytest.raises(P.ProtocolError):
        P.decode_response(P.OP_GET, body[: len(body) // 2])


def test_layout_encoded_stream_chunk_roundtrip():
    from repro.cluster import protocol as P

    rng = np.random.default_rng(22)
    blocks = [rng.standard_normal((4, 8)).astype(np.float32) for _ in range(2)]
    parts = P.encode_stream_chunk(5, 7, [WARM.encode(b) for b in blocks])
    seq, start, got = P.decode_stream_chunk(b"".join(bytes(p) for p in parts))
    assert (seq, start) == (5, 7)
    for want, have in zip(blocks, got):
        np.testing.assert_array_equal(have, BatchCodec.decode(WARM.encode(want)))


def test_layout_selection_is_all_or_nothing():
    """LAYOUT_ENCODED is chosen only when *every* item is bytes-like;
    ndarray lists keep the packed layout — the two worlds never mix on
    one response."""
    from repro.cluster import protocol as P

    rng = np.random.default_rng(23)
    arr = rng.standard_normal((4, 8)).astype(np.float32)
    enc_parts = P._enc_blocks([WARM.encode(arr), WARM.encode(arr)])
    assert bytes(enc_parts[1]) == bytes([P.LAYOUT_ENCODED])
    arr_parts = P._enc_blocks([arr, arr])
    assert bytes(arr_parts[1]) == b"\x01"  # packed homogeneous layout
    assert bytes(P._enc_blocks([])[1]) != bytes([P.LAYOUT_ENCODED])
