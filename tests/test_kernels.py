"""Per-kernel interpret-mode validation: sweep shapes/dtypes, allclose vs
the pure-jnp ref.py oracle (assignment deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import paged_decode, paged_decode_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.kv_codec import dequantize, dequantize_ref, quantize, quantize_ref
from repro.kernels.rwkv6 import wkv, wkv_ref

KEY = jax.random.key(42)


# ---------------------------------------------------------------- kv_codec
@pytest.mark.parametrize("shape", [(16, 256), (4, 8, 128), (32, 130), (3, 5, 96)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_kv_codec_matches_oracle(shape, dtype):
    x = jax.random.normal(jax.random.fold_in(KEY, sum(shape)), shape, dtype) * 4
    q, s = quantize(x, interpret=True)
    qr, sr = quantize_ref(x)
    # round-half boundaries may differ by one ULP between reduction orders
    diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert diff.max() <= 1 and (diff > 0).mean() < 0.01
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    d = dequantize(q, s, interpret=True)
    dr = dequantize_ref(qr, sr)
    # one-ULP q differences dequantize to at most one scale step
    np.testing.assert_allclose(
        np.asarray(d, np.float32), np.asarray(dr, np.float32),
        atol=float(np.max(np.asarray(sr))) + 1e-3,
    )


def test_kv_codec_matches_host_codec():
    from repro.core.codec import quantize_int8

    x = jax.random.normal(KEY, (24, 192), jnp.float32)
    q, _ = quantize(x, interpret=True)
    qh, _ = quantize_int8(np.asarray(x))
    diff = np.abs(np.asarray(q, np.int32) - qh.astype(np.int32))
    assert diff.max() <= 1 and (diff > 0).mean() < 0.01


def test_kv_codec_zero_channel_scale_one():
    x = jnp.zeros((8, 128), jnp.float32)
    q, s = quantize(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(s), np.ones(128, np.float32))
    np.testing.assert_array_equal(np.asarray(q), np.zeros((8, 128), np.int8))


# ---------------------------------------------------- paged decode attention
@pytest.mark.parametrize(
    "B,H,KVH,D,page,NB,P",
    [(2, 8, 2, 64, 16, 4, 12), (3, 4, 4, 128, 8, 3, 10), (1, 16, 1, 64, 32, 2, 5)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_matches_oracle(B, H, KVH, D, page, NB, P, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, B * 1000 + H), 5)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp = jax.random.normal(ks[1], (P, page, KVH, D), dtype)
    vp = jax.random.normal(ks[2], (P, page, KVH, D), dtype)
    tables = jax.random.randint(ks[3], (B, NB), 0, P)
    kv_len = jax.random.randint(ks[4], (B,), 1, NB * page + 1)
    out = paged_decode(q, kp, vp, tables, kv_len, interpret=True)
    ref = paged_decode_ref(q, kp, vp, tables, kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_paged_decode_single_valid_token():
    """kv_len=1: only the first slot of the first page participates."""
    B, H, KVH, D, page, NB, P = 1, 2, 1, 64, 8, 2, 4
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, KVH, D), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, KVH, D), jnp.float32)
    tables = jnp.array([[2, 0]], jnp.int32)
    kv_len = jnp.array([1], jnp.int32)
    out = paged_decode(q, kp, vp, tables, kv_len, interpret=True)
    # attention over one token == that token's value
    np.testing.assert_allclose(
        np.asarray(out)[0, 0], np.asarray(vp)[2, 0, 0], rtol=1e-5, atol=1e-5
    )


# -------------------------------------------------------------------- rwkv6
@pytest.mark.parametrize("B,H,S,N,chunk", [(2, 3, 37, 16, 8), (1, 2, 64, 32, 32), (2, 4, 100, 64, 16)])
def test_rwkv6_kernel_matches_oracle(B, H, S, N, chunk):
    ks = jax.random.split(jax.random.fold_in(KEY, S * 10 + N), 6)
    r = jax.random.normal(ks[0], (B, H, S, N))
    k = jax.random.normal(ks[1], (B, H, S, N))
    v = jax.random.normal(ks[2], (B, H, S, N))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, S, N))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, N, N)) * 0.5
    y, sT = wkv(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    yr, sr = wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sr), rtol=3e-4, atol=3e-4)


def test_rwkv6_kernel_state_chaining():
    """Running two halves with carried state == one full run."""
    B, H, S, N = 1, 2, 64, 16
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, H, S, N))
    k = jax.random.normal(ks[1], (B, H, S, N))
    v = jax.random.normal(ks[2], (B, H, S, N))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, S, N))) * 0.4 + 0.55
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    s0 = jnp.zeros((B, H, N, N))
    y_full, s_full = wkv(r, k, v, w, u, s0, chunk=16, interpret=True)
    h = S // 2
    y1, s1 = wkv(r[:, :, :h], k[:, :, :h], v[:, :, :h], w[:, :, :h], u, s0, chunk=16, interpret=True)
    y2, s2 = wkv(r[:, :, h:], k[:, :, h:], v[:, :, h:], w[:, :, h:], u, s1, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_full[:, :, :h]), np.asarray(y1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_full[:, :, h:]), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- mamba2
@pytest.mark.parametrize("B,S,H,P,N,chunk", [(2, 50, 3, 8, 16, 16), (1, 128, 2, 16, 8, 64)])
def test_mamba2_ssd_kernel_matches_oracle(B, S, H, P, N, chunk):
    from repro.kernels.mamba2 import ssd, ssd_ref

    ks = jax.random.split(jax.random.fold_in(KEY, S + P), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    Bm = jax.random.normal(ks[1], (B, S, N))
    Cm = jax.random.normal(ks[2], (B, S, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    a = jnp.exp(-dt * jnp.exp(jax.random.normal(ks[4], (H,))[None, None] * 0.3))
    s0 = jax.random.normal(ks[5], (B, H, P, N)) * 0.5
    y, sT = ssd(x, Bm, Cm, a, dt, s0, chunk=chunk, interpret=True)
    yr, sr = ssd_ref(x, Bm, Cm, a, dt, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=4e-4, atol=4e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sr), rtol=4e-4, atol=4e-4)


def test_mamba2_ssd_state_chaining():
    from repro.kernels.mamba2 import ssd

    B, S, H, P, N = 1, 64, 2, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    Bm = jax.random.normal(ks[1], (B, S, N))
    Cm = jax.random.normal(ks[2], (B, S, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    a = jnp.exp(-dt * 0.8)
    s0 = jnp.zeros((B, H, P, N))
    y_full, s_full = ssd(x, Bm, Cm, a, dt, s0, chunk=16, interpret=True)
    h = S // 2
    y1, s1 = ssd(x[:, :h], Bm[:, :h], Cm[:, :h], a[:, :h], dt[:, :h], s0, chunk=16, interpret=True)
    y2, s2 = ssd(x[:, h:], Bm[:, h:], Cm[:, h:], a[:, h:], dt[:, h:], s1, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_full[:, :h]), np.asarray(y1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_full[:, h:]), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("Sq,Skv,H,KVH,D", [(128, 128, 4, 2, 64), (64, 192, 8, 8, 128)])
def test_flash_attention_matches_oracle(Sq, Skv, H, KVH, D):
    ks = jax.random.split(jax.random.fold_in(KEY, Sq + Skv), 3)
    q = jax.random.normal(ks[0], (2, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (2, Skv, KVH, D), jnp.float32)
    v = jax.random.normal(ks[2], (2, Skv, KVH, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_offset=Skv - Sq, block_q=64, block_k=64, interpret=True)
    # ops takes model layout (B,S,H,D); the ref oracle takes kernel layout
    ref = attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=True, q_offset=Skv - Sq,
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(jnp.moveaxis(ref, 1, 2)), rtol=2e-5, atol=2e-5
    )
