"""HLO cost model: trip-count awareness (the reason it exists), dot flops,
collective accounting, nested loops."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlocost import analyze_text, parse_module


def _compiled_text(fn, *avals):
    return jax.jit(fn).lower(*avals).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    def scan10(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    t = analyze_text(_compiled_text(scan10, x, x))
    expect = 10 * 2 * 256**3
    assert t.flops == pytest.approx(expect, rel=0.05)


def test_single_dot_matches_xla_cost_analysis():
    x = jax.ShapeDtypeStruct((128, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(x, w).compile()
    t = analyze_text(c.as_text())
    assert t.flops == pytest.approx(float(c.cost_analysis()["flops"]), rel=0.05)


def test_nested_scan_trip_counts_compose():
    def nested(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    t = analyze_text(_compiled_text(nested, x, x))
    expect = 15 * 2 * 128**3
    assert t.flops == pytest.approx(expect, rel=0.1)


def test_parse_module_finds_entry_and_constants():
    def f(x):
        def body(c, _):
            return c + 1.0, None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    text = _compiled_text(f, jax.ShapeDtypeStruct((8, 128), jnp.float32))
    comps, entry = parse_module(text)
    assert entry is not None
    lits = [i.literal for c in comps.values() for i in c.instrs if i.literal is not None]
    assert 7 in lits


def test_collectives_counted_with_trip_multiplier():
    """An all-reduce inside a scanned body must count once per trip."""
    mesh = jax.make_mesh((1,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        def body(c, _):
            s = jax.lax.with_sharding_constraint(c @ c, NamedSharding(mesh, P()))
            return s, None
        c, _ = jax.lax.scan(body, x, None, length=4)
        return c

    # single-device mesh rarely materializes collectives; this test instead
    # guards the walk doesn't crash and bytes scale with trips
    t = analyze_text(_compiled_text(f, jax.ShapeDtypeStruct((64, 64), jnp.float32)))
    assert t.flops == pytest.approx(4 * 2 * 64**3, rel=0.1)
