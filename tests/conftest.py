import os
import sys

# tests must see exactly 1 device (dry-run sets its own XLA_FLAGS in a
# subprocess); keep CPU planes deterministic
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
