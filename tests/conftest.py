import os
import signal
import sys
import threading

import pytest

# tests must see exactly 1 device (dry-run sets its own XLA_FLAGS in a
# subprocess); keep CPU planes deterministic
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------- timeouts
# A deadlocked lock ordering must fail fast, not hang the suite (the
# concurrency stress tests exist precisely to catch such bugs).  CI
# installs pytest-timeout (see pytest.ini / requirements-dev.txt); when the
# plugin is absent (minimal local envs) this SIGALRM fallback enforces the
# same per-test budget on the main thread — CPython lock waits are
# signal-interruptible, so even a test stuck in Lock.acquire gets killed.
try:
    import pytest_timeout  # noqa: F401

    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False

_DEFAULT_TIMEOUT_S = int(os.environ.get("REPRO_TEST_TIMEOUT_S", "300"))


def pytest_configure(config):
    if not _HAVE_PYTEST_TIMEOUT:
        config.addinivalue_line(
            "markers",
            "timeout(seconds): per-test timeout (pytest-timeout fallback shim)",
        )


if not _HAVE_PYTEST_TIMEOUT:

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        marker = item.get_closest_marker("timeout")
        seconds = int(marker.args[0]) if (marker and marker.args) else _DEFAULT_TIMEOUT_S
        usable = (
            seconds > 0
            and hasattr(signal, "SIGALRM")
            and threading.current_thread() is threading.main_thread()
        )
        if not usable:
            yield
            return

        def _expired(signum, frame):
            raise TimeoutError(f"test exceeded {seconds}s (conftest timeout shim)")

        old = signal.signal(signal.SIGALRM, _expired)
        signal.alarm(seconds)
        try:
            yield
        finally:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, old)
