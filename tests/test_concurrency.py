"""Concurrent-access stress: ≥4 threads hammer one backend with interleaved
put_batch / probe / get_batch / maintenance.  Asserts the thread-safety
contract of ``core.backend``:

  * no lost writes — every sequence a writer committed is fully readable
    after the dust settles;
  * no torn reads — payloads round-trip bit-exactly (raw codec) through
    the CRC-verified tensor log, even while compaction, merging and
    flushes run concurrently;
  * stats sum correctly — counters match the ground truth the threads
    recorded locally.
"""

import threading

import numpy as np
import pytest

from repro.core.codec import CODEC_RAW, BatchCodec
from repro.core.sharded_store import ShardedKVBlockStore
from repro.core.store import KVBlockStore

B = 16
WIDTH = 24
BLOCKS_PER_SEQ = 4
SEQS_PER_WRITER = 24
N_WRITERS = 2


def _seq_tokens(writer: int, i: int):
    rng = np.random.default_rng(1000 * writer + i)
    return rng.integers(0, 50000, size=B * BLOCKS_PER_SEQ).tolist()


def _seq_blocks(writer: int, i: int):
    """Deterministic, sequence-unique payloads so readers can verify values
    (raw codec => lossless round-trip => any torn/mixed read is caught)."""
    rng = np.random.default_rng(7_000_000 + 1000 * writer + i)
    return [rng.standard_normal((B, WIDTH)).astype(np.float16) for _ in range(BLOCKS_PER_SEQ)]


def _mk_store(tmp_path, kind: str):
    codec = BatchCodec(CODEC_RAW, use_zlib=True)
    if kind == "lsm":
        return KVBlockStore(
            str(tmp_path / "lsm"), block_size=B, codec=codec, buffer_bytes=16 * 1024,
            vlog_file_bytes=256 * 1024,
        )
    return ShardedKVBlockStore(
        str(tmp_path / "sharded"), n_shards=4, block_size=B, codec=codec,
        buffer_bytes=16 * 1024, vlog_file_bytes=256 * 1024, io_threads=2,
    )


@pytest.mark.timeout(120)
@pytest.mark.parametrize("kind", ["lsm", "sharded"])
def test_concurrent_stress_no_lost_writes_no_torn_reads(tmp_path, kind):
    store = _mk_store(tmp_path, kind)
    errors = []
    written = {}  # (writer, i) -> True once committed
    written_lock = threading.Lock()
    blocks_put = [0] * N_WRITERS
    writers_done = threading.Event()
    done_count = [0]
    done_lock = threading.Lock()

    def writer(w: int):
        try:
            for i in range(SEQS_PER_WRITER):
                tokens = _seq_tokens(w, i)
                n = store.put_batch(tokens, _seq_blocks(w, i))
                blocks_put[w] += n
                with written_lock:
                    written[(w, i)] = True
        except BaseException as e:  # noqa: BLE001 — surfaced below
            errors.append(e)
        finally:
            with done_lock:
                done_count[0] += 1
                if done_count[0] == N_WRITERS:
                    writers_done.set()

    def verify_one(w: int, i: int, require_full: bool):
        tokens = _seq_tokens(w, i)
        probed = store.probe(tokens)
        if require_full:
            assert probed == B * BLOCKS_PER_SEQ, f"lost write: seq ({w},{i}) probed {probed}"
        got = store.get_batch(tokens, probed)
        expect = _seq_blocks(w, i)
        for blk, exp in zip(got, expect[: len(got)]):
            np.testing.assert_array_equal(blk, exp)  # raw codec: bit-exact or torn

    def reader():
        rng = np.random.default_rng(42)
        try:
            while not writers_done.is_set():
                with written_lock:
                    keys = list(written)
                if not keys:
                    continue
                w, i = keys[rng.integers(0, len(keys))]
                verify_one(w, i, require_full=True)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    def maintainer():
        try:
            while not writers_done.is_set():
                store.maintenance(compact_steps=2)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = (
        [threading.Thread(target=writer, args=(w,)) for w in range(N_WRITERS)]
        + [threading.Thread(target=reader), threading.Thread(target=maintainer)]
    )
    assert len(threads) >= 4
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=90)
        assert not t.is_alive(), "stress thread wedged (lock ordering bug?)"
    assert not errors, f"concurrent errors: {errors[:3]}"

    # ---- no lost writes: every committed sequence fully readable
    for (w, i) in written:
        verify_one(w, i, require_full=True)

    # ---- stats sum correctly against ground truth
    total_blocks = sum(blocks_put)
    assert total_blocks == N_WRITERS * SEQS_PER_WRITER * BLOCKS_PER_SEQ
    st = store.stats
    assert st.put_blocks == total_blocks
    assert st.put_tokens == total_blocks * B
    assert st.probes == st.probe_hits + st.probe_empty
    assert st.get_blocks > 0
    store.close()


@pytest.mark.timeout(120)
def test_concurrent_many_ops_against_maintenance(tmp_path):
    """Fan-out ops racing maintenance on the sharded store: positional
    results stay aligned and complete."""
    store = _mk_store(tmp_path, "sharded")
    seqs = [_seq_tokens(9, i) for i in range(32)]
    blocks = {i: _seq_blocks(9, i) for i in range(32)}
    errors = []
    stop = threading.Event()

    def maintainer():
        try:
            while not stop.is_set():
                store.maintenance(compact_steps=2)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    t = threading.Thread(target=maintainer)
    t.start()
    try:
        store.put_many([(seqs[i], blocks[i], 0) for i in range(32)])
        for _ in range(5):
            probed = store.probe_many(seqs)
            assert probed == [B * BLOCKS_PER_SEQ] * len(seqs)
            got = store.get_many(list(zip(seqs, probed)))
            for i, g in enumerate(got):
                assert len(g) == BLOCKS_PER_SEQ
                np.testing.assert_array_equal(g[0], blocks[i][0])
    finally:
        stop.set()
        t.join(timeout=30)
    assert not t.is_alive()
    assert not errors, f"maintenance errors: {errors[:3]}"
    store.close()
