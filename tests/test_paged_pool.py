"""Paged KV pool: allocator invariants (hypothesis), staging round-trip,
and end-to-end agreement of pool + paged_decode kernel vs dense attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.serving.paged_pool import PagedKVPool, PoolFullError


def make_pool(n_pages=8, page=4, L=2, KVH=2, D=16):
    return PagedKVPool(n_pages, page, L, KVH, D)


def test_alloc_free_roundtrip():
    pool = make_pool()
    pages = pool.alloc(1, 3)
    assert len(set(pages)) == 3 and pool.free_pages == 5
    pool.free(1)
    assert pool.free_pages == 8


def test_pool_full():
    pool = make_pool(n_pages=2)
    pool.alloc(1, 2)
    with pytest.raises(PoolFullError):
        pool.alloc(2, 1)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 31), st.integers(1, 3)), min_size=1, max_size=16))
def test_allocator_never_double_books(ops):
    """Property: live pages are disjoint and free+live == total."""
    pool = make_pool(n_pages=16)
    live = {}
    for seq_id, n in ops:
        if seq_id in live:
            pool.free(seq_id)
            del live[seq_id]
        else:
            try:
                live[seq_id] = pool.alloc(seq_id, n)
            except PoolFullError:
                continue
        flat = [p for pages in live.values() for p in pages]
        assert len(flat) == len(set(flat))  # disjoint
        assert pool.free_pages + len(flat) == 16


def test_staging_and_kernel_agree_with_dense():
    """Promote blocks into the pool, run the paged kernel per layer, and
    compare against dense attention over the same KV."""
    from repro.kernels.decode_attention import paged_decode, paged_decode_ref

    rng = np.random.default_rng(0)
    L, KVH, D, page = 2, 2, 32, 4
    H = 4
    pool = make_pool(n_pages=16, page=page, L=L, KVH=KVH, D=D)
    seqs = {10: 7, 11: 10}  # seq_id -> token count
    dense = {}
    for sid, n_tok in seqs.items():
        pool.alloc(sid, -(-n_tok // page))
        k = rng.standard_normal((L, n_tok, KVH, D)).astype(np.float16)
        v = rng.standard_normal((L, n_tok, KVH, D)).astype(np.float16)
        dense[sid] = (k, v)
        # stage page-aligned blocks (as the hierarchy promotion does)
        for off in range(0, n_tok, page):
            end = min(off + page, n_tok)
            pool.stage_block(sid, off, k[:, off:end], v[:, off:end])
        assert pool.seq_len(sid) == n_tok

    sids = list(seqs)
    tables = jnp.asarray(pool.block_tables(sids))
    lens = jnp.asarray(pool.kv_lens(sids))
    q = jnp.asarray(rng.standard_normal((len(sids), H, D)), jnp.float32)

    for layer in range(L):
        kp, vp = pool.layer_view(layer)
        out = paged_decode(q, jnp.asarray(kp, jnp.float32), jnp.asarray(vp, jnp.float32),
                           tables, lens, interpret=True)
        ref = paged_decode_ref(q, jnp.asarray(kp, jnp.float32), jnp.asarray(vp, jnp.float32),
                               tables, lens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
        # dense cross-check for each sequence
        for i, sid in enumerate(sids):
            k, v = dense[sid]
            kf = jnp.asarray(k[layer], jnp.float32)  # (T, KVH, D)
            qf = q[i].reshape(KVH, H // KVH, D)
            s = jnp.einsum("kgd,tkd->kgt", qf, kf) / (D**0.5)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("kgt,tkd->kgd", p, jnp.asarray(v[layer], jnp.float32))
            np.testing.assert_allclose(
                np.asarray(out)[i], np.asarray(o.reshape(H, D)), rtol=2e-3, atol=2e-3
            )


def test_append_token_extends_pages():
    pool = make_pool(n_pages=4, page=2, L=1, KVH=1, D=8)
    pool.alloc(5, 1)
    for t in range(5):  # crosses two page boundaries
        k = np.full((1, 1, 8), t, np.float16)
        pool.append_token(5, k, k)
    assert pool.seq_len(5) == 5
    assert len(pool.block_tables([5])[0]) == 3
    kp, _ = pool.layer_view(0)
    table = pool.block_tables([5])[0]
    assert kp[table[2], 0, 0, 0] == 4  # 5th token on the 3rd page
