"""int8 error-feedback gradient compression: end-to-end data-parallel demo
(per-device grads inside shard_map, compressed psum) vs the exact mean
gradient — subprocess (needs >1 host device)."""

import os
import subprocess
import sys
import textwrap


def test_compressed_dp_allreduce_close_to_exact():
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import quantize_tensor, dequantize_tensor

        mesh = jax.make_mesh((4,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
        key = jax.random.key(0)
        w = jax.random.normal(key, (16, 8))
        xs = jax.random.normal(jax.random.fold_in(key, 1), (8, 16))  # 2 rows/device
        ys = jax.random.normal(jax.random.fold_in(key, 2), (8, 8))

        def local_loss(w, x, y):
            return jnp.mean((x @ w - y) ** 2)

        def dp_grad_compressed(w, x, y):
            g = jax.grad(local_loss)(w, x, y)      # per-shard gradient
            q, s = quantize_tensor(g)              # int8 on the wire
            # max-scale requantization (same scheme as
            # repro.distributed.compression.allreduce_compressed)
            s_max = jax.lax.pmax(s, "data")
            qr = jnp.round(q.astype(jnp.float32) * (s / s_max))
            qsum = jax.lax.psum(qr.astype(jnp.int32), "data")
            return qsum.astype(jnp.float32) * (s_max / 4)

        fn = shard_map(dp_grad_compressed, mesh=mesh,
                       in_specs=(P(), P("data"), P("data")), out_specs=P(),
                       check_rep=False)
        with mesh:
            g_c = jax.jit(fn)(w, xs, ys)
        g_exact = jax.grad(lambda w: local_loss(w, xs, ys))(w)
        rel = float(jnp.linalg.norm(g_c - g_exact) / jnp.linalg.norm(g_exact))
        assert rel < 0.02, rel  # one-step quantization error ~ 1/127
        print("COMP_OK", rel)
        """
    )
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd="/root/repo",
    )
    assert "COMP_OK" in r.stdout, r.stderr[-2000:]
