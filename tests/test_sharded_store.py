"""ShardedKVBlockStore: routing stability, monolithic equivalence,
round-robin maintenance, global budget eviction, aggregated stats, and the
multi-tenant workload the shard axis exists for."""

import numpy as np
import pytest

from repro.cache.hierarchy import CacheHierarchy
from repro.core import KVBlockStore, ShardedKVBlockStore, shard_of
from repro.workload import MultiTenantWorkload

B = 4


def _blocks(rng, n, kvdim=(2, 4)):
    return [rng.standard_normal((kvdim[0], B, kvdim[1]), dtype=np.float32) for _ in range(n)]


def _seqs(rng, n, max_blocks=6):
    out = []
    for _ in range(n):
        nb = int(rng.integers(1, max_blocks + 1))
        out.append([int(x) for x in rng.integers(0, 50_000, nb * B)])
    return out


# ---------------------------------------------------------------- routing
def test_routing_is_stable_under_extension():
    """Every extension of a prefix must land on the first block's shard —
    prefix locality is what keeps probes and range scans shard-local."""
    rng = np.random.default_rng(0)
    for toks in _seqs(rng, 50):
        base = shard_of(toks, B, 8)
        ext = toks + [int(x) for x in rng.integers(0, 50_000, 3 * B)]
        assert shard_of(ext, B, 8) == base


def test_routing_spreads_distinct_heads():
    rng = np.random.default_rng(1)
    hits = {shard_of(toks, B, 4) for toks in _seqs(rng, 200)}
    assert hits == {0, 1, 2, 3}  # 200 distinct first blocks cover 4 shards


def test_sharded_matches_monolithic(tmp_path):
    """Same operation sequence against both backends: identical probe
    results and identical payloads back (the sharded store is a pure
    partitioning of the keyspace, never a semantic change)."""
    rng = np.random.default_rng(2)
    mono = KVBlockStore(str(tmp_path / "mono"), block_size=B, buffer_bytes=4096)
    shard = ShardedKVBlockStore(str(tmp_path / "shard"), n_shards=4, block_size=B, buffer_bytes=4096)
    seqs = []
    for i, toks in enumerate(_seqs(rng, 30)):
        if seqs and rng.random() < 0.4:  # extend an existing prefix
            parent = seqs[int(rng.integers(0, len(seqs)))]
            toks = parent + [int(x) for x in rng.integers(0, 50_000, 2 * B)]
        blocks = _blocks(rng, len(toks) // B)
        assert mono.put_batch(toks, blocks) == shard.put_batch(toks, blocks)
        seqs.append(toks)
        if i % 5 == 0:
            mono.maintenance()
            shard.maintenance()
    for toks in seqs:
        n = mono.probe(toks)
        assert shard.probe(toks) == n
        got_m, got_s = mono.get_batch(toks, n), shard.get_batch(toks, n)
        assert len(got_m) == len(got_s) == n // B
        for a, b in zip(got_m, got_s):
            np.testing.assert_array_equal(a, b)
    mono.close()
    shard.close()


# ------------------------------------------------------------ maintenance
def test_round_robin_maintenance_bounds_per_cycle_work(tmp_path):
    s = ShardedKVBlockStore(str(tmp_path / "kvs"), n_shards=4, block_size=B,
                            buffer_bytes=4096, shards_per_cycle=1)
    touched = []
    for _ in range(8):
        rep = s.maintenance()
        assert len(rep["shards"]) == 1  # exactly one shard per cycle
        touched.extend(rep["shards"].keys())
    assert touched == [0, 1, 2, 3, 0, 1, 2, 3]  # round-robin, no starvation
    s.close()


def test_global_budget_drains_heaviest_shard_first(tmp_path):
    s = ShardedKVBlockStore(str(tmp_path / "kvs"), n_shards=4, block_size=B,
                            buffer_bytes=2048, vlog_file_bytes=2048,
                            budget_bytes=60_000)
    rng = np.random.default_rng(3)
    for _ in range(80):
        toks = [int(x) for x in rng.integers(0, 100_000, 4 * B)]
        s.put_batch(toks, _blocks(rng, 4, kvdim=(2, 16)))
        s.maintenance()
    assert s.disk_bytes <= 60_000 + 4 * 2048  # budget + per-shard active-file slack
    assert s.stats.evicted_blocks > 0
    s.close()


# ----------------------------------------------------------------- stats
def test_stats_aggregate_across_shards(tmp_path):
    s = ShardedKVBlockStore(str(tmp_path / "kvs"), n_shards=4, block_size=B, buffer_bytes=4096)
    rng = np.random.default_rng(4)
    seqs = _seqs(rng, 40, max_blocks=3)
    total_put = sum(s.put_batch(toks, _blocks(rng, len(toks) // B)) for toks in seqs)
    for toks in seqs:
        s.probe(toks)
    agg = s.stats
    assert agg.put_blocks == total_put == sum(st.put_blocks for st in s.per_shard_stats().values())
    assert agg.probes == len(seqs)
    assert sum(1 for n in s.shard_file_counts() if n) >= 2  # data actually spread
    s.close()


def test_reopen_validates_routing_params(tmp_path):
    root = str(tmp_path / "kvs")
    s = ShardedKVBlockStore(root, n_shards=4, block_size=B)
    s.close()
    with pytest.raises(ValueError, match="orphan"):
        ShardedKVBlockStore(root, n_shards=8, block_size=B)
    with pytest.raises(ValueError, match="orphan"):  # block_size changes the hash too
        ShardedKVBlockStore(root, n_shards=4, block_size=2 * B)
    s2 = ShardedKVBlockStore(root, n_shards=4, block_size=B)
    s2.close()


def test_global_eviction_falls_through_stuck_shard(tmp_path):
    """When the heaviest shard is down to its active file (unevictable),
    eviction must continue with lighter shards instead of giving up."""
    from repro.core import CODEC_RAW, BatchCodec

    s = ShardedKVBlockStore(str(tmp_path / "kvs"), n_shards=2, block_size=B,
                            buffer_bytes=4096, vlog_file_bytes=2048,
                            codec=BatchCodec(CODEC_RAW, use_zlib=False))
    rng = np.random.default_rng(6)

    def toks_for(shard, nb=1):
        while True:
            t = [int(x) for x in rng.integers(0, 100_000, nb * B)]
            if shard_of(t, B, 2) == shard:
                return t

    # shard 0: one 40KB block in a single (active) file — heaviest but stuck
    s.put_batch(toks_for(0), [rng.standard_normal((2, B, 1280), dtype=np.float32)])
    # shard 1: many small sealed files
    for _ in range(20):
        s.put_batch(toks_for(1), _blocks(rng, 1, kvdim=(2, 32)))
    assert s.shards[0].disk_bytes > s.shards[1].disk_bytes
    assert s.shards[1].log.file_count > 2
    s.budget_bytes = s.shards[0].disk_bytes + 4096  # forces draining shard 1
    evicted = s._evict_to_budget()
    assert evicted > 0
    assert s.disk_bytes <= s.budget_bytes
    s.close()


# ----------------------------------------------------- multi-tenant workload
def test_multi_tenant_workload_shapes():
    wl = MultiTenantWorkload(n_tenants=3, prompt_len=64, requests_per_stage=9,
                             stages=(0.5,), block_size=B, corpus_size=4, seed=0)
    reqs = wl.stage_requests(0)
    assert len(reqs) == 9
    tags = [r.tokens[0] for r in reqs]
    assert set(tags) == {wl.vocab, wl.vocab + 1, wl.vocab + 2}  # interleaved
    for r in reqs:
        assert len(r.tokens) == 64
        assert r.tokens[:B] == [r.tokens[0]] * B  # tag block
    # tenants never share a first block -> disjoint keyspaces
    assert len({tuple(r.tokens[:B]) for r in reqs}) == 3


def test_multi_tenant_traffic_spreads_over_shards(tmp_path):
    """End-to-end: M tenant corpora through hierarchy + sharded disk tier;
    tenants populate multiple shards and later stages hit disk."""
    store = ShardedKVBlockStore(str(tmp_path / "kvs"), n_shards=4, block_size=B, buffer_bytes=4096)
    h = CacheHierarchy(B, device_budget_blocks=8, host_budget_blocks=8, store=store)
    wl = MultiTenantWorkload(n_tenants=4, prompt_len=8 * B, requests_per_stage=8,
                             stages=(0.5, 0.75), block_size=B, corpus_size=2, seed=1)
    rng = np.random.default_rng(5)
    for p in wl.warmup_prompts(wl.n_tenants * 2 * 8 * B):
        acq = h.acquire(p)
        nb = (len(p) - acq.reuse_tokens) // B
        h.commit(p, _blocks(rng, nb), acq)
        h.release(acq)
        h.maintenance()
    populated = sum(1 for n in store.shard_disk_bytes() if n)
    assert populated >= 2  # 4 tenant tag-blocks spread over >= 2 of 4 shards
    hits = 0
    for si in range(2):
        for r in wl.stage_requests(si):
            acq = h.acquire(r.tokens)
            hits += acq.reuse_tokens
            nb = (len(r.tokens) - acq.reuse_tokens) // B
            h.commit(r.tokens, _blocks(rng, nb), acq)
            h.release(acq)
    assert hits > 0
    store.close()
