"""Model-zoo correctness: per-arch smoke (shapes + no NaNs, assignment
requirement) and the strong invariant that prefill+decode with caches
reproduces the training forward logits (validates GQA/MLA caches, absorbed
MLA decode, RWKV/Mamba recurrent state, Zamba shared-attn sites, Whisper
cross-attention)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCH_IDS, get_config
from repro.models import layers
from repro.models.layers import _chunked_attention, _direct_attention, moe_layer

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16, key=KEY):
    k1, k2 = jax.random.split(key)
    toks = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    out = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(k2, (B, cfg.enc_frames, cfg.d_model), jnp.bfloat16) * 0.1
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_shapes_no_nans(arch):
    """Assignment smoke: reduced config, one forward/train step on CPU,
    output shapes + no NaNs."""
    cfg = get_config(arch, smoke=True)
    params = models.init_params(cfg, KEY)
    batch = _batch(cfg)
    loss_f = jax.jit(jax.value_and_grad(models.loss_fn(cfg), has_aux=True))
    (loss, parts), grads = loss_f(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # shapes: grads match params exactly
    assert jax.tree.structure(grads) == jax.tree.structure(params)
    for g, p in zip(flat, jax.tree.leaves(params)):
        assert g.shape == p.shape


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_train_forward(arch):
    """Teacher-forced decode (one token at a time through the cache path)
    must reproduce the cache-free training forward logits."""
    # ample MoE capacity so the training reference is effectively dropless
    cfg = dataclasses.replace(get_config(arch, smoke=True), capacity_factor=8.0)
    params = models.init_params(cfg, KEY)
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    inputs = {k: v for k, v in batch.items() if k != "labels"}

    # reference: full forward (no cache)
    if cfg.family == "encdec":
        from repro.models import encdec

        memory = encdec.encode(params, cfg, inputs["frames"])
        ref_logits, _ = encdec.decode_forward(params, cfg, inputs["tokens"], memory=memory)
    else:
        from repro.models import transformer

        ref_logits, _, _ = transformer.lm_forward(params, cfg, inputs["tokens"])
    ref = np.asarray(ref_logits, np.float32)

    # cache path: prefill first half, decode the rest token by token
    half = S // 2
    cache = models.init_cache(cfg, B, S + 4)
    prefill = jax.jit(models.prefill_fn(cfg))
    decode = jax.jit(models.decode_fn(cfg))
    pre_inputs = dict(inputs)
    pre_inputs["tokens"] = inputs["tokens"][:, :half]
    logits, cache = prefill(params, pre_inputs, cache, 0)
    got = [np.asarray(logits, np.float32)]
    for t in range(half, S):
        lg, cache = decode(params, inputs["tokens"][:, t : t + 1], cache, t)
        got.append(np.asarray(lg, np.float32))
    got = np.concatenate(got, axis=1)

    np.testing.assert_allclose(got, ref, rtol=0.15, atol=0.15)  # bf16 paths
    # argmax agreement is the serving-relevant invariant
    agree = (got.argmax(-1) == ref.argmax(-1)).mean()
    assert agree >= 0.95, f"{arch}: argmax agreement {agree}"


@pytest.mark.parametrize("arch", ["qwen3-14b", "minicpm3-4b", "zamba2-1.2b", "rwkv6-1.6b"])
def test_prefill_with_prefix_offset(arch):
    """Two-stage prefill (the serving reuse path: cached prefix + suffix
    compute) == single-shot prefill."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), capacity_factor=8.0)
    params = models.init_params(cfg, KEY)
    B, S = 2, 12
    inputs = _batch(cfg, B, S)
    toks = inputs["tokens"]
    cache_a = models.init_cache(cfg, B, S)
    prefill = jax.jit(models.prefill_fn(cfg))
    full_logits, cache_a = prefill(params, {"tokens": toks}, cache_a, 0)

    cache_b = models.init_cache(cfg, B, S)
    _, cache_b = prefill(params, {"tokens": toks[:, :6]}, cache_b, 0)
    tail_logits, cache_b = prefill(params, {"tokens": toks[:, 6:]}, cache_b, 6)

    np.testing.assert_allclose(
        np.asarray(tail_logits, np.float32),
        np.asarray(full_logits[:, 6:], np.float32),
        rtol=0.1,
        atol=0.1,
    )


# ------------------------------------------------------ attention numerics
def test_chunked_attention_matches_direct():
    rng = np.random.default_rng(0)
    B, S, H, KVH, D = 2, 37, 8, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KVH, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KVH, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kv_len = jnp.full((B,), S)
    ref = _direct_attention(q, k, v, pos, kv_len, True, D**-0.5)
    for chunk in (5, 16, 64):
        got = _chunked_attention(q, k, v, pos, kv_len, True, D**-0.5, chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_chunked_attention_respects_kv_len():
    rng = np.random.default_rng(1)
    B, S, T, H, D = 1, 1, 40, 4, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
    pos = jnp.full((B, S), 17)
    kv_len = jnp.full((B,), 18)
    ref = _direct_attention(q, k[:, :18], v[:, :18], pos, kv_len, True, D**-0.5)
    got = _chunked_attention(q, k, v, pos, kv_len, True, D**-0.5, 7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------- MoE
def test_moe_matches_dense_oracle():
    """With ample capacity, sort-based dispatch == per-token dense oracle."""
    cfg = dataclasses.replace(
        get_config("olmoe-1b-7b", smoke=True), capacity_factor=8.0, n_experts=4, experts_per_token=2
    )
    from repro.models.common import tree_init
    from repro.models.layers import build_moe_template

    p = tree_init(build_moe_template(cfg), KEY)
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    out, probs = moe_layer(p, cfg, x)

    # oracle
    xf = np.asarray(x).reshape(-1, cfg.d_model)
    logits = xf @ np.asarray(p["router"], np.float32)
    pr = np.exp(logits - logits.max(-1, keepdims=True))
    pr /= pr.sum(-1, keepdims=True)
    topk = np.argsort(-pr, axis=-1)[:, : cfg.experts_per_token]
    ref = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        wsum = pr[t, topk[t]].sum()
        for e in topk[t]:
            wg = np.asarray(p["w_gate"][e])
            wu = np.asarray(p["w_up"][e])
            wd = np.asarray(p["w_down"][e])
            h = (xf[t] @ wg) * (1 / (1 + np.exp(-(xf[t] @ wg)))) * (xf[t] @ wu)
            ref[t] += (pr[t, e] / wsum) * (h @ wd)
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model), ref, rtol=1e-4, atol=1e-4)


def test_moe_drops_over_capacity():
    cfg = dataclasses.replace(
        get_config("olmoe-1b-7b", smoke=True), capacity_factor=0.25, n_experts=4, experts_per_token=1
    )
    from repro.models.common import tree_init
    from repro.models.layers import build_moe_template

    p = tree_init(build_moe_template(cfg), KEY)
    x = jnp.ones((1, 16, cfg.d_model), jnp.float32) * 0.3  # all tokens identical -> one expert
    out, _ = moe_layer(p, cfg, x)
    # capacity = 16*1/4*0.25 = 1 slot: at most 1 token served, rest dropped (zeros)
    nz = np.abs(np.asarray(out)).sum(axis=-1) > 1e-6
    assert nz.sum() <= 2


# -------------------------------------------------------------- kv bytes
def test_kv_bytes_per_token_ordering():
    """MLA latent cache must be far smaller than GQA full KV (the property
    that makes minicpm3 the best fit for disk KV caching, cf. Fig. 5)."""
    mla = get_config("minicpm3-4b").kv_bytes_per_token
    qwen = get_config("qwen2.5-32b").kv_bytes_per_token
    glm = get_config("glm4-9b").kv_bytes_per_token
    assert mla < glm < qwen
    assert get_config("rwkv6-1.6b").kv_bytes_per_token == 0
