"""GPipe pipeline (shard_map + ppermute): output must equal sequential
stage application.  Runs in a subprocess (needs >1 host device)."""

import os
import subprocess
import sys
import textwrap


def test_gpipe_matches_sequential():
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import gpipe, pipeline_stage_params

        mesh = jax.make_mesh((4,), ("stage",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        n_stages, n_micro, mb, d = 4, 6, 2, 8
        key = jax.random.key(0)
        w = jax.random.normal(key, (n_stages, d, d)) * 0.3
        xs = jax.random.normal(jax.random.fold_in(key, 1), (n_micro, mb, d))

        def stage_fn(p, x):
            return jnp.tanh(x @ p)

        piped = gpipe(stage_fn, mesh, axis="stage")
        with mesh:
            ys = jax.jit(piped)(w, xs)

        # sequential reference
        ref = xs
        for s in range(n_stages):
            ref = jax.vmap(lambda x: stage_fn(w[s], x))(ref)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(ref), rtol=1e-5, atol=1e-5)

        # stage splitter
        stacked = {"w": jnp.zeros((8, 3))}
        split = pipeline_stage_params(stacked, 4)
        assert split["w"].shape == (4, 2, 3)
        print("PIPE_OK")
        """
    )
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd="/root/repo",
    )
    assert "PIPE_OK" in r.stdout, r.stderr[-2000:]
