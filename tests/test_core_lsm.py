"""Unit + property tests for the LSM index engine (paper §2.2/§3.2/§C)."""

import os
import random

import pytest
from hypothesis_compat import HealthCheck, given, settings, st

from repro.core.bloom import BloomFilter
from repro.core.costmodel import TreeShape, cost_terms, optimize
from repro.core.keycodec import (
    decode_tokens,
    encode_tokens,
    key_token_len,
    shared_prefix_len,
    successor,
)
from repro.core.lsm import LSMTree
from repro.core.memtable import MemTable
from repro.core.sst import SSTReader, SSTWriter
from repro.core.wal import WAL


# --------------------------------------------------------------- key codec
@given(st.lists(st.integers(0, 2**32 - 1), max_size=64))
def test_keycodec_roundtrip(tokens):
    key = encode_tokens(tokens)
    assert decode_tokens(key) == tuple(tokens)
    assert key_token_len(key) == len(tokens)


@given(
    st.lists(st.integers(0, 2**32 - 1), max_size=32),
    st.lists(st.integers(0, 2**32 - 1), max_size=32),
)
def test_keycodec_order_preserving(a, b):
    """Lexicographic order of encodings == lexicographic order of sequences:
    the core property the prefix-preserving index relies on."""
    ka, kb = encode_tokens(a), encode_tokens(b)
    assert (ka < kb) == (tuple(a) < tuple(b))
    assert (ka == kb) == (tuple(a) == tuple(b))
    # prefix property
    is_prefix = len(a) <= len(b) and tuple(b[: len(a)]) == tuple(a)
    assert kb.startswith(ka) == is_prefix


@given(st.binary(max_size=24))
def test_successor_bound(key):
    s = successor(key)
    if key and any(b != 0xFF for b in key):
        assert s > key
        # everything prefixed by `key` sorts below successor(key)
        assert s > key + b"\xff" * 4
    else:
        assert s is None  # no finite bound exists


def test_shared_prefix_len():
    assert shared_prefix_len(b"abcd", b"abcf") == 3
    assert shared_prefix_len(b"", b"x") == 0
    assert shared_prefix_len(b"ab", b"ab") == 2


# ------------------------------------------------------------------- bloom
@given(st.sets(st.binary(min_size=1, max_size=16), min_size=1, max_size=200))
def test_bloom_no_false_negatives(keys):
    bf = BloomFilter.for_entries(len(keys), 10.0)
    for k in keys:
        bf.add(k)
    for k in keys:
        assert k in bf
    raw = bf.to_bytes()
    bf2 = BloomFilter.from_bytes(raw)
    for k in keys:
        assert k in bf2


def test_bloom_fpr_reasonable():
    bf = BloomFilter.for_entries(1000, 10.0)
    rng = random.Random(0)
    ins = {bytes([rng.randrange(256) for _ in range(8)]) for _ in range(1000)}
    for k in ins:
        bf.add(k)
    probes = 0
    fps = 0
    while probes < 5000:
        k = bytes([rng.randrange(256) for _ in range(8)])
        if k in ins:
            continue
        probes += 1
        fps += k in bf
    assert fps / probes < 0.05  # 10 bits/key -> ~1% analytic


# --------------------------------------------------------------- memtable
@given(st.lists(st.tuples(st.binary(min_size=1, max_size=8), st.one_of(st.none(), st.binary(max_size=8)))))
def test_memtable_matches_dict(ops):
    mt = MemTable()
    d = {}
    for k, v in ops:
        mt.put(k, v)
        d[k] = v
    assert sorted(d) == [k for k, _ in mt.items()]
    for k, v in d.items():
        found, got = mt.get(k)
        assert found and got == v


# --------------------------------------------------------------------- sst
@given(
    st.dictionaries(st.binary(min_size=1, max_size=12), st.binary(max_size=32), min_size=1, max_size=300)
)
@settings(max_examples=30, suppress_health_check=[HealthCheck.function_scoped_fixture], deadline=None)
def test_sst_roundtrip(tmp_path_factory, kv):
    path = str(tmp_path_factory.mktemp("sst") / "run.sst")
    w = SSTWriter(path, block_bytes=256)
    for k in sorted(kv):
        w.add(k, kv[k])
    meta = w.finish()
    assert meta.entries == len(kv)
    r = SSTReader(path)
    for k, v in kv.items():
        found, got = r.get(k)
        assert found and got == v
    # absent keys
    assert r.get(b"\x00" * 13)[0] is False
    # full ordered scan
    assert [(k, v) for k, v in r.items()] == sorted(kv.items())
    # sub-range
    ks = sorted(kv)
    lo, hi = ks[len(ks) // 4], ks[3 * len(ks) // 4]
    assert list(r.range(lo, hi)) == [(k, v) for k, v in sorted(kv.items()) if lo <= k < hi]
    r.close()


def test_sst_prefix_compression_effective(tmp_path):
    """Token-prefix keys share long prefixes; on-disk cost must be ~suffix."""
    path = str(tmp_path / "run.sst")
    base = list(range(1000))
    keys = [encode_tokens(base[: i + 1]) for i in range(1000)]  # up to 4KB keys
    w = SSTWriter(path, block_bytes=4096)
    for k in keys:
        w.add(k, b"v" * 8)
    w.finish()
    raw_key_bytes = sum(len(k) for k in keys)  # ~2MB uncompressed
    assert os.path.getsize(path) < raw_key_bytes * 0.1


# --------------------------------------------------------------------- wal
def test_wal_replay_and_torn_tail(tmp_path):
    path = str(tmp_path / "wal.log")
    w = WAL(path)
    recs = [(bytes([i]), bytes([i] * i) if i % 3 else None) for i in range(1, 20)]
    for k, v in recs:
        w.append(k, v)
    w.sync()
    w.close()
    assert list(WAL.replay(path)) == recs
    # torn tail: truncate mid-record -> earlier records still replay
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 3)
    replayed = list(WAL.replay(path))
    assert replayed == recs[: len(replayed)]
    assert len(replayed) >= len(recs) - 2


# --------------------------------------------------------------------- lsm
class _Oracle:
    def __init__(self):
        self.d = {}

    def apply(self, k, v):
        if v is None:
            self.d.pop(k, None)
        else:
            self.d[k] = v


@given(
    ops=st.lists(
        st.tuples(
            st.binary(min_size=1, max_size=6),
            st.one_of(st.none(), st.binary(max_size=24)),
        ),
        max_size=400,
    ),
    buffer_bytes=st.sampled_from([256, 1024]),
    T=st.sampled_from([2, 4, 8]),
    K=st.sampled_from([1, 3]),
)
@settings(max_examples=25, deadline=None)
def test_lsm_matches_oracle(tmp_path_factory, ops, buffer_bytes, T, K):
    root = str(tmp_path_factory.mktemp("lsm"))
    t = LSMTree(root, buffer_bytes=buffer_bytes, size_ratio=T, runs_per_level=min(K, T - 1))
    oracle = _Oracle()
    for k, v in ops:
        t.put(k, v)
        oracle.apply(k, v)
    for k, v in oracle.d.items():
        found, got = t.get(k)
        assert found and got == v, k
    # deleted keys report absent
    deleted = {k for k, v in ops if v is None} - set(oracle.d)
    for k in deleted:
        assert t.get(k)[0] is False
    # full range matches oracle
    assert list(t.range(b"", b"\xff" * 8)) == sorted(oracle.d.items())
    t.close()


@given(
    ops=st.lists(
        st.tuples(st.binary(min_size=1, max_size=6), st.binary(max_size=16)),
        min_size=1,
        max_size=200,
    )
)
@settings(max_examples=15, deadline=None)
def test_lsm_crash_recovery(tmp_path_factory, ops):
    """Crash-without-close (WAL replay + manifest) loses nothing."""
    root = str(tmp_path_factory.mktemp("lsmcr"))
    t = LSMTree(root, buffer_bytes=512)
    d = {}
    for k, v in ops:
        t.put(k, v)
        d[k] = v
    t.wal.sync()
    # simulate crash: abandon the instance without close/flush
    del t
    t2 = LSMTree(root, buffer_bytes=512)
    for k, v in d.items():
        found, got = t2.get(k)
        assert found and got == v
    t2.close()


def test_lsm_lazy_param_transition(tmp_path):
    """set_targets must not restructure immediately; levels adopt (T,K) on
    their next compaction (paper App. C)."""
    t = LSMTree(str(tmp_path), buffer_bytes=256, size_ratio=2, runs_per_level=1)
    rng = random.Random(0)
    for i in range(300):
        t.put(bytes([rng.randrange(256) for _ in range(6)]), b"x" * 16)
    before = t.level_params()
    t.set_targets(8, 7)
    assert t.level_params() == before  # lazy: nothing restructured yet
    for i in range(1500):
        t.put(bytes([rng.randrange(256) for _ in range(6)]), b"x" * 16)
    t.flush()
    t.compact_all()
    assert any(p == (8, 7) for p in t.level_params())
    t.close()


def test_lsm_tiering_has_lower_write_amp(tmp_path):
    """K=T-1 (tiering) must show lower write amplification than K=1
    (leveling) on a pure-insert workload — the §3.3 premise."""

    def run(K):
        root = str(tmp_path / f"k{K}")
        t = LSMTree(root, buffer_bytes=2048, size_ratio=4, runs_per_level=K)
        rng = random.Random(1)
        for i in range(4000):
            t.put(bytes([rng.randrange(256) for _ in range(8)]), b"v" * 20)
        wa = t.stats.compact_bytes_out / max(1, t.stats.puts * 28)
        t.close()
        return wa

    assert run(3) < run(1)


# --------------------------------------------------------------- cost model
def test_cost_model_limits():
    shape = TreeShape(n_entries=1_000_000, entry_bytes=32, buffer_bytes=1 << 20)
    lv = cost_terms(shape, T=4, K=1)
    tr = cost_terms(shape, T=4, K=3)
    assert tr["W"] < lv["W"]  # tiering writes cheaper
    assert tr["R"] > lv["R"]  # tiering reads costlier
    assert tr["S"] > lv["S"]


def test_optimizer_tracks_workload():
    shape = TreeShape(n_entries=1_000_000, entry_bytes=32, buffer_bytes=1 << 20)
    write_heavy = optimize(shape, w=0.9, s=0.02, r=0.05, z=0.03)
    read_heavy = optimize(shape, w=0.05, s=0.45, r=0.45, z=0.05)
    assert write_heavy["K"] > read_heavy["K"]  # §3.3: writes favor tiering
    assert read_heavy["K"] == 1  # reads favor leveling
